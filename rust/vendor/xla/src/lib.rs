//! Offline stub of the xla-rs PJRT bindings.
//!
//! Mirrors the exact API surface `cocopie::runtime` uses — [`PjRtClient`],
//! [`PjRtLoadedExecutable`], [`PjRtBuffer`], [`Literal`],
//! [`HloModuleProto`], [`XlaComputation`] — but every operational entry
//! point returns [`Error::Unavailable`]: the build container links no
//! XLA/PJRT native library. See README.md for how to swap in the real
//! bindings; no downstream source changes are required.

use std::fmt;

/// Stub error: the requested PJRT operation is not available offline.
#[derive(Debug, Clone)]
pub enum Error {
    /// Which entry point was hit, for actionable messages.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} unavailable (no PJRT native library in \
                 this build; see rust/vendor/xla/README.md)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types that can cross the host/device boundary.
pub trait NativeType: sealed::Sealed + Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side literal value. The stub keeps no data: the first operation
/// on it reports unavailability.
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready to compile.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
    }

    #[test]
    fn errors_render_actionable_messages() {
        let e = PjRtClient::cpu().unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("PjRtClient::cpu"), "{msg}");
        assert!(msg.contains("README"), "{msg}");
    }
}
