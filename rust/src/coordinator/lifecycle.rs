//! Live deployment lifecycle — the control plane of a *running*
//! [`super::Coordinator`].
//!
//! The builder freezes a menu at startup; this module un-freezes it.
//! A [`Lifecycle`] handle (cloneable, off-thread) can
//! [`register`](Lifecycle::register) new versions — compiled and
//! warmed off the leader thread, gated by the static plan verifier —
//! and [`retire`](Lifecycle::retire) old ones, which *drain* their
//! shard queues (never drop them) and only return once the last
//! outstanding request, failover retries included, has resolved.
//!
//! On top of the registry sit two controllers:
//!
//! * [`Lifecycle::canary`] drives a staged rollout
//!   (e.g. 5% → 25% → 100% of the incumbent's unpinned traffic,
//!   split by the deployment tier's deficit-round-robin `Split`
//!   policy), judging each stage from *windowed* [`Metrics`] deltas —
//!   live p99, shed rate, failovers — against the incumbent, and
//!   promotes or rolls back automatically.
//! * [`Retuner`] periodically re-runs the batched auto-tuner at the
//!   batch size the deployment has actually been serving (the
//!   observed windowed mean, not the build-time guess) and, when the
//!   re-tuned plan measurably wins, hot-swaps it in as
//!   `name@(v+1)` through the same canary gate. Weights stay
//!   `Arc`-shared between versions, so the swap is pointer-flip
//!   cheap.

use std::str::FromStr;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::codegen::{autotune_plan_batched, observed_tune_batch,
                     ExecPlan};
use crate::exec::{ModelExecutor, Tensor};

use super::deployment::verify_for_serving;
use super::metrics::{Metrics, Summary};
use super::{router, spawn_deployment, Control, Deployment, Installed,
            Registry, Request, SharedDepMetrics, SlotState,
            SpawnedDep};

/// A versioned deployment identity, rendered `name@version`
/// (`"cocogen@3"`). A bare name parses as version 1, so pre-lifecycle
/// deployment names are valid version-1 ids.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct DeploymentId {
    pub name: String,
    pub version: u32,
}

impl DeploymentId {
    pub fn new(name: &str, version: u32) -> DeploymentId {
        DeploymentId {
            name: name.to_string(),
            version,
        }
    }

    /// Parse `"name@3"`; a bare `"name"` is version 1.
    pub fn parse(s: &str) -> Result<DeploymentId> {
        match s.rsplit_once('@') {
            None => {
                ensure!(!s.is_empty(), "empty deployment id");
                Ok(DeploymentId {
                    name: s.to_string(),
                    version: 1,
                })
            }
            Some((name, v)) => {
                ensure!(!name.is_empty(),
                        "empty deployment name in '{s}'");
                let version: u32 = v.parse().map_err(|_| {
                    anyhow!("bad version '{v}' in deployment id '{s}'")
                })?;
                ensure!(version >= 1,
                        "version must be >= 1 in '{s}'");
                Ok(DeploymentId {
                    name: name.to_string(),
                    version,
                })
            }
        }
    }

    /// The next version of the same deployment.
    pub fn next(&self) -> DeploymentId {
        DeploymentId {
            name: self.name.clone(),
            version: self.version + 1,
        }
    }
}

impl std::fmt::Display for DeploymentId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
           -> std::fmt::Result {
        write!(f, "{}@{}", self.name, self.version)
    }
}

impl FromStr for DeploymentId {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<DeploymentId> {
        DeploymentId::parse(s)
    }
}

/// Control-plane handle onto a running coordinator. Cloneable and
/// thread-safe: registration compiles and warms the new version's
/// backends on the *calling* thread (serving continues untouched),
/// then hands the finished structures to the leader, which installs
/// them between batches.
#[derive(Clone)]
pub struct Lifecycle {
    control: Sender<Control>,
    registry: Arc<RwLock<Registry>>,
    dep_metrics: SharedDepMetrics,
    global: Arc<Metrics>,
    pending: Arc<AtomicUsize>,
    retry: Sender<Vec<Request>>,
    max_batch: usize,
}

impl Lifecycle {
    pub(crate) fn new(
        control: Sender<Control>, registry: Arc<RwLock<Registry>>,
        dep_metrics: SharedDepMetrics, global: Arc<Metrics>,
        pending: Arc<AtomicUsize>, retry: Sender<Vec<Request>>,
        max_batch: usize,
    ) -> Lifecycle {
        Lifecycle {
            control,
            registry,
            dep_metrics,
            global,
            pending,
            retry,
            max_batch,
        }
    }

    /// Register a new deployment version on the running coordinator
    /// and make it immediately routable (state `Live`). Returns its
    /// slot index. Compile and warm-up run on this thread; the plan
    /// must pass the static verifier at batch 1 *and* the
    /// coordinator's serving batch before any traffic can reach it.
    pub fn register(&self, dep: Deployment) -> Result<usize> {
        self.install(dep, SlotState::Live)
    }

    /// Register a version as a `Canary`: warm and serving, but outside
    /// the unpinned rotation until [`Lifecycle::canary_weight`] routes
    /// it a traffic share (or a promote flips it `Live`).
    pub fn register_canary(&self, dep: Deployment) -> Result<usize> {
        self.install(dep, SlotState::Canary)
    }

    fn install(&self, dep: Deployment, state: SlotState)
               -> Result<usize> {
        ensure!(!dep.name.is_empty(),
                "deployment names must be non-empty");
        ensure!(!dep.backends.is_empty(),
                "deployment '{}' has no backends", dep.name);
        ensure!(
            dep.backends.len() <= 64,
            "deployment '{}': at most 64 backends (failed-backend \
             tracking is a u64 bitmask)",
            dep.name
        );
        {
            let reg = self.registry.read().unwrap();
            ensure!(
                !reg.slots.iter().any(|s| s.name == dep.name),
                "duplicate deployment name '{}'",
                dep.name
            );
            ensure!(
                reg.slots.len() < router::MAX_VARIANTS,
                "at most {} deployments over a coordinator's lifetime",
                router::MAX_VARIANTS
            );
        }
        // Registration gate: no version becomes routable unless the
        // static verifier proves its plan safe at every batch size the
        // coordinator will form.
        if let Some(plan) = dep.plan() {
            verify_for_serving(&dep.name, plan,
                               &[1, self.max_batch])?;
        }
        let mut sd = spawn_deployment(dep, self.max_batch,
                                      &self.global, &self.pending,
                                      &self.retry)?;
        // Blocks here — on the *caller's* thread — until every backend
        // has compiled; the leader never waits on a compile.
        let sig = sd.signature()?;
        let SpawnedDep {
            name,
            dep,
            variant,
            workers,
            bms,
            metrics,
            plan,
            ..
        } = sd;
        let (reply_tx, reply_rx) = mpsc::channel();
        let msg = Box::new(Installed {
            name: name.clone(),
            elems: sig.image_elems(),
            state,
            dep,
            variant,
            workers,
            metrics: metrics.clone(),
            plan,
        });
        self.control
            .send(Control::Install {
                msg,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        let slot = reply_rx
            .recv()
            .map_err(|_| anyhow!("coordinator stopped"))?
            .map_err(|e| anyhow!(e))?;
        self.dep_metrics
            .lock()
            .unwrap()
            .push((name, metrics, bms));
        Ok(slot)
    }

    /// Retire a version: it leaves the rotation at once (late `infer`s
    /// get a typed [`super::ServeError::Retired`]), its shard queue is
    /// *drained* to the backends, and this call returns — with the
    /// retiree's final summary — only once its outstanding count,
    /// failover retries included, reaches zero.
    pub fn retire(&self, name: &str) -> Result<Summary> {
        self.retire_to(name, None)
    }

    /// [`Lifecycle::retire`], naming the `successor` version embedded
    /// in the [`super::ServeError::Retired`] hint late clients see.
    pub fn retire_to(&self, name: &str,
                     successor: Option<Arc<str>>) -> Result<Summary> {
        let slot = self.slot_of(name)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        self.control
            .send(Control::Retire {
                slot,
                successor,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("coordinator stopped"))?
            .map_err(|e| anyhow!(e))
    }

    /// Route `weight` (in `[0, 1]`) of the incumbent's unpinned
    /// traffic to the canary via the deficit-round-robin `Split`
    /// policy. Call again with a new weight to advance a rollout
    /// stage.
    pub fn canary_weight(&self, incumbent: &str, canary: &str,
                         weight: f64) -> Result<()> {
        let incumbent = self.slot_of(incumbent)?;
        let canary = self.slot_of(canary)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        self.control
            .send(Control::CanarySet {
                incumbent,
                canary,
                weight,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("coordinator stopped"))?
            .map_err(|e| anyhow!(e))
    }

    /// Tear the canary split down. `promote` flips the canary slot
    /// `Live` (it joins the unpinned rotation); otherwise it stays
    /// `Canary` for the controller to retire (rollback).
    pub fn canary_end(&self, promote: bool) -> Result<()> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.control
            .send(Control::CanaryEnd {
                promote,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("coordinator stopped"))?
            .map_err(|e| anyhow!(e))
    }

    /// Every registered version and its lifecycle state, in
    /// registration order (tombstones included).
    pub fn status(&self) -> Vec<(Arc<str>, SlotState)> {
        self.registry
            .read()
            .unwrap()
            .slots
            .iter()
            .map(|s| (s.name.clone(), s.state))
            .collect()
    }

    /// Drive a full staged rollout of `dep` against `incumbent`:
    /// register it as a canary, walk `cfg.stages`, and at each stage
    /// reset both versions' metric windows, wait for evidence, and
    /// judge the canary's windowed p99 / shed rate / failovers against
    /// the incumbent's. Any failed stage rolls back (canary drained
    /// and retired, incumbent untouched); surviving every stage
    /// promotes the canary `Live` and retires the incumbent.
    pub fn canary(&self, dep: Deployment, incumbent: &str,
                  cfg: &CanaryConfig) -> Result<CanaryOutcome> {
        ensure!(!cfg.stages.is_empty(),
                "canary needs at least one stage");
        let canary_name: Arc<str> = dep.name.clone();
        // Resolve the incumbent before compiling anything.
        self.slot_of(incumbent)?;
        self.register_canary(dep)?;
        let inc_m = self.slot_metrics(incumbent)?;
        let can_m = self.slot_metrics(&canary_name)?;
        for (stage, &weight) in cfg.stages.iter().enumerate() {
            if let Err(e) =
                self.canary_weight(incumbent, &canary_name, weight)
            {
                let _ = self.retire_to(&canary_name,
                                       Some(Arc::from(incumbent)));
                return Err(e);
            }
            // Epoch-tagged window reset: this stage's evidence starts
            // clean on both sides, unpolluted by the predecessor
            // stage (or the incumbent's whole history).
            inc_m.reset_window();
            can_m.reset_window();
            let t0 = Instant::now();
            while can_m.window_completed() < cfg.min_requests
                && t0.elapsed() < cfg.stage_window
            {
                std::thread::sleep(cfg.poll);
            }
            if let Some(reason) = judge(&inc_m.window_summary(),
                                        &can_m.window_summary(), cfg)
            {
                self.canary_end(false)?;
                let _ = self.retire_to(&canary_name,
                                       Some(Arc::from(incumbent)));
                return Ok(CanaryOutcome::RolledBack {
                    stage,
                    weight,
                    reason,
                });
            }
        }
        self.canary_end(true)?;
        self.retire_to(incumbent, Some(canary_name))?;
        Ok(CanaryOutcome::Promoted)
    }

    pub(crate) fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub(crate) fn slot_plan(&self, name: &str)
                            -> Result<(Option<Arc<ExecPlan>>, f64)> {
        let reg = self.registry.read().unwrap();
        let s = reg
            .slots
            .iter()
            .find(|s| &*s.name == name)
            .ok_or_else(|| anyhow!("unknown deployment '{name}'"))?;
        ensure!(s.state == SlotState::Live,
                "deployment '{name}' is not live");
        Ok((s.plan.clone(), s.metrics.summary().mean_batch))
    }

    fn slot_of(&self, name: &str) -> Result<usize> {
        self.registry
            .read()
            .unwrap()
            .slots
            .iter()
            .position(|s| &*s.name == name)
            .ok_or_else(|| anyhow!("unknown deployment '{name}'"))
    }

    fn slot_metrics(&self, name: &str) -> Result<Arc<Metrics>> {
        self.registry
            .read()
            .unwrap()
            .slots
            .iter()
            .find(|s| &*s.name == name)
            .map(|s| s.metrics.clone())
            .ok_or_else(|| anyhow!("unknown deployment '{name}'"))
    }
}

/// Staged-rollout policy for [`Lifecycle::canary`].
#[derive(Debug, Clone)]
pub struct CanaryConfig {
    /// Traffic fractions routed to the canary, one rollout stage
    /// each (default `5% → 25% → 100%`).
    pub stages: Vec<f64>,
    /// Maximum wall-clock per stage before judging with whatever
    /// evidence arrived.
    pub stage_window: Duration,
    /// Minimum canary completions a stage window must hold to
    /// promote — fewer is "insufficient evidence" and rolls back.
    pub min_requests: u64,
    /// Rollback when the canary's windowed p99 exceeds the
    /// incumbent's windowed p99 times this ratio.
    pub max_p99_ratio: f64,
    /// Floor (ms) below which p99 deltas are timer noise, not
    /// regressions — both sides are raised to it before comparing.
    pub p99_floor_ms: f64,
    /// Allowed canary shed-rate excess over the incumbent's.
    pub max_shed_excess: f64,
    /// Allowed canary failovers per stage window.
    pub max_failovers: u64,
    /// Poll interval while a stage window fills.
    pub poll: Duration,
}

impl Default for CanaryConfig {
    fn default() -> CanaryConfig {
        CanaryConfig {
            stages: vec![0.05, 0.25, 1.0],
            stage_window: Duration::from_secs(5),
            min_requests: 32,
            max_p99_ratio: 1.5,
            p99_floor_ms: 5.0,
            max_shed_excess: 0.05,
            max_failovers: 0,
            poll: Duration::from_millis(20),
        }
    }
}

/// What a staged rollout decided.
#[derive(Debug, Clone, PartialEq)]
pub enum CanaryOutcome {
    /// Every stage passed: the canary is `Live`, the incumbent
    /// drained and retired.
    Promoted,
    /// A stage failed: the canary drained and retired, the incumbent
    /// untouched.
    RolledBack {
        stage: usize,
        weight: f64,
        reason: String,
    },
}

/// The promote/rollback decision for one canary stage, from the two
/// windowed summaries. `None` means the stage passes.
fn judge(inc: &Summary, can: &Summary, cfg: &CanaryConfig)
         -> Option<String> {
    if can.completed < cfg.min_requests {
        return Some(format!(
            "insufficient evidence: {} canary completions in the \
             stage window (need {})",
            can.completed, cfg.min_requests
        ));
    }
    if can.failovers > cfg.max_failovers {
        return Some(format!(
            "{} failovers in the canary window (allowed {})",
            can.failovers, cfg.max_failovers
        ));
    }
    let shed_rate = |s: &Summary| {
        s.shed as f64 / (s.completed + s.shed).max(1) as f64
    };
    let excess = shed_rate(can) - shed_rate(inc);
    if excess > cfg.max_shed_excess {
        return Some(format!(
            "canary shed rate exceeds the incumbent's by {excess:.3}"
        ));
    }
    // With an empty incumbent window (e.g. the 100% stage routes it
    // nothing) there is no latency baseline — p99 cannot regress
    // against nothing, so only the absolute gates above apply.
    let budget = if inc.completed > 0 {
        inc.p99_ms.max(cfg.p99_floor_ms) * cfg.max_p99_ratio
    } else {
        f64::INFINITY
    };
    if can.p99_ms.max(cfg.p99_floor_ms) > budget {
        return Some(format!(
            "canary windowed p99 {:.2} ms over budget {:.2} ms",
            can.p99_ms, budget
        ));
    }
    None
}

/// Policy for the background [`Retuner`] (and one-shot
/// [`retune_once`]).
#[derive(Debug, Clone)]
pub struct RetunerConfig {
    /// Minimum measured speedup (incumbent time / re-tuned time)
    /// before a re-tuned plan is worth a canary rollout.
    pub min_speedup: f64,
    /// Threads the offline tuner and comparison measure with.
    pub threads: usize,
    /// Rollout gate a winning plan must pass.
    pub canary: CanaryConfig,
    /// Interval between re-tune passes.
    pub interval: Duration,
}

impl Default for RetunerConfig {
    fn default() -> RetunerConfig {
        RetunerConfig {
            min_speedup: 1.05,
            threads: 1,
            canary: CanaryConfig::default(),
            interval: Duration::from_secs(30),
        }
    }
}

/// What one re-tune pass did.
#[derive(Debug, Clone)]
pub enum RetuneOutcome {
    /// The deployment has no attached plan to re-tune (custom
    /// backends).
    NoPlan,
    /// Re-tuned and measured at the observed batch; the incumbent
    /// plan kept winning (speedup below the configured minimum).
    Kept {
        observed_batch: usize,
        speedup: f64,
    },
    /// The re-tuned plan won offline and went through the canary gate
    /// as version `id`.
    Swapped {
        id: String,
        speedup: f64,
        outcome: CanaryOutcome,
    },
}

/// One re-tune pass: re-run the batched auto-tuner at the batch size
/// the deployment has *actually* been serving (its observed mean
/// batch from [`Metrics`], not the build-time guess), measure the
/// tuned plan against the incumbent's, and when it wins by at least
/// `cfg.min_speedup`, roll it out as `name@(v+1)` through the canary
/// gate. Weights are `Arc`-shared between the plans, so the re-tuned
/// version costs metadata, not a second copy of the model.
pub fn retune_once(lc: &Lifecycle, name: &str, cfg: &RetunerConfig)
                   -> Result<RetuneOutcome> {
    let (plan, mean_batch) = lc.slot_plan(name)?;
    let Some(plan) = plan else {
        return Ok(RetuneOutcome::NoPlan);
    };
    let batch = observed_tune_batch(mean_batch, lc.max_batch());
    // A serving plan is shared immutably; tune a field-wise copy
    // (weights stay shared) and compare both at the observed batch.
    let mut tuned = ExecPlan {
        ir: plan.ir.clone(),
        layers: plan.layers.clone(),
        scheme: plan.scheme,
    };
    autotune_plan_batched(&mut tuned, cfg.threads, batch);
    let tuned = tuned.into_shared();
    let t_old = measure_batch_ms(&plan, cfg.threads, batch);
    let t_new = measure_batch_ms(&tuned, cfg.threads, batch);
    let speedup = t_old / t_new.max(1e-9);
    if speedup < cfg.min_speedup {
        return Ok(RetuneOutcome::Kept {
            observed_batch: batch,
            speedup,
        });
    }
    let id = DeploymentId::parse(name)?.next();
    let dep = Deployment::from_plan(&id.to_string(), tuned);
    let outcome = lc.canary(dep, name, &cfg.canary)?;
    Ok(RetuneOutcome::Swapped {
        id: id.to_string(),
        speedup,
        outcome,
    })
}

/// Measured batched latency (ms): one warm-up plus best-of-2 direct
/// executor runs on zero images at the target batch — the same
/// protocol as the build-time latency prior, at the serving batch.
fn measure_batch_ms(plan: &Arc<ExecPlan>, threads: usize,
                    batch: usize) -> f64 {
    let inp = plan.ir.input;
    let mut exec = ModelExecutor::new_batched(plan, threads, batch);
    let images: Vec<Tensor> = (0..batch)
        .map(|_| Tensor::zeros(inp.c, inp.h, inp.w))
        .collect();
    exec.run_batch(&images); // warm: arena + scratch allocation
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let t0 = Instant::now();
        exec.run_batch(&images);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best * 1e3
}

/// Background re-tuning loop: wakes every `cfg.interval`, runs
/// [`retune_once`] on the named deployment — following it across
/// promoted swaps, so `model@2` re-tunes as `model@3` next pass — and
/// records each outcome. [`Retuner::stop`] (or drop) signals the loop
/// and joins it.
pub struct Retuner {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<Vec<RetuneOutcome>>>,
}

impl Retuner {
    pub fn spawn(lc: Lifecycle, name: &str, cfg: RetunerConfig)
                 -> Retuner {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let mut current = name.to_string();
        let handle = std::thread::spawn(move || {
            let mut outcomes = Vec::new();
            'passes: loop {
                // Interruptible sleep: stop() must not wait out a
                // long interval.
                let t0 = Instant::now();
                while t0.elapsed() < cfg.interval {
                    if flag.load(Ordering::SeqCst) {
                        break 'passes;
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                match retune_once(&lc, &current, &cfg) {
                    Ok(o) => {
                        if let RetuneOutcome::Swapped {
                            id,
                            outcome: CanaryOutcome::Promoted,
                            ..
                        } = &o
                        {
                            current = id.clone();
                        }
                        outcomes.push(o);
                    }
                    // Coordinator gone, or the slot was retired under
                    // us — either way this retuner's job is over.
                    Err(_) => break 'passes,
                }
            }
            outcomes
        });
        Retuner {
            stop,
            handle: Some(handle),
        }
    }

    /// Signal the loop and join it, returning every pass's outcome.
    pub fn stop(mut self) -> Vec<RetuneOutcome> {
        self.stop.store(true, Ordering::SeqCst);
        self.handle
            .take()
            .and_then(|h| h.join().ok())
            .unwrap_or_default()
    }
}

impl Drop for Retuner {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deployment_id_parses_versions_and_bare_names() {
        let id = DeploymentId::parse("cocogen@3").unwrap();
        assert_eq!(id.name, "cocogen");
        assert_eq!(id.version, 3);
        assert_eq!(id.to_string(), "cocogen@3");
        let bare = DeploymentId::parse("cocogen").unwrap();
        assert_eq!(bare, DeploymentId::new("cocogen", 1));
        assert_eq!(bare.next().to_string(), "cocogen@2");
        // FromStr round-trips through the same parser.
        let fs: DeploymentId = "seq@7".parse().unwrap();
        assert_eq!(fs, DeploymentId::new("seq", 7));
    }

    #[test]
    fn deployment_id_rejects_malformed_ids() {
        assert!(DeploymentId::parse("").is_err());
        assert!(DeploymentId::parse("@2").is_err());
        assert!(DeploymentId::parse("m@").is_err());
        assert!(DeploymentId::parse("m@zero").is_err());
        assert!(DeploymentId::parse("m@0").is_err());
        // An embedded '@' belongs to the name; the *last* one is the
        // version separator.
        let odd = DeploymentId::parse("a@b@2").unwrap();
        assert_eq!(odd.name, "a@b");
        assert_eq!(odd.version, 2);
    }

    fn summary(completed: u64, p99_ms: f64, shed: u64,
               failovers: u64) -> Summary {
        Summary {
            completed,
            rejected: 0,
            failovers,
            shed,
            queue_depth: 0,
            queue_depth_max: 0,
            p50_ms: p99_ms / 2.0,
            p99_ms,
            mean_queue_ms: 0.0,
            mean_batch: 1.0,
        }
    }

    fn cfg() -> CanaryConfig {
        CanaryConfig {
            min_requests: 10,
            ..CanaryConfig::default()
        }
    }

    #[test]
    fn judge_passes_a_clean_canary() {
        let inc = summary(100, 20.0, 0, 0);
        let can = summary(50, 24.0, 0, 0);
        assert_eq!(judge(&inc, &can, &cfg()), None);
    }

    #[test]
    fn judge_rolls_back_on_p99_regression() {
        let inc = summary(100, 20.0, 0, 0);
        let can = summary(50, 31.0, 0, 0); // > 20 * 1.5
        let reason = judge(&inc, &can, &cfg()).unwrap();
        assert!(reason.contains("p99"), "{reason}");
    }

    #[test]
    fn judge_ignores_sub_floor_noise() {
        // 0.4 ms vs 0.1 ms is a 4x ratio but both are under the 5 ms
        // floor — noise, not a regression.
        let inc = summary(100, 0.1, 0, 0);
        let can = summary(50, 0.4, 0, 0);
        assert_eq!(judge(&inc, &can, &cfg()), None);
    }

    #[test]
    fn judge_rolls_back_on_failovers_and_sheds() {
        let inc = summary(100, 20.0, 0, 0);
        let failing = summary(50, 20.0, 0, 1);
        assert!(judge(&inc, &failing, &cfg())
            .unwrap()
            .contains("failover"));
        let shedding = summary(50, 20.0, 25, 0); // 33% shed rate
        assert!(judge(&inc, &shedding, &cfg())
            .unwrap()
            .contains("shed"));
    }

    #[test]
    fn judge_requires_evidence_but_not_an_incumbent_baseline() {
        let inc = summary(100, 20.0, 0, 0);
        let sparse = summary(3, 1.0, 0, 0);
        assert!(judge(&inc, &sparse, &cfg())
            .unwrap()
            .contains("insufficient"));
        // Empty incumbent window (100% stage): no p99 baseline, only
        // the absolute gates apply.
        let empty_inc = summary(0, 0.0, 0, 0);
        let can = summary(50, 400.0, 0, 0);
        assert_eq!(judge(&empty_inc, &can, &cfg()), None);
    }
}
