//! Serving metrics: request counts, latency percentiles, batch sizes,
//! failovers. The coordinator keeps one global [`Metrics`] plus one per
//! deployment and one per backend, so a [`ServeReport`] can attribute
//! latency and load to the deployment/backend that actually served each
//! request — and each deployment's sink doubles as the SLA router's
//! live latency feed ([`Metrics::live_latency_ms`]).
//!
//! Memory is bounded under sustained traffic: latencies go into a
//! fixed-capacity uniform reservoir (Vitter's Algorithm R) instead of an
//! ever-growing `Vec`, and queue-wait / batch-size means are running
//! sums — a coordinator serving millions of requests holds a few KB of
//! metric state, and `summary()` sorts one bounded sample (once, for
//! every percentile) rather than re-sorting the full request history.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::rng::Rng;
use crate::util::stats;

/// Latency sample capacity. 4096 points give sub-millisecond-stable
/// p50/p99 estimates while capping summary() work and resident memory.
const LATENCY_RESERVOIR: usize = 4096;

/// Fixed-capacity uniform sample over an unbounded stream (Algorithm R):
/// after `seen` pushes every value has had probability cap/seen of being
/// in the sample. Deterministic via the library RNG.
struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng: Rng,
}

impl Reservoir {
    fn new(cap: usize, seed: u64) -> Reservoir {
        Reservoir {
            cap: cap.max(1),
            seen: 0,
            samples: Vec::new(),
            rng: Rng::seed_from(seed),
        }
    }

    fn push(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            // Replace a random slot with probability cap/seen.
            let j = (self.rng.f64() * self.seen as f64) as u64;
            if (j as usize) < self.cap {
                self.samples[j as usize] = v;
            }
        }
    }

    /// Percentiles (p in [0,100]) from ONE sort of the bounded sample.
    fn percentiles<const N: usize>(&self, ps: [f64; N]) -> [f64; N] {
        if self.samples.is_empty() {
            return [0.0; N];
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.map(|p| stats::percentile_sorted(&v, p))
    }
}

/// Smoothing factor of the live latency estimate: each new sample moves
/// the estimate by 1/16 of the gap. Small enough to ride out per-batch
/// noise, large enough that a deployment whose service time shifts is
/// re-classified by the SLA router within a few dozen requests — an
/// all-time mean would move as 1/N and pin admission decisions to
/// history.
const LATENCY_EWMA_ALPHA: f64 = 1.0 / 16.0;

/// One measurement window: the same counters/reservoir as the lifetime
/// view, but resettable. The canary controller compares incumbent and
/// canary over the *same* observation window, so a version's p99 is
/// never polluted by its predecessor's (or its own warm-up) samples.
struct Window {
    latencies_s: Reservoir,
    latency_ewma_s: Option<f64>,
    queue_wait_sum_s: f64,
    batch_size_sum: f64,
    completed: u64,
    rejected: u64,
    failovers: u64,
    shed: u64,
    queue_depth_max: usize,
}

impl Window {
    fn fresh(epoch: u64) -> Window {
        Window {
            // Epoch-salted seed keeps windows deterministic yet
            // decorrelated from each other and the lifetime reservoir.
            latencies_s: Reservoir::new(
                LATENCY_RESERVOIR,
                0x4C41_54 ^ epoch.wrapping_mul(0x9E37_79B9),
            ),
            latency_ewma_s: None,
            queue_wait_sum_s: 0.0,
            batch_size_sum: 0.0,
            completed: 0,
            rejected: 0,
            failovers: 0,
            shed: 0,
            queue_depth_max: 0,
        }
    }
}

struct Inner {
    latencies_s: Reservoir,
    /// Exponentially decayed mean latency (s); `None` until the first
    /// completion.
    latency_ewma_s: Option<f64>,
    queue_wait_sum_s: f64,
    batch_size_sum: f64,
    completed: u64,
    rejected: u64,
    failovers: u64,
    shed: u64,
    queue_depth: usize,
    queue_depth_max: usize,
    /// Bumped by [`Metrics::reset_window`]; tags which observation
    /// window the `window` state belongs to.
    epoch: u64,
    window: Window,
}

impl Default for Inner {
    fn default() -> Inner {
        Inner {
            latencies_s: Reservoir::new(LATENCY_RESERVOIR, 0x4C41_54),
            latency_ewma_s: None,
            queue_wait_sum_s: 0.0,
            batch_size_sum: 0.0,
            completed: 0,
            rejected: 0,
            failovers: 0,
            shed: 0,
            queue_depth: 0,
            queue_depth_max: 0,
            epoch: 0,
            window: Window::fresh(0),
        }
    }
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time summary.
#[derive(Debug, Clone)]
pub struct Summary {
    pub completed: u64,
    pub rejected: u64,
    /// Requests re-routed to another backend after an infer failure.
    pub failovers: u64,
    /// Requests turned away at admission (`ServeError::Overloaded`).
    /// Shed requests never enter the latency reservoir or the decayed
    /// mean — admission decisions stay pinned to *served* latency.
    pub shed: u64,
    /// Intake queue depth at the last gauge update.
    pub queue_depth: usize,
    /// High-water intake queue depth over the sink's lifetime.
    pub queue_depth_max: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_queue_ms: f64,
    pub mean_batch: f64,
}

/// One backend's share of a deployment's traffic.
#[derive(Debug, Clone)]
pub struct BackendReport {
    pub name: Arc<str>,
    pub summary: Summary,
}

/// One named deployment's view: its aggregate summary plus the
/// per-backend breakdown, in backend declaration order.
#[derive(Debug, Clone)]
pub struct DeploymentReport {
    pub name: Arc<str>,
    pub summary: Summary,
    pub backends: Vec<BackendReport>,
}

/// Shutdown report: the aggregate view plus one report per registered
/// deployment, in registration order.
///
/// `overall.rejected` can exceed the per-deployment sum: requests the
/// leader rejects before resolving a deployment (no admissible SLA
/// variant, or a submission racing past shutdown) are counted globally
/// only. Rejections of *resolved* requests — exhausted failover, every
/// worker thread of the deployment gone — count in that deployment's
/// summary too.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub overall: Summary,
    pub deployments: Vec<DeploymentReport>,
}

impl ServeReport {
    /// The report for one named deployment, if registered.
    pub fn deployment(&self, name: &str) -> Option<&DeploymentReport> {
        self.deployments.iter().find(|d| &*d.name == name)
    }

    /// Every backend summary across all deployments, flattened in
    /// (deployment, backend) declaration order.
    pub fn backends(&self) -> Vec<(Arc<str>, Summary)> {
        self.deployments
            .iter()
            .flat_map(|d| {
                d.backends
                    .iter()
                    .map(|b| (b.name.clone(), b.summary.clone()))
            })
            .collect()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&self, total: Duration, queue_wait: Duration,
                  batch_size: usize) {
        let mut g = self.inner.lock().unwrap();
        let s = total.as_secs_f64();
        g.latencies_s.push(s);
        g.latency_ewma_s = Some(match g.latency_ewma_s {
            None => s,
            Some(e) => e + LATENCY_EWMA_ALPHA * (s - e),
        });
        g.queue_wait_sum_s += queue_wait.as_secs_f64();
        g.batch_size_sum += batch_size as f64;
        g.completed += 1;
        let w = &mut g.window;
        w.latencies_s.push(s);
        w.latency_ewma_s = Some(match w.latency_ewma_s {
            None => s,
            Some(e) => e + LATENCY_EWMA_ALPHA * (s - e),
        });
        w.queue_wait_sum_s += queue_wait.as_secs_f64();
        w.batch_size_sum += batch_size as f64;
        w.completed += 1;
    }

    /// The live end-to-end latency operating point, in ms: an
    /// exponentially decayed mean (`LATENCY_EWMA_ALPHA`), so the SLA
    /// router's admission decisions track a deployment that speeds up
    /// or degrades instead of being pinned to its all-time history.
    /// `None` until the first completion, so callers can fall back to a
    /// measured prior.
    pub fn live_latency_ms(&self) -> Option<f64> {
        self.inner.lock().unwrap().latency_ewma_s.map(|s| s * 1e3)
    }

    pub fn record_rejected(&self) {
        let mut g = self.inner.lock().unwrap();
        g.rejected += 1;
        g.window.rejected += 1;
    }

    /// One request handed to another backend after this one failed.
    pub fn record_failover(&self) {
        let mut g = self.inner.lock().unwrap();
        g.failovers += 1;
        g.window.failovers += 1;
    }

    /// One request turned away at admission. Deliberately touches only
    /// the `shed` counter: a shed request has no service latency, so it
    /// must not perturb the reservoir or the EWMA the SLA router reads.
    pub fn record_shed(&self) {
        let mut g = self.inner.lock().unwrap();
        g.shed += 1;
        g.window.shed += 1;
    }

    /// Update the intake-queue depth gauge (and its high-water mark).
    pub fn set_queue_depth(&self, depth: usize) {
        let mut g = self.inner.lock().unwrap();
        g.queue_depth = depth;
        g.queue_depth_max = g.queue_depth_max.max(depth);
        g.window.queue_depth_max = g.window.queue_depth_max.max(depth);
    }

    /// Start a fresh observation window: windowed counters, reservoir,
    /// and the windowed decayed mean all reset; the lifetime view is
    /// untouched. Bumps the window epoch. The canary controller calls
    /// this on incumbent and canary at each stage boundary so both are
    /// judged over the same interval, and a version's windowed p99 is
    /// never polluted by its predecessor's (or warm-up) samples.
    pub fn reset_window(&self) {
        let mut g = self.inner.lock().unwrap();
        g.epoch += 1;
        let epoch = g.epoch;
        g.window = Window::fresh(epoch);
    }

    /// The current window epoch ([`Metrics::reset_window`] count).
    pub fn window_epoch(&self) -> u64 {
        self.inner.lock().unwrap().epoch
    }

    /// Completions since the last [`Metrics::reset_window`] — the cheap
    /// poll the canary controller uses to wait for a minimum sample
    /// size before judging a stage.
    pub fn window_completed(&self) -> u64 {
        self.inner.lock().unwrap().window.completed
    }

    /// [`Summary`] over the current observation window only (since the
    /// last [`Metrics::reset_window`]). `queue_depth` is the live gauge
    /// (a gauge has no window); `queue_depth_max` is the high-water
    /// mark within the window.
    pub fn window_summary(&self) -> Summary {
        let g = self.inner.lock().unwrap();
        let w = &g.window;
        let [p50, p99] = w.latencies_s.percentiles([50.0, 99.0]);
        let denom = w.completed.max(1) as f64;
        Summary {
            completed: w.completed,
            rejected: w.rejected,
            failovers: w.failovers,
            shed: w.shed,
            queue_depth: g.queue_depth,
            queue_depth_max: w.queue_depth_max,
            p50_ms: p50 * 1e3,
            p99_ms: p99 * 1e3,
            mean_queue_ms: if w.completed == 0 {
                0.0
            } else {
                w.queue_wait_sum_s / denom * 1e3
            },
            mean_batch: if w.completed == 0 {
                0.0
            } else {
                w.batch_size_sum / denom
            },
        }
    }

    pub fn summary(&self) -> Summary {
        let g = self.inner.lock().unwrap();
        let [p50, p99] = g.latencies_s.percentiles([50.0, 99.0]);
        let denom = g.completed.max(1) as f64;
        Summary {
            completed: g.completed,
            rejected: g.rejected,
            failovers: g.failovers,
            shed: g.shed,
            queue_depth: g.queue_depth,
            queue_depth_max: g.queue_depth_max,
            p50_ms: p50 * 1e3,
            p99_ms: p99 * 1e3,
            mean_queue_ms: if g.completed == 0 {
                0.0
            } else {
                g.queue_wait_sum_s / denom * 1e3
            },
            mean_batch: if g.completed == 0 {
                0.0
            } else {
                g.batch_size_sum / denom
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record(
                Duration::from_millis(i),
                Duration::from_millis(i / 2),
                4,
            );
        }
        m.record_rejected();
        let s = m.summary();
        assert_eq!(s.completed, 100);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.failovers, 0);
        assert!((s.p50_ms - 50.5).abs() < 1.0);
        assert!(s.p99_ms > 98.0);
        assert_eq!(s.mean_batch, 4.0);
    }

    #[test]
    fn failovers_count_independently_of_completion() {
        let m = Metrics::new();
        m.record_failover();
        m.record_failover();
        m.record(Duration::from_millis(3), Duration::from_millis(1), 1);
        let s = m.summary();
        assert_eq!(s.failovers, 2);
        assert_eq!(s.completed, 1);
        assert_eq!(s.rejected, 0);
    }

    #[test]
    fn memory_stays_bounded_under_sustained_traffic() {
        let m = Metrics::new();
        let n = 200_000u64;
        for i in 0..n {
            // latencies uniform in [0, 100) ms
            m.record(
                Duration::from_micros((i % 100) * 1000),
                Duration::from_micros(500),
                8,
            );
        }
        {
            let g = m.inner.lock().unwrap();
            assert_eq!(g.latencies_s.seen, n);
            assert!(g.latencies_s.samples.len() <= LATENCY_RESERVOIR,
                    "reservoir grew past its cap: {}",
                    g.latencies_s.samples.len());
        }
        let s = m.summary();
        assert_eq!(s.completed, n);
        // means are exact (running sums over the full stream)
        assert!((s.mean_queue_ms - 0.5).abs() < 1e-6);
        assert_eq!(s.mean_batch, 8.0);
        // sampled percentiles track the true uniform distribution
        assert!((s.p50_ms - 50.0).abs() < 5.0, "p50 {}", s.p50_ms);
        assert!(s.p99_ms > 90.0, "p99 {}", s.p99_ms);
    }

    #[test]
    fn live_latency_tracks_drift_and_is_absent_when_idle() {
        let m = Metrics::new();
        assert_eq!(m.live_latency_ms(), None,
                   "no traffic must yield no estimate");
        m.record(Duration::from_millis(10), Duration::ZERO, 1);
        let first = m.live_latency_ms().unwrap();
        assert!((first - 10.0).abs() < 1e-9,
                "first sample initializes the estimate: {first}");
        // A long fast history...
        for _ in 0..1000 {
            m.record(Duration::from_millis(2), Duration::ZERO, 1);
        }
        assert!(m.live_latency_ms().unwrap() < 3.0);
        // ...must not pin the estimate once the deployment degrades:
        // within a few dozen slow requests the router-visible point has
        // moved to the new regime (an all-time mean would still read
        // ~2.5 ms here).
        for _ in 0..64 {
            m.record(Duration::from_millis(50), Duration::ZERO, 1);
        }
        let drifted = m.live_latency_ms().unwrap();
        assert!(drifted > 40.0, "estimate stuck at {drifted} ms");
    }

    #[test]
    fn shed_requests_never_contaminate_latency_state() {
        let m = Metrics::new();
        for _ in 0..1000 {
            m.record_shed();
        }
        // No latency state may exist: the reservoir is untouched, the
        // decayed mean is still absent, and the router would fall back
        // to the deployment's measured prior.
        assert_eq!(m.live_latency_ms(), None,
                   "sheds must not seed the EWMA");
        {
            let g = m.inner.lock().unwrap();
            assert_eq!(g.latencies_s.seen, 0);
            assert!(g.latencies_s.samples.is_empty());
        }
        let s = m.summary();
        assert_eq!(s.shed, 1000);
        assert_eq!(s.completed, 0);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.p50_ms, 0.0);
        assert_eq!(s.p99_ms, 0.0);
        // And sheds interleaved with real completions leave the served
        // latency estimate exactly where completions alone put it.
        m.record(Duration::from_millis(8), Duration::ZERO, 1);
        let before = m.live_latency_ms().unwrap();
        for _ in 0..100 {
            m.record_shed();
        }
        assert_eq!(m.live_latency_ms().unwrap(), before);
    }

    #[test]
    fn queue_depth_gauge_tracks_current_and_high_water() {
        let m = Metrics::new();
        assert_eq!(m.summary().queue_depth, 0);
        assert_eq!(m.summary().queue_depth_max, 0);
        m.set_queue_depth(3);
        m.set_queue_depth(9);
        m.set_queue_depth(2);
        let s = m.summary();
        assert_eq!(s.queue_depth, 2, "gauge reads the last update");
        assert_eq!(s.queue_depth_max, 9, "high-water mark sticks");
    }

    #[test]
    fn window_reset_forgets_predecessor_latency() {
        let m = Metrics::new();
        // A slow "predecessor" era.
        for _ in 0..200 {
            m.record(Duration::from_millis(80), Duration::ZERO, 1);
        }
        assert!(m.window_summary().p99_ms > 70.0);
        assert_eq!(m.window_epoch(), 0);
        m.reset_window();
        assert_eq!(m.window_epoch(), 1);
        assert_eq!(m.window_completed(), 0);
        // An empty window reports zero latency, not the old era's.
        assert_eq!(m.window_summary().p99_ms, 0.0);
        // The fast successor era: its windowed p99 must reflect only
        // its own samples, while the lifetime view still remembers the
        // slow history.
        for _ in 0..200 {
            m.record(Duration::from_millis(3), Duration::ZERO, 2);
        }
        let w = m.window_summary();
        assert_eq!(w.completed, 200);
        assert!(w.p99_ms < 10.0, "windowed p99 polluted: {}", w.p99_ms);
        assert_eq!(w.mean_batch, 2.0);
        let life = m.summary();
        assert_eq!(life.completed, 400);
        assert!(life.p99_ms > 70.0, "lifetime view must keep history");
    }

    #[test]
    fn window_counters_reset_independently_of_lifetime() {
        let m = Metrics::new();
        m.record_shed();
        m.record_failover();
        m.record_rejected();
        m.set_queue_depth(7);
        m.set_queue_depth(0);
        let w = m.window_summary();
        assert_eq!((w.shed, w.failovers, w.rejected), (1, 1, 1));
        assert_eq!(w.queue_depth_max, 7);
        m.reset_window();
        let w = m.window_summary();
        assert_eq!((w.shed, w.failovers, w.rejected), (0, 0, 0));
        assert_eq!(w.queue_depth_max, 0,
                   "window high-water must restart");
        let life = m.summary();
        assert_eq!((life.shed, life.failovers, life.rejected), (1, 1, 1));
        assert_eq!(life.queue_depth_max, 7);
    }

    #[test]
    fn reservoir_is_exact_below_capacity() {
        let mut r = Reservoir::new(16, 1);
        for i in 0..10 {
            r.push(i as f64);
        }
        assert_eq!(r.samples.len(), 10);
        let [p0, p100] = r.percentiles([0.0, 100.0]);
        assert_eq!(p0, 0.0);
        assert_eq!(p100, 9.0);
    }
}
