//! Serving metrics: request counts, latency percentiles, batch sizes,
//! failovers. The coordinator keeps one global [`Metrics`] plus one per
//! backend, so a [`ServeReport`] can attribute latency and load to the
//! backend that actually served each request.
//!
//! Memory is bounded under sustained traffic: latencies go into a
//! fixed-capacity uniform reservoir (Vitter's Algorithm R) instead of an
//! ever-growing `Vec`, and queue-wait / batch-size means are running
//! sums — a coordinator serving millions of requests holds a few KB of
//! metric state, and `summary()` sorts one bounded sample (once, for
//! every percentile) rather than re-sorting the full request history.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::rng::Rng;
use crate::util::stats;

/// Latency sample capacity. 4096 points give sub-millisecond-stable
/// p50/p99 estimates while capping summary() work and resident memory.
const LATENCY_RESERVOIR: usize = 4096;

/// Fixed-capacity uniform sample over an unbounded stream (Algorithm R):
/// after `seen` pushes every value has had probability cap/seen of being
/// in the sample. Deterministic via the library RNG.
struct Reservoir {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
    rng: Rng,
}

impl Reservoir {
    fn new(cap: usize, seed: u64) -> Reservoir {
        Reservoir {
            cap: cap.max(1),
            seen: 0,
            samples: Vec::new(),
            rng: Rng::seed_from(seed),
        }
    }

    fn push(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            // Replace a random slot with probability cap/seen.
            let j = (self.rng.f64() * self.seen as f64) as u64;
            if (j as usize) < self.cap {
                self.samples[j as usize] = v;
            }
        }
    }

    /// Percentiles (p in [0,100]) from ONE sort of the bounded sample.
    fn percentiles<const N: usize>(&self, ps: [f64; N]) -> [f64; N] {
        if self.samples.is_empty() {
            return [0.0; N];
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.map(|p| stats::percentile_sorted(&v, p))
    }
}

struct Inner {
    latencies_s: Reservoir,
    queue_wait_sum_s: f64,
    batch_size_sum: f64,
    completed: u64,
    rejected: u64,
    failovers: u64,
}

impl Default for Inner {
    fn default() -> Inner {
        Inner {
            latencies_s: Reservoir::new(LATENCY_RESERVOIR, 0x4C41_54),
            queue_wait_sum_s: 0.0,
            batch_size_sum: 0.0,
            completed: 0,
            rejected: 0,
            failovers: 0,
        }
    }
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time summary.
#[derive(Debug, Clone)]
pub struct Summary {
    pub completed: u64,
    pub rejected: u64,
    /// Requests re-routed to another backend after an infer failure.
    pub failovers: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_queue_ms: f64,
    pub mean_batch: f64,
}

/// Shutdown report: the aggregate view plus one summary per backend, in
/// backend declaration order.
///
/// `overall.rejected` can exceed the per-backend sum: requests the
/// leader rejects before any backend accepted them (every worker
/// thread gone) are counted globally only, since no backend served or
/// failed them.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub overall: Summary,
    pub per_backend: Vec<(String, Summary)>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&self, total: Duration, queue_wait: Duration,
                  batch_size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.latencies_s.push(total.as_secs_f64());
        g.queue_wait_sum_s += queue_wait.as_secs_f64();
        g.batch_size_sum += batch_size as f64;
        g.completed += 1;
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// One request handed to another backend after this one failed.
    pub fn record_failover(&self) {
        self.inner.lock().unwrap().failovers += 1;
    }

    pub fn summary(&self) -> Summary {
        let g = self.inner.lock().unwrap();
        let [p50, p99] = g.latencies_s.percentiles([50.0, 99.0]);
        let denom = g.completed.max(1) as f64;
        Summary {
            completed: g.completed,
            rejected: g.rejected,
            failovers: g.failovers,
            p50_ms: p50 * 1e3,
            p99_ms: p99 * 1e3,
            mean_queue_ms: if g.completed == 0 {
                0.0
            } else {
                g.queue_wait_sum_s / denom * 1e3
            },
            mean_batch: if g.completed == 0 {
                0.0
            } else {
                g.batch_size_sum / denom
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record(
                Duration::from_millis(i),
                Duration::from_millis(i / 2),
                4,
            );
        }
        m.record_rejected();
        let s = m.summary();
        assert_eq!(s.completed, 100);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.failovers, 0);
        assert!((s.p50_ms - 50.5).abs() < 1.0);
        assert!(s.p99_ms > 98.0);
        assert_eq!(s.mean_batch, 4.0);
    }

    #[test]
    fn failovers_count_independently_of_completion() {
        let m = Metrics::new();
        m.record_failover();
        m.record_failover();
        m.record(Duration::from_millis(3), Duration::from_millis(1), 1);
        let s = m.summary();
        assert_eq!(s.failovers, 2);
        assert_eq!(s.completed, 1);
        assert_eq!(s.rejected, 0);
    }

    #[test]
    fn memory_stays_bounded_under_sustained_traffic() {
        let m = Metrics::new();
        let n = 200_000u64;
        for i in 0..n {
            // latencies uniform in [0, 100) ms
            m.record(
                Duration::from_micros((i % 100) * 1000),
                Duration::from_micros(500),
                8,
            );
        }
        {
            let g = m.inner.lock().unwrap();
            assert_eq!(g.latencies_s.seen, n);
            assert!(g.latencies_s.samples.len() <= LATENCY_RESERVOIR,
                    "reservoir grew past its cap: {}",
                    g.latencies_s.samples.len());
        }
        let s = m.summary();
        assert_eq!(s.completed, n);
        // means are exact (running sums over the full stream)
        assert!((s.mean_queue_ms - 0.5).abs() < 1e-6);
        assert_eq!(s.mean_batch, 8.0);
        // sampled percentiles track the true uniform distribution
        assert!((s.p50_ms - 50.0).abs() < 5.0, "p50 {}", s.p50_ms);
        assert!(s.p99_ms > 90.0, "p99 {}", s.p99_ms);
    }

    #[test]
    fn reservoir_is_exact_below_capacity() {
        let mut r = Reservoir::new(16, 1);
        for i in 0..10 {
            r.push(i as f64);
        }
        assert_eq!(r.samples.len(), 10);
        let [p0, p100] = r.percentiles([0.0, 100.0]);
        assert_eq!(p0, 0.0);
        assert_eq!(p100, 9.0);
    }
}
