//! Serving metrics: request counts, latency percentiles, batch sizes.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats;

#[derive(Default)]
struct Inner {
    latencies_s: Vec<f64>,
    queue_waits_s: Vec<f64>,
    batch_sizes: Vec<f64>,
    completed: u64,
    rejected: u64,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time summary.
#[derive(Debug, Clone)]
pub struct Summary {
    pub completed: u64,
    pub rejected: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_queue_ms: f64,
    pub mean_batch: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&self, total: Duration, queue_wait: Duration,
                  batch_size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.latencies_s.push(total.as_secs_f64());
        g.queue_waits_s.push(queue_wait.as_secs_f64());
        g.batch_sizes.push(batch_size as f64);
        g.completed += 1;
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn summary(&self) -> Summary {
        let g = self.inner.lock().unwrap();
        Summary {
            completed: g.completed,
            rejected: g.rejected,
            p50_ms: stats::percentile(&g.latencies_s, 50.0) * 1e3,
            p99_ms: stats::percentile(&g.latencies_s, 99.0) * 1e3,
            mean_queue_ms: stats::mean(&g.queue_waits_s) * 1e3,
            mean_batch: stats::mean(&g.batch_sizes),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record(
                Duration::from_millis(i),
                Duration::from_millis(i / 2),
                4,
            );
        }
        m.record_rejected();
        let s = m.summary();
        assert_eq!(s.completed, 100);
        assert_eq!(s.rejected, 1);
        assert!((s.p50_ms - 50.5).abs() < 1.0);
        assert!(s.p99_ms > 98.0);
        assert_eq!(s.mean_batch, 4.0);
    }
}
