//! Serving metrics: request counts, latency percentiles, batch sizes,
//! failovers. The coordinator keeps one global [`Metrics`] plus one per
//! backend, so a [`ServeReport`] can attribute latency and load to the
//! backend that actually served each request.

use std::sync::Mutex;
use std::time::Duration;

use crate::util::stats;

#[derive(Default)]
struct Inner {
    latencies_s: Vec<f64>,
    queue_waits_s: Vec<f64>,
    batch_sizes: Vec<f64>,
    completed: u64,
    rejected: u64,
    failovers: u64,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time summary.
#[derive(Debug, Clone)]
pub struct Summary {
    pub completed: u64,
    pub rejected: u64,
    /// Requests re-routed to another backend after an infer failure.
    pub failovers: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_queue_ms: f64,
    pub mean_batch: f64,
}

/// Shutdown report: the aggregate view plus one summary per backend, in
/// backend declaration order.
///
/// `overall.rejected` can exceed the per-backend sum: requests the
/// leader rejects before any backend accepted them (every worker
/// thread gone) are counted globally only, since no backend served or
/// failed them.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub overall: Summary,
    pub per_backend: Vec<(String, Summary)>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&self, total: Duration, queue_wait: Duration,
                  batch_size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.latencies_s.push(total.as_secs_f64());
        g.queue_waits_s.push(queue_wait.as_secs_f64());
        g.batch_sizes.push(batch_size as f64);
        g.completed += 1;
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// One request handed to another backend after this one failed.
    pub fn record_failover(&self) {
        self.inner.lock().unwrap().failovers += 1;
    }

    pub fn summary(&self) -> Summary {
        let g = self.inner.lock().unwrap();
        Summary {
            completed: g.completed,
            rejected: g.rejected,
            failovers: g.failovers,
            p50_ms: stats::percentile(&g.latencies_s, 50.0) * 1e3,
            p99_ms: stats::percentile(&g.latencies_s, 99.0) * 1e3,
            mean_queue_ms: stats::mean(&g.queue_waits_s) * 1e3,
            mean_batch: stats::mean(&g.batch_sizes),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record(
                Duration::from_millis(i),
                Duration::from_millis(i / 2),
                4,
            );
        }
        m.record_rejected();
        let s = m.summary();
        assert_eq!(s.completed, 100);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.failovers, 0);
        assert!((s.p50_ms - 50.5).abs() < 1.0);
        assert!(s.p99_ms > 98.0);
        assert_eq!(s.mean_batch, 4.0);
    }

    #[test]
    fn failovers_count_independently_of_completion() {
        let m = Metrics::new();
        m.record_failover();
        m.record_failover();
        m.record(Duration::from_millis(3), Duration::from_millis(1), 1);
        let s = m.summary();
        assert_eq!(s.failovers, 2);
        assert_eq!(s.completed, 1);
        assert_eq!(s.rejected, 0);
    }
}
