//! Serving coordinator — the L3 request path, multi-backend edition.
//!
//! A leader thread owns the dynamic batcher and the batch router; each
//! [`Backend`] (PJRT runtime, native executor pool, ...) lives on its own
//! worker thread, which compiles the model during startup and then
//! executes the batches routed to it. Clients submit images over
//! channels and receive [`Prediction`]s; Python is never on this path.
//!
//! ```text
//!  Client::submit ──► leader: batcher ──► BatchRouter ──┬─► worker[0]: Backend (pjrt)
//!                        ▲                              └─► worker[1]: Backend (native pool)
//!                        │         failover retry                 │
//!                        └────────────────────────────────────────┘
//! ```
//!
//! Failure handling: a worker whose `infer_batch` errors logs the
//! cause, puts its backend into a routing cooldown (a half-open circuit
//! breaker, not a permanent removal), and hands the batch back to the
//! leader, which re-routes it to the next healthy backend (counted in
//! `Summary::failovers`). A request that has failed on every backend is
//! rejected — its reply channel drops, so the client sees a recv error.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod router;

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::runtime::{HostTensor, Runtime};
pub use backend::{Backend, ModelSignature, NativeBackend,
                  NativeBatchMode, PjrtBackend};
pub use batcher::{BatchPolicy, BatchStep};
pub use metrics::{Metrics, ServeReport, Summary};
pub use router::{BackendState, BatchRouter, RouterPolicy};

/// A classification request: one NHWC image (flattened) + reply channel.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Prediction>,
    /// Bitmask of backend indices that have failed this request — the
    /// exhaustion test ("failed on every backend") uses this, so a
    /// degraded-mode re-pick of the same backend doesn't burn a
    /// distinct-backend credit.
    failed: u64,
    /// Total failover hops; a hard bound that guarantees termination
    /// even when routing can only reach already-failed backends (e.g.
    /// the others' worker threads are gone).
    tries: usize,
}

/// The response.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub class: usize,
    pub score: f32,
    pub latency_ms: f64,
    /// Name of the backend that served this request.
    pub backend: String,
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    image_elems: usize,
}

impl Client {
    /// Submit an image; returns the receiver for the prediction.
    pub fn submit(&self, image: Vec<f32>) -> Result<Receiver<Prediction>> {
        anyhow::ensure!(
            image.len() == self.image_elems,
            "image has {} elements, model wants {}",
            image.len(),
            self.image_elems
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request {
                image,
                enqueued: Instant::now(),
                reply: rtx,
                failed: 0,
                tries: 0,
            })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }
}

/// Serving options for the PJRT path (see [`Coordinator::start`]).
#[derive(Clone)]
pub struct ServeConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub policy: BatchPolicy,
    /// Explicit parameter tensors (trained weights); deterministic-random
    /// init when None.
    pub params: Option<Vec<HostTensor>>,
}

impl ServeConfig {
    pub fn new(model: &str) -> ServeConfig {
        ServeConfig {
            artifacts_dir: Runtime::default_dir(),
            model: model.to_string(),
            policy: BatchPolicy::default(),
            params: None,
        }
    }
}

/// A batch of requests dispatched to one backend worker.
struct Job {
    reqs: Vec<Request>,
}

/// The serving coordinator for one model (one or more backends).
pub struct Coordinator {
    client: Client,
    /// Aggregate metrics across all backends.
    pub metrics: Arc<Metrics>,
    backend_metrics: Vec<(String, Arc<Metrics>)>,
    leader: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start serving `cfg.model` on the PJRT runtime alone — the
    /// pre-`Backend`-seam entry point, kept for callers that only want
    /// the AOT path. Equivalent to [`Coordinator::start_with`] over one
    /// [`PjrtBackend`].
    pub fn start(cfg: ServeConfig) -> Result<Coordinator> {
        let policy = cfg.policy;
        Coordinator::start_with(
            vec![Box::new(PjrtBackend::new(cfg))],
            policy,
            RouterPolicy::Failover,
        )
    }

    /// Start serving across `backends` under `policy`, routing each
    /// formed batch per `router`. Blocks until every backend has
    /// compiled on its worker thread; fails if any compile fails or the
    /// backends disagree on the model signature.
    pub fn start_with(backends: Vec<Box<dyn Backend>>, policy: BatchPolicy,
                      router: RouterPolicy) -> Result<Coordinator> {
        ensure!(!backends.is_empty(), "need at least one backend");
        ensure!(
            backends.len() <= 64,
            "at most 64 backends (failed-backend tracking is a u64 \
             bitmask)"
        );
        ensure!(policy.max_batch > 0, "max_batch must be positive");
        let n_backends = backends.len();
        let global = Arc::new(Metrics::new());
        let pending = Arc::new(AtomicUsize::new(0));
        let (retry_tx, retry_rx) = mpsc::channel::<Vec<Request>>();

        // Spawn every worker first so the backends compile in parallel,
        // then collect their signatures: startup costs the slowest
        // compile, not the sum.
        let mut init_rxs = Vec::with_capacity(n_backends);
        let mut job_txs = Vec::with_capacity(n_backends);
        let mut states = Vec::with_capacity(n_backends);
        let mut backend_metrics = Vec::with_capacity(n_backends);
        let mut workers = Vec::with_capacity(n_backends);
        for (index, be) in backends.into_iter().enumerate() {
            let name = be.name().to_string();
            let state = BackendState::new(&name);
            let bm = Arc::new(Metrics::new());
            let (job_tx, job_rx) = mpsc::channel::<Job>();
            let (init_tx, init_rx) =
                mpsc::channel::<Result<ModelSignature>>();
            let ctx = WorkerCtx {
                index,
                max_batch: policy.max_batch,
                jobs: job_rx,
                init_tx,
                state: state.clone(),
                metrics: bm.clone(),
                global: global.clone(),
                retry: retry_tx.clone(),
                pending: pending.clone(),
                n_backends,
            };
            let handle = std::thread::spawn(move || backend_worker(be, ctx));
            init_rxs.push((name.clone(), init_rx));
            job_txs.push(job_tx);
            states.push(state);
            backend_metrics.push((name, bm));
            workers.push(handle);
        }
        // Only workers hold retry senders from here on, so the retry
        // channel drains exactly when the workers are done.
        drop(retry_tx);

        let mut sigs: Vec<ModelSignature> = Vec::with_capacity(n_backends);
        for (name, init_rx) in init_rxs {
            let sig = init_rx
                .recv()
                .map_err(|_| anyhow!("backend '{name}' died during \
                                      compile"))??;
            sigs.push(sig);
        }

        for (i, sig) in sigs.iter().enumerate().skip(1) {
            ensure!(
                *sig == sigs[0],
                "backend '{}' signature {:?} disagrees with '{}' ({:?})",
                backend_metrics[i].0,
                sig,
                backend_metrics[0].0,
                sigs[0]
            );
        }
        let image_elems = sigs[0].image_elems();

        let router = BatchRouter::new(router, n_backends)?;
        let (tx, rx) = mpsc::channel::<Request>();
        let ctx = LeaderCtx {
            rx,
            retry_rx,
            jobs: job_txs,
            states,
            router,
            policy,
            global: global.clone(),
            pending,
            workers,
        };
        let leader = std::thread::spawn(move || leader_main(ctx));
        Ok(Coordinator {
            client: Client { tx, image_elems },
            metrics: global,
            backend_metrics,
            leader: Some(leader),
        })
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Submit an image through the coordinator's own client handle;
    /// returns the receiver for the prediction.
    ///
    /// ```
    /// use cocopie::codegen::{build_plan, PruneConfig, Scheme};
    /// use cocopie::coordinator::{
    ///     BatchPolicy, Coordinator, NativeBackend, RouterPolicy,
    /// };
    /// use cocopie::ir::{Chw, IrBuilder};
    ///
    /// let mut b = IrBuilder::new("doc", Chw::new(3, 8, 8));
    /// b.conv("c1", 3, 4, 1, true).gap("g").dense("fc", 3, false);
    /// let plan = build_plan(&b.build().unwrap(), Scheme::CocoGen,
    ///                       PruneConfig::default(), 7)
    ///     .into_shared();
    /// let coord = Coordinator::start_with(
    ///     vec![Box::new(NativeBackend::new("native", plan))],
    ///     BatchPolicy::default(),
    ///     RouterPolicy::Failover,
    /// )
    /// .unwrap();
    /// let pred = coord.submit(vec![0.5; 8 * 8 * 3]).unwrap()
    ///     .recv().unwrap();
    /// assert!(pred.class < 3);
    /// assert_eq!(pred.backend, "native");
    /// coord.shutdown();
    /// ```
    pub fn submit(&self, image: Vec<f32>) -> Result<Receiver<Prediction>> {
        self.client.submit(image)
    }

    /// Stop accepting requests and join the workers. All outstanding
    /// Client clones must be dropped first, or this blocks until they
    /// are. Returns the aggregate summary; use
    /// [`Coordinator::shutdown_report`] for the per-backend view.
    pub fn shutdown(self) -> Summary {
        self.shutdown_report().overall
    }

    /// Like [`Coordinator::shutdown`], with per-backend summaries.
    pub fn shutdown_report(mut self) -> ServeReport {
        drop(self.client);
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
        ServeReport {
            overall: self.metrics.summary(),
            per_backend: self
                .backend_metrics
                .iter()
                .map(|(n, m)| (n.clone(), m.summary()))
                .collect(),
        }
    }
}

/// Everything a backend worker thread owns besides the backend itself.
struct WorkerCtx {
    /// This backend's index (bit position in `Request::failed`).
    index: usize,
    max_batch: usize,
    jobs: Receiver<Job>,
    init_tx: Sender<Result<ModelSignature>>,
    state: Arc<BackendState>,
    metrics: Arc<Metrics>,
    global: Arc<Metrics>,
    retry: Sender<Vec<Request>>,
    pending: Arc<AtomicUsize>,
    n_backends: usize,
}

fn backend_worker(mut be: Box<dyn Backend>, ctx: WorkerCtx) {
    // Compile on this thread: PJRT handles are thread-affine.
    let sig = match be.compile(ctx.max_batch) {
        Ok(s) => {
            let _ = ctx.init_tx.send(Ok(s.clone()));
            s
        }
        Err(e) => {
            let _ = ctx.init_tx.send(Err(e));
            return;
        }
    };
    let (h, w, c) =
        (sig.input_shape[0], sig.input_shape[1], sig.input_shape[2]);
    let elems = sig.image_elems();
    let classes = sig.classes;
    let name = be.name().to_string();
    while let Ok(mut job) = ctx.jobs.recv() {
        let t0 = Instant::now();
        let n = job.reqs.len();
        let mut x = vec![0f32; n * elems];
        for (i, r) in job.reqs.iter().enumerate() {
            x[i * elems..(i + 1) * elems].copy_from_slice(&r.image);
        }
        let images = HostTensor::f32(&[n, h, w, c], x);
        // `Backend` is a public extension seam: a panicking
        // `infer_batch` must become a failed batch (failover path), not
        // a dead worker thread — a dead worker would leak the batch's
        // `pending` count and hang shutdown.
        let infer = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| be.infer_batch(&images)),
        );
        // Either a validated logits row per request, or the reason this
        // batch failed (kept for the operator: metrics alone can't say
        // *why* a backend started failing over).
        let failure: Option<String> = match infer {
            Err(_) => Some("infer_batch panicked".to_string()),
            Ok(Err(e)) => Some(format!("{e:#}")),
            Ok(Ok(t)) => match t.as_f32() {
                Err(e) => Some(format!("{e:#}")),
                Ok(lv) if lv.len() < n * classes => Some(format!(
                    "returned {} logits for {n} images x {classes} classes",
                    lv.len()
                )),
                Ok(lv) => {
                    let done = Instant::now();
                    for (i, r) in job.reqs.drain(..).enumerate() {
                        let row = &lv[i * classes..(i + 1) * classes];
                        // total_cmp: a NaN logit must not panic the
                        // worker (a panic here would leak `pending` and
                        // hang shutdown).
                        let (class, score) = row
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.total_cmp(b.1))
                            .map(|(cl, s)| (cl, *s))
                            .unwrap();
                        let total = done - r.enqueued;
                        ctx.metrics.record(total, t0 - r.enqueued, n);
                        ctx.global.record(total, t0 - r.enqueued, n);
                        let _ = r.reply.send(Prediction {
                            class,
                            score,
                            latency_ms: total.as_secs_f64() * 1e3,
                            backend: name.clone(),
                        });
                    }
                    ctx.pending.fetch_sub(n, Ordering::SeqCst);
                    None
                }
            },
        };
        if let Some(err) = failure {
            eprintln!(
                "coordinator: backend '{name}' failed a batch of {n}: {err}"
            );
            // Cool this backend down; requests that still have untried
            // backends go back to the leader.
            ctx.state.mark_unhealthy();
            let all_failed = if ctx.n_backends >= 64 {
                u64::MAX
            } else {
                (1u64 << ctx.n_backends) - 1
            };
            let mut forward = Vec::new();
            let mut exhausted = 0usize;
            for mut r in job.reqs.drain(..) {
                r.failed |= 1u64 << ctx.index;
                r.tries += 1;
                // Rejected once it failed on every backend — or, as a
                // termination bound when routing can only reach
                // already-failed backends (the others' threads are
                // gone), after 2x n_backends hops.
                if r.failed == all_failed || r.tries >= 2 * ctx.n_backends {
                    exhausted += 1;
                    ctx.metrics.record_rejected();
                    ctx.global.record_rejected();
                } else {
                    ctx.metrics.record_failover();
                    ctx.global.record_failover();
                    forward.push(r);
                }
            }
            ctx.pending.fetch_sub(exhausted, Ordering::SeqCst);
            if !forward.is_empty() {
                let fwd_len = forward.len();
                if ctx.retry.send(forward).is_err() {
                    // Leader already gone; nothing can serve these.
                    for _ in 0..fwd_len {
                        ctx.metrics.record_rejected();
                        ctx.global.record_rejected();
                    }
                    ctx.pending.fetch_sub(fwd_len, Ordering::SeqCst);
                }
            }
        }
        ctx.state.end();
    }
}

/// Everything the leader thread owns.
struct LeaderCtx {
    rx: Receiver<Request>,
    retry_rx: Receiver<Vec<Request>>,
    jobs: Vec<Sender<Job>>,
    states: Vec<Arc<BackendState>>,
    router: BatchRouter,
    policy: BatchPolicy,
    global: Arc<Metrics>,
    pending: Arc<AtomicUsize>,
    workers: Vec<JoinHandle<()>>,
}

fn leader_main(mut ctx: LeaderCtx) {
    // Short enough that failover retries are picked up promptly, long
    // enough that an idle coordinator barely wakes.
    let idle = Duration::from_millis(20);
    let mut open = true;
    while open || ctx.pending.load(Ordering::SeqCst) > 0 {
        while let Ok(reqs) = ctx.retry_rx.try_recv() {
            dispatch(&mut ctx, reqs);
        }
        if open {
            // The deadline anchors at each batch's first request's
            // *enqueue* time: time spent queued behind failover retries
            // counts against max_wait.
            match batcher::next_batch_step(&ctx.rx, &ctx.policy, idle,
                                           |r: &Request| r.enqueued) {
                BatchStep::Batch(batch) => {
                    ctx.pending.fetch_add(batch.len(), Ordering::SeqCst);
                    dispatch(&mut ctx, batch);
                }
                BatchStep::Idle => {}
                BatchStep::Closed => open = false,
            }
        } else {
            // Request channel closed: drain in-flight work + retries.
            if let Ok(reqs) = ctx.retry_rx.recv_timeout(idle) {
                dispatch(&mut ctx, reqs);
            }
        }
    }
    // Close the job channels so workers exit, then join them.
    ctx.jobs.clear();
    for h in ctx.workers.drain(..) {
        let _ = h.join();
    }
}

/// Route one batch (every request already counted in `pending`). The
/// router always yields a backend (degraded mode falls back to
/// unhealthy ones); rejection happens either in the worker once a
/// request has failed on every backend, or here when *every* worker
/// thread is gone.
fn dispatch(ctx: &mut LeaderCtx, reqs: Vec<Request>) {
    let mut first = ctx.router.pick(&ctx.states);
    // Backends every request in this batch has already failed on
    // (non-zero only for failover retries). Steering the retry away
    // from them is what makes "rejected only after failing on every
    // backend" hold even when the router is in degraded mode.
    let avoid: u64 = reqs.iter().fold(u64::MAX, |m, r| m & r.failed);
    if avoid & (1u64 << first) != 0 {
        let fresh = (0..ctx.jobs.len())
            .filter(|&k| avoid & (1u64 << k) == 0)
            .min_by_key(|&k| (!ctx.states[k].healthy(), k));
        if let Some(k) = fresh {
            first = k;
        }
    }
    let mut job = Job { reqs };
    ctx.states[first].begin();
    match ctx.jobs[first].send(job) {
        Ok(()) => return,
        Err(mpsc::SendError(j)) => {
            // This worker's thread is gone (panic) — not a request
            // failure. Cool it down and scan the others, healthy
            // first, before giving up on the batch.
            ctx.states[first].mark_unhealthy();
            ctx.states[first].end();
            job = j;
        }
    }
    let mut order: Vec<usize> =
        (0..ctx.jobs.len()).filter(|&k| k != first).collect();
    // Untried-by-this-batch first, then healthy, then declaration order.
    order.sort_by_key(|&k| {
        (avoid & (1u64 << k) != 0, !ctx.states[k].healthy())
    });
    for k in order {
        ctx.states[k].begin();
        match ctx.jobs[k].send(job) {
            Ok(()) => return,
            Err(mpsc::SendError(j)) => {
                ctx.states[k].mark_unhealthy();
                ctx.states[k].end();
                job = j;
            }
        }
    }
    reject(ctx, job.reqs);
}

fn reject(ctx: &LeaderCtx, reqs: Vec<Request>) {
    let n = reqs.len();
    for r in reqs {
        // Dropping the reply sender signals the client with a recv error.
        drop(r);
        ctx.global.record_rejected();
    }
    ctx.pending.fetch_sub(n, Ordering::SeqCst);
}
