//! Serving coordinator — the L3 request path. A leader thread owns the
//! dynamic batcher; the worker thread owns the PJRT runtime (xla handles
//! are thread-affine, so the worker creates its own client and compiles
//! the artifact during startup); clients submit images and receive
//! predictions over channels. Python is never on this path.

pub mod batcher;
pub mod metrics;
pub mod router;

use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::runtime::{HostTensor, Runtime};
pub use batcher::BatchPolicy;
pub use metrics::{Metrics, Summary};

/// A classification request: one NHWC image (flattened) + reply channel.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Prediction>,
}

/// The response.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub class: usize,
    pub score: f32,
    pub latency_ms: f64,
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    image_elems: usize,
}

impl Client {
    /// Submit an image; returns the receiver for the prediction.
    pub fn submit(&self, image: Vec<f32>) -> Result<Receiver<Prediction>> {
        anyhow::ensure!(
            image.len() == self.image_elems,
            "image has {} elements, model wants {}",
            image.len(),
            self.image_elems
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request {
                image,
                enqueued: Instant::now(),
                reply: rtx,
            })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }
}

/// Serving options.
#[derive(Clone)]
pub struct ServeConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub policy: BatchPolicy,
    /// Explicit parameter tensors (trained weights); deterministic-random
    /// init when None.
    pub params: Option<Vec<HostTensor>>,
}

impl ServeConfig {
    pub fn new(model: &str) -> ServeConfig {
        ServeConfig {
            artifacts_dir: Runtime::default_dir(),
            model: model.to_string(),
            policy: BatchPolicy::default(),
            params: None,
        }
    }
}

/// The serving coordinator for one model.
pub struct Coordinator {
    client: Client,
    pub metrics: Arc<Metrics>,
    worker: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the worker; blocks until its runtime is initialized and the
    /// `infer_b{max_batch}` artifact is compiled.
    pub fn start(cfg: ServeConfig) -> Result<Coordinator> {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) = mpsc::channel::<Result<usize>>();
        let m = metrics.clone();
        let worker = std::thread::spawn(move || {
            worker_main(cfg, rx, init_tx, m);
        });
        let image_elems = init_rx
            .recv()
            .map_err(|_| anyhow!("worker died during init"))??;
        Ok(Coordinator {
            client: Client { tx, image_elems },
            metrics,
            worker: Some(worker),
        })
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Stop accepting requests and join the worker. All outstanding
    /// Client clones must be dropped first, or this blocks until they
    /// are.
    pub fn shutdown(mut self) -> Summary {
        drop(self.client);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        self.metrics.summary()
    }
}

fn worker_main(cfg: ServeConfig, rx: Receiver<Request>,
               init_tx: Sender<Result<usize>>, m: Arc<Metrics>) {
    // Everything PJRT lives on this thread.
    let setup = (|| -> Result<_> {
        let rt = Runtime::new(&cfg.artifacts_dir)?;
        let spec = rt.manifest.model(&cfg.model)?.clone();
        let art = format!("infer_b{}", cfg.policy.max_batch);
        let exe = rt.load_model_artifact(&cfg.model, &art)?;
        let params = cfg.params.clone().unwrap_or_else(|| {
            crate::cocotune::trainer::ModelState::init(&spec, 0x5EED)
                .params
        });
        let masks: Vec<HostTensor> = spec
            .masks
            .iter()
            .map(|t| HostTensor::ones(&t.shape))
            .collect();
        // Hot-path optimization: params + masks live on the device; only
        // the image batch is uploaded per execution (EXPERIMENTS.md §Perf).
        let mut prefix_host = params.clone();
        prefix_host.extend(masks.iter().cloned());
        let prefix = exe.upload_prefix(rt.client(), &prefix_host)?;
        Ok((rt, spec, exe, prefix))
    })();
    let (rt, spec, exe, prefix) = match setup {
        Ok(v) => {
            let elems: usize = v.1.input_shape.iter().product();
            let _ = init_tx.send(Ok(elems));
            v
        }
        Err(e) => {
            let _ = init_tx.send(Err(e));
            return;
        }
    };
    let (h, w, c) = (
        spec.input_shape[0],
        spec.input_shape[1],
        spec.input_shape[2],
    );
    let image_elems = h * w * c;
    let classes = spec.classes;
    let batch_cap = cfg.policy.max_batch;
    while let Some(mut batch) = batcher::next_batch(&rx, &cfg.policy) {
        let t0 = Instant::now();
        let n = batch.len();
        // Pad to the compiled batch size.
        let mut x = vec![0f32; batch_cap * image_elems];
        for (i, r) in batch.iter().enumerate() {
            x[i * image_elems..(i + 1) * image_elems]
                .copy_from_slice(&r.image);
        }
        let suffix = [HostTensor::f32(&[batch_cap, h, w, c], x)];
        let out = match exe.run_with_prefix(rt.client(), &prefix, &suffix) {
            Ok(o) => o,
            Err(_) => {
                for r in batch.drain(..) {
                    drop(r);
                    m.record_rejected();
                }
                continue;
            }
        };
        let logits = out[0].as_f32().unwrap();
        let done = Instant::now();
        for (i, r) in batch.drain(..).enumerate() {
            let row = &logits[i * classes..(i + 1) * classes];
            let (class, score) = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(cl, s)| (cl, *s))
                .unwrap();
            let total = done - r.enqueued;
            m.record(total, t0 - r.enqueued, n);
            let _ = r.reply.send(Prediction {
                class,
                score,
                latency_ms: total.as_secs_f64() * 1e3,
            });
        }
    }
}
