//! Serving coordinator — the L3 request path, deployment edition.
//!
//! One [`Coordinator`] registers several **named deployments** — points
//! on the compression-compilation menu (`dense`, `cocogen`,
//! `cocogen-quant`, `coco-auto`, ...), each built by
//! [`Deployment::builder`] and each served by its own backends. A
//! leader thread owns the SLA router and a per-deployment dynamic
//! batcher; each [`Backend`] (PJRT runtime, native executor pool, ...)
//! lives on its own worker thread, which compiles the model during
//! startup and then executes the batches routed to it. Clients submit
//! typed [`InferRequest`]s over channels and receive
//! `Result<Prediction, ServeError>`; Python is never on this path.
//!
//! ```text
//!                         leader: SLA router (live Metrics feedback)
//! Client::infer ────────►   │ per-deployment shard batcher
//!  {image, sla,             ├─► dep "cocogen":  BatchRouter ─► workers
//!   deployment?}            ├─► dep "int8":     BatchRouter ─► workers
//!                           └─► dep "coco-auto":BatchRouter ─► workers
//!                                  ▲        failover retry      │
//!                                  └────────────────────────────┘
//! ```
//!
//! Routing is two-tier: the leader first resolves each request to a
//! deployment — an explicit name wins; otherwise the request's
//! [`Sla`] class picks among the registered variants using *live*
//! latency points fed back from each deployment's [`Metrics`] — then
//! batches per deployment and routes each batch across that
//! deployment's backends ([`RouterPolicy`]).
//!
//! Failure handling: a worker whose `infer_batch` errors logs the
//! cause, puts its backend into a routing cooldown (a half-open circuit
//! breaker, not a permanent removal), and hands the batch back to the
//! leader, which re-routes it to the next healthy backend of the same
//! deployment (counted in `Summary::failovers`). A request that has
//! failed on every backend of its deployment is rejected with a typed
//! [`ServeError::Exhausted`] on its reply channel.

pub mod backend;
pub mod batcher;
pub mod deployment;
pub mod lifecycle;
pub mod metrics;
pub mod router;

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender,
                      SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::codegen::ExecPlan;
use crate::runtime::{HostTensor, Runtime};
pub use backend::{Backend, ModelSignature, NativeBackend,
                  NativeBatchMode, PjrtBackend};
pub use batcher::{BatchPolicy, Push, ShardBatcher};
pub use deployment::{Deployment, DeploymentBuilder};
pub use lifecycle::{retune_once, CanaryConfig, CanaryOutcome,
                    DeploymentId, Lifecycle, RetuneOutcome, Retuner,
                    RetunerConfig};
pub use metrics::{BackendReport, DeploymentReport, Metrics, ServeReport,
                  Summary};
pub use router::{BackendState, BatchRouter, Router, RouterPolicy, Sla,
                 SlaPolicy, Variant};

/// Typed serving error — every client-visible failure mode of the
/// request path. Submission-time errors come back from
/// [`Client::infer`] directly; routing/execution-time errors arrive on
/// the reply channel, so a rejected request is an explicit
/// `Err(ServeError)` rather than a hung or dropped `recv`.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The image's element count does not match the model signature.
    WrongImageSize { got: usize, want: usize },
    /// `InferRequest::deployment` names no registered deployment.
    UnknownDeployment(String),
    /// The named deployment version has been retired (or is draining
    /// out) under the live lifecycle registry. `current_version` names
    /// the successor that took over its traffic, when one exists —
    /// clients pinned to a retired version re-pin to it.
    Retired { current_version: Option<Arc<str>> },
    /// The request's SLA class admits no registered variant under the
    /// configured [`SlaPolicy`].
    NoAdmissibleVariant { sla: Sla },
    /// The request failed on every backend of its deployment.
    Exhausted,
    /// Load shed: the deployment's bounded queue is past the watermark
    /// this request's SLA class may enter at (Standard/Quality shed at
    /// the soft watermark, Realtime only when hard-full). The embedded
    /// hint grows with queue depth — callers should back off at least
    /// this long before retrying.
    Overloaded { retry_after_ms: u64 },
    /// The coordinator has shut down (or is shutting down).
    Stopped,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::WrongImageSize { got, want } => {
                write!(f, "image has {got} elements, model wants {want}")
            }
            ServeError::UnknownDeployment(name) => {
                write!(f, "unknown deployment '{name}'")
            }
            ServeError::Retired { current_version } => match current_version {
                Some(v) => write!(f, "deployment retired; current \
                                      version is '{v}'"),
                None => write!(f, "deployment retired"),
            },
            ServeError::NoAdmissibleVariant { sla } => {
                write!(f,
                       "no registered deployment admissible for SLA \
                        class '{}'",
                       sla.label())
            }
            ServeError::Exhausted => {
                write!(f, "request failed on every backend of its \
                           deployment")
            }
            ServeError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded; retry in {retry_after_ms} ms")
            }
            ServeError::Stopped => write!(f, "coordinator stopped"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Lifecycle state of one slot in the versioned deployment registry.
///
/// ```text
///             canary_weight / promote
///   Canary ────────────────────────► Live
///     │ rollback (retire)              │ retire
///     ▼                                ▼
///   Draining ──(outstanding == 0)──► Retired
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    /// Routable: in the unpinned SLA-routing mask and pinnable.
    Live,
    /// Warm and serving, but outside the unpinned rotation — traffic
    /// reaches it only through the canary split or an explicit pin.
    Canary,
    /// Retiring: refuses new work (typed [`ServeError::Retired`]);
    /// everything already admitted drains to completion.
    Draining,
    /// Drained and out of rotation. Slots are tombstones, never
    /// reused, so a slot index pinned inside an in-flight request
    /// stays valid for the coordinator's lifetime.
    Retired,
}

/// One registered deployment version, registry view. Kept deliberately
/// small: the leader's hot-path structures (job senders, batch router,
/// backend states) live leader-side; the registry is the shared
/// source of truth for *identity and lifecycle state*.
pub(crate) struct Slot {
    pub(crate) name: Arc<str>,
    /// Flattened image size this version's signature accepts.
    pub(crate) elems: usize,
    pub(crate) state: SlotState,
    /// Successor version, for the [`ServeError::Retired`] hint.
    pub(crate) successor: Option<Arc<str>>,
    /// The deployment's metrics sink (canary windows, retuner's
    /// observed batch distribution).
    pub(crate) metrics: Arc<Metrics>,
    /// The compiled plan behind a native single-plan deployment —
    /// what the retuner re-tunes.
    pub(crate) plan: Option<Arc<ExecPlan>>,
}

/// The versioned deployment registry, shared (behind an `RwLock`)
/// between clients (name resolution + size checks), the lifecycle
/// handle (control-plane validation), and the leader — the registry's
/// only writer. Append-only: at most [`router::MAX_VARIANTS`]
/// registrations over a coordinator's lifetime.
pub(crate) struct Registry {
    pub(crate) slots: Vec<Slot>,
}

/// Shared per-deployment metrics table (deployment, sink, per-backend
/// sinks) — appended by live registration, read by
/// [`Coordinator::shutdown_report`].
pub(crate) type SharedDepMetrics =
    Arc<Mutex<Vec<(Arc<str>, Arc<Metrics>, Vec<(Arc<str>, Arc<Metrics>)>)>>>;

/// A fully spawned deployment, handed from the lifecycle handle (which
/// compiled and warmed it off the leader thread) to the leader, which
/// installs it into the routing structures between batches.
pub(crate) struct Installed {
    pub(crate) name: Arc<str>,
    pub(crate) elems: usize,
    pub(crate) state: SlotState,
    pub(crate) dep: LeaderDep,
    pub(crate) variant: Variant,
    pub(crate) workers: Vec<JoinHandle<()>>,
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) plan: Option<Arc<ExecPlan>>,
}

/// Control-plane operations, [`Lifecycle`] → leader. The leader
/// applies them between batches, so the data path never takes a lock
/// against the control path.
pub(crate) enum Control {
    /// Install a spawned deployment; replies with its slot index.
    Install {
        msg: Box<Installed>,
        reply: Sender<std::result::Result<usize, String>>,
    },
    /// Begin draining a slot; the reply arrives only once its
    /// outstanding count reaches zero (satellite: drained, not
    /// dropped), carrying the retiree's final summary.
    Retire {
        slot: usize,
        successor: Option<Arc<str>>,
        reply: Sender<std::result::Result<Summary, String>>,
    },
    /// Split the incumbent's unpinned traffic with a canary slot at
    /// `weight` (fraction to the canary, in `[0, 1]`).
    CanarySet {
        incumbent: usize,
        canary: usize,
        weight: f64,
        reply: Sender<std::result::Result<(), String>>,
    },
    /// End the canary split. `promote` flips the canary slot Live;
    /// otherwise it stays Canary for the caller to retire (rollback).
    CanaryEnd {
        promote: bool,
        reply: Sender<std::result::Result<(), String>>,
    },
}

/// A pending retire: the leader polls the slot each loop iteration and
/// replies once shard queue and outstanding count are both empty.
struct DrainWaiter {
    slot: usize,
    reply: Sender<std::result::Result<Summary, String>>,
}

/// Leader-side canary split state. Reuses the deployment-tier `Split`
/// deficit-round-robin router over a two-entry phantom backend pair
/// (index 0 = incumbent, 1 = canary) so the traffic split inherits
/// DRR's bounded deficit instead of needing a second weighting scheme.
struct CanaryState {
    incumbent: usize,
    canary: usize,
    weight: f64,
    /// `None` at the degenerate weights (`w <= 0` or `w >= 1`, which
    /// `Split` rejects): all traffic goes one way.
    split: Option<BatchRouter>,
    duo: [Arc<BackendState>; 2],
    /// The canary's image size — the redirect only applies to requests
    /// the canary can actually serve.
    canary_elems: usize,
}

impl CanaryState {
    /// Which slot this unpinned request goes to.
    fn pick(&mut self) -> usize {
        match self.split.as_mut() {
            None => {
                if self.weight >= 1.0 {
                    self.canary
                } else {
                    self.incumbent
                }
            }
            Some(r) => {
                if r.pick(&self.duo) == 0 {
                    self.incumbent
                } else {
                    self.canary
                }
            }
        }
    }
}

/// The typed request form: one NHWC image (flattened), the SLA class
/// the router resolves when no explicit deployment is named.
#[derive(Debug, Clone)]
pub struct InferRequest<'a> {
    pub image: Vec<f32>,
    pub sla: Sla,
    /// Pin the request to a named deployment, bypassing SLA
    /// resolution. `None` lets the live router pick.
    pub deployment: Option<&'a str>,
}

impl InferRequest<'static> {
    /// A `Standard`-class request with router-chosen deployment — what
    /// the [`Client::submit`] convenience wrapper sends.
    pub fn new(image: Vec<f32>) -> InferRequest<'static> {
        InferRequest {
            image,
            sla: Sla::Standard,
            deployment: None,
        }
    }
}

/// What a client's reply channel carries.
pub type PredictionResult = Result<Prediction, ServeError>;

/// A submission as it travels leader-ward: deployment still unresolved
/// when the client did not pin one.
struct Submit {
    image: Vec<f32>,
    sla: Sla,
    deployment: Option<usize>,
    enqueued: Instant,
    reply: Sender<PredictionResult>,
}

/// A resolved classification request owned by the leader/workers.
/// `pub(crate)` only so the lifecycle handle can hold a clone of the
/// failover-retry sender; its fields stay module-private.
pub(crate) struct Request {
    image: Vec<f32>,
    /// Index of the deployment this request resolved to.
    deployment: usize,
    enqueued: Instant,
    reply: Sender<PredictionResult>,
    /// Bitmask of backend indices (within the deployment) that have
    /// failed this request — the exhaustion test ("failed on every
    /// backend") uses this, so a degraded-mode re-pick of the same
    /// backend doesn't burn a distinct-backend credit.
    failed: u64,
    /// Total failover hops; a hard bound that guarantees termination
    /// even when routing can only reach already-failed backends (e.g.
    /// the others' worker threads are gone).
    tries: usize,
}

/// The response. Names are interned (`Arc<str>`): the hot reply path
/// shares one allocation per backend/deployment for the coordinator's
/// lifetime instead of a fresh `String` per request.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub class: usize,
    pub score: f32,
    pub latency_ms: f64,
    /// Name of the backend that served this request.
    pub backend: Arc<str>,
    /// Name of the deployment the request resolved to.
    pub deployment: Arc<str>,
}

/// Handle for submitting requests.
///
/// Submission is backpressured end to end: the intake channel is
/// *bounded* (never an unbounded buffer), and a coordinator whose
/// outstanding work has saturated every queue fails submissions fast
/// with [`ServeError::Overloaded`] instead of buffering them — an
/// open-loop client can never build an invisible backlog inside the
/// coordinator.
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Submit>,
    /// The live deployment registry: name resolution, per-version
    /// image sizes, and lifecycle states all read through here, so a
    /// version registered (or retired) after this client was cloned is
    /// visible immediately.
    registry: Arc<RwLock<Registry>>,
    closing: Arc<AtomicBool>,
    /// Shared count of admitted, not-yet-served requests.
    pending: Arc<AtomicUsize>,
    /// Sync-path shed threshold: when `pending` reaches it, every
    /// queue is saturated and submission fails without a round-trip.
    intake_bound: usize,
}

impl Client {
    /// Submit a typed request; returns the receiver for the
    /// prediction. Submission-time failures (wrong image size, unknown
    /// deployment name, retired version, saturated intake, coordinator
    /// stopped) are returned here; routing/execution failures arrive
    /// typed on the receiver.
    pub fn infer(&self, req: InferRequest<'_>)
                 -> Result<Receiver<PredictionResult>, ServeError> {
        let deployment = {
            let reg = self.registry.read().unwrap();
            let deployment = match req.deployment {
                None => None,
                Some(name) => {
                    let d = reg
                        .slots
                        .iter()
                        .position(|s| &*s.name == name)
                        .ok_or_else(|| {
                            ServeError::UnknownDeployment(
                                name.to_string(),
                            )
                        })?;
                    // A pin to a draining/retired version is refused
                    // with the successor's name — late `infer`s never
                    // hold a drain open.
                    if matches!(reg.slots[d].state,
                                SlotState::Draining
                                    | SlotState::Retired)
                    {
                        return Err(ServeError::Retired {
                            current_version: reg.slots[d]
                                .successor
                                .clone(),
                        });
                    }
                    Some(d)
                }
            };
            // Size validation is per deployment: a pinned request must
            // match its deployment's signature; an unpinned one must
            // match at least one *live* deployment (the leader then
            // routes it only among those).
            match deployment {
                Some(d) if req.image.len() != reg.slots[d].elems => {
                    return Err(ServeError::WrongImageSize {
                        got: req.image.len(),
                        want: reg.slots[d].elems,
                    });
                }
                None if !reg.slots.iter().any(|s| {
                    s.state == SlotState::Live
                        && s.elems == req.image.len()
                }) =>
                {
                    return Err(ServeError::WrongImageSize {
                        got: req.image.len(),
                        want: reg
                            .slots
                            .first()
                            .map(|s| s.elems)
                            .unwrap_or(0),
                    });
                }
                _ => {}
            }
            deployment
        };
        if self.closing.load(Ordering::SeqCst) {
            return Err(ServeError::Stopped);
        }
        // Fail-fast shed: outstanding work already exceeds every
        // queue's capacity, so the leader would only shed this request
        // anyway — answer here without occupying an intake slot.
        let depth = self.pending.load(Ordering::SeqCst);
        if depth >= self.intake_bound {
            return Err(ServeError::Overloaded {
                retry_after_ms: router::retry_after_ms(depth, 1.0),
            });
        }
        let (rtx, rrx) = mpsc::channel();
        match self.tx.try_send(Submit {
            image: req.image,
            sla: req.sla,
            deployment,
            enqueued: Instant::now(),
            reply: rtx,
        }) {
            Ok(()) => Ok(rrx),
            // A full intake channel is backpressure, not an error in
            // the request: the caller gets a typed shed with a
            // depth-scaled back-off hint.
            Err(TrySendError::Full(_)) => Err(ServeError::Overloaded {
                retry_after_ms: router::retry_after_ms(
                    self.pending.load(Ordering::SeqCst),
                    1.0,
                ),
            }),
            Err(TrySendError::Disconnected(_)) => {
                Err(ServeError::Stopped)
            }
        }
    }

    /// Thin convenience wrapper: a `Standard`-class request with the
    /// deployment left to the SLA router.
    pub fn submit(&self, image: Vec<f32>)
                  -> Result<Receiver<PredictionResult>, ServeError> {
        self.infer(InferRequest::new(image))
    }

    /// The names of the deployments currently accepting work
    /// (live, canary, or warming — everything not yet retired), in
    /// registration order.
    pub fn deployments(&self) -> Vec<Arc<str>> {
        self.registry
            .read()
            .unwrap()
            .slots
            .iter()
            .filter(|s| {
                !matches!(s.state,
                          SlotState::Draining | SlotState::Retired)
            })
            .map(|s| s.name.clone())
            .collect()
    }
}

/// Serving options for the PJRT path (see [`Deployment::pjrt`] and
/// [`Coordinator::start`]).
#[derive(Clone)]
pub struct ServeConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub policy: BatchPolicy,
    /// Explicit parameter tensors (trained weights); deterministic-random
    /// init when None.
    pub params: Option<Vec<HostTensor>>,
}

impl ServeConfig {
    pub fn new(model: &str) -> ServeConfig {
        ServeConfig {
            artifacts_dir: Runtime::default_dir(),
            model: model.to_string(),
            policy: BatchPolicy::default(),
            params: None,
        }
    }
}

/// A batch of requests dispatched to one backend worker.
struct Job {
    reqs: Vec<Request>,
    /// The deployment's queue depth when this batch was dispatched —
    /// forwarded to the backend as [`Backend::queue_hint`] (elastic
    /// pools scale on it).
    depth: usize,
}

/// Default per-deployment queue bound (outstanding requests). Generous
/// on purpose: closed-loop clients never approach it, so existing
/// callers see no sheds, while an open-loop overload is still bounded —
/// the queue can never grow without limit.
pub const DEFAULT_QUEUE_CAP: usize = 4096;

/// Builder for a multi-deployment [`Coordinator`]: register named
/// deployments, set the batching policy, the SLA admission limits and
/// the queue bound, then [`CoordinatorBuilder::start`].
pub struct CoordinatorBuilder {
    deployments: Vec<Deployment>,
    policy: BatchPolicy,
    sla: SlaPolicy,
    queue_cap: usize,
}

impl CoordinatorBuilder {
    /// Batching policy shared by every deployment's shard batcher.
    pub fn policy(mut self, policy: BatchPolicy) -> CoordinatorBuilder {
        self.policy = policy;
        self
    }

    /// Per-SLA admission limits for the live variant router.
    pub fn sla(mut self, sla: SlaPolicy) -> CoordinatorBuilder {
        self.sla = sla;
        self
    }

    /// Bound each deployment's queue at `cap` outstanding (admitted,
    /// not yet served) requests. From the soft watermark (`cap / 2`)
    /// Standard/Quality requests shed with [`ServeError::Overloaded`];
    /// at `cap` Realtime sheds too. Default [`DEFAULT_QUEUE_CAP`].
    pub fn queue_cap(mut self, cap: usize) -> CoordinatorBuilder {
        self.queue_cap = cap;
        self
    }

    /// Register a named deployment. Registration order is report order.
    /// A deployment's backends must agree on the model signature;
    /// *across* deployments signatures may differ (conv and sequence
    /// models serve side by side — requests route only among
    /// deployments whose signature matches the submitted image).
    pub fn register(mut self, dep: Deployment) -> CoordinatorBuilder {
        self.deployments.push(dep);
        self
    }

    /// Start serving: spawn every backend worker (compiles run in
    /// parallel), verify each deployment's backends agree on its
    /// signature, and start the leader.
    pub fn start(self) -> Result<Coordinator> {
        let CoordinatorBuilder {
            deployments,
            policy,
            sla,
            queue_cap,
        } = self;
        ensure!(!deployments.is_empty(),
                "register at least one deployment");
        ensure!(
            deployments.len() <= router::MAX_VARIANTS,
            "at most {} deployments",
            router::MAX_VARIANTS
        );
        ensure!(policy.max_batch > 0, "max_batch must be positive");
        for (i, d) in deployments.iter().enumerate() {
            ensure!(!d.name.is_empty(), "deployment names must be \
                                         non-empty");
            ensure!(
                !deployments[..i].iter().any(|e| e.name == d.name),
                "duplicate deployment name '{}'",
                d.name
            );
            ensure!(!d.backends.is_empty(),
                    "deployment '{}' has no backends", d.name);
            ensure!(
                d.backends.len() <= 64,
                "deployment '{}': at most 64 backends (failed-backend \
                 tracking is a u64 bitmask)",
                d.name
            );
        }

        let global = Arc::new(Metrics::new());
        let pending = Arc::new(AtomicUsize::new(0));
        let closing = Arc::new(AtomicBool::new(false));
        let (retry_tx, retry_rx) = mpsc::channel::<Vec<Request>>();
        let (control_tx, control_rx) = mpsc::channel::<Control>();
        let max_batch = policy.max_batch;

        // Spawn every deployment's workers first so the backends
        // compile in parallel, then collect their signatures: startup
        // costs the slowest compile, not the sum.
        let mut spawned = Vec::with_capacity(deployments.len());
        for dep in deployments {
            spawned.push(spawn_deployment(dep, max_batch, &global,
                                          &pending, &retry_tx)?);
        }
        let mut deps = Vec::with_capacity(spawned.len());
        let mut dep_metrics = Vec::with_capacity(spawned.len());
        let mut variants = Vec::with_capacity(spawned.len());
        let mut workers = Vec::new();
        let mut slots = Vec::with_capacity(spawned.len());
        for mut sd in spawned {
            // Signatures must agree *within* a deployment (its
            // backends serve the same compiled model). Across
            // deployments they may differ: the sequence tier registers
            // `[T, D, 1]` text models next to `[H, W, C]` conv models
            // behind one client, and the leader routes each request
            // only among deployments whose signature matches the
            // submitted image.
            let sig = sd.signature()?;
            slots.push(Slot {
                name: sd.name.clone(),
                elems: sig.image_elems(),
                state: SlotState::Live,
                successor: None,
                metrics: sd.metrics.clone(),
                plan: sd.plan.clone(),
            });
            variants.push(sd.variant);
            deps.push(sd.dep);
            workers.extend(sd.workers);
            dep_metrics.push((sd.name, sd.metrics, sd.bms));
        }

        let n_deps = slots.len();
        let registry = Arc::new(RwLock::new(Registry { slots }));
        let dep_metrics: SharedDepMetrics =
            Arc::new(Mutex::new(dep_metrics));
        // Bounded intake: the channel between clients and the leader
        // holds at most one coordinator's worth of queue capacity
        // (clamped to a sane range — the leader drains it far faster
        // than backends serve, so it only fills when everything else
        // already has). `intake_bound` is the fail-fast threshold:
        // pending work can only exceed every per-deployment cap
        // combined when the system is saturated. Both are sized from
        // the builder-time menu; live registrations reuse them (the
        // clamp keeps the bound sane either way).
        let intake_cap =
            queue_cap.saturating_mul(n_deps).clamp(64, 8192);
        let intake_bound = queue_cap.saturating_mul(2 * n_deps);
        let (tx, rx) = mpsc::sync_channel::<Submit>(intake_cap);
        let lifecycle = Lifecycle::new(
            control_tx,
            registry.clone(),
            dep_metrics.clone(),
            global.clone(),
            pending.clone(),
            retry_tx,
            max_batch,
        );
        let ctx = LeaderCtx {
            rx,
            retry_rx,
            control_rx,
            deps,
            sla_router: Router::with_policy(variants, sla),
            policy,
            queue_cap,
            registry: registry.clone(),
            global: global.clone(),
            pending: pending.clone(),
            closing: closing.clone(),
            workers,
            drains: Vec::new(),
            canary: None,
        };
        let leader = std::thread::spawn(move || leader_main(ctx));
        Ok(Coordinator {
            client: Client {
                tx,
                registry,
                closing: closing.clone(),
                pending,
                intake_bound,
            },
            metrics: global,
            dep_metrics,
            lifecycle,
            closing,
            leader: Some(leader),
        })
    }
}

/// A deployment whose backend workers have been spawned (compiles
/// running in parallel on their threads) but whose signatures have not
/// been collected yet.
pub(crate) struct SpawnedDep {
    pub(crate) name: Arc<str>,
    pub(crate) dep: LeaderDep,
    pub(crate) variant: Variant,
    pub(crate) workers: Vec<JoinHandle<()>>,
    pub(crate) bms: Vec<(Arc<str>, Arc<Metrics>)>,
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) plan: Option<Arc<ExecPlan>>,
    init_rxs: Vec<(Arc<str>, Receiver<Result<ModelSignature>>)>,
}

impl SpawnedDep {
    /// Block until every backend's compile finishes and check that
    /// they agree on one model signature.
    pub(crate) fn signature(&mut self) -> Result<ModelSignature> {
        let mut first: Option<ModelSignature> = None;
        for (bname, init_rx) in self.init_rxs.drain(..) {
            let sig = init_rx.recv().map_err(|_| {
                anyhow!("backend '{bname}' of deployment '{}' died \
                         during compile",
                        self.name)
            })??;
            match &first {
                Some(f) => ensure!(
                    &sig == f,
                    "backend '{bname}' of deployment '{}' signature \
                     {sig:?} disagrees with its deployment's ({f:?})",
                    self.name
                ),
                None => first = Some(sig),
            }
        }
        first.ok_or_else(|| {
            anyhow!("deployment '{}' has no backends", self.name)
        })
    }
}

/// Spawn one deployment's backend workers (each starts compiling
/// immediately) and assemble its leader-side routing state. Shared by
/// [`CoordinatorBuilder::start`] (the static menu) and
/// [`Lifecycle::register`] (live registration on a running
/// coordinator).
pub(crate) fn spawn_deployment(
    dep: Deployment, max_batch: usize, global: &Arc<Metrics>,
    pending: &Arc<AtomicUsize>, retry: &Sender<Vec<Request>>,
) -> Result<SpawnedDep> {
    // Validate the batch-routing policy before consuming the
    // deployment's backends.
    let batch_router =
        BatchRouter::new(dep.router.clone(), dep.backends.len())?;
    let dep_name = dep.name.clone();
    let accuracy = dep.accuracy;
    let prior_latency_ms = dep.prior_latency_ms;
    let plan = dep.plan().cloned();
    let dm = Arc::new(Metrics::new());
    let tracker = Arc::new(AtomicU64::new(0));
    let n_backends = dep.backends.len();
    let mut jobs = Vec::with_capacity(n_backends);
    let mut states = Vec::with_capacity(n_backends);
    let mut bms = Vec::with_capacity(n_backends);
    let mut workers = Vec::with_capacity(n_backends);
    let mut init_rxs = Vec::with_capacity(n_backends);
    for (index, be) in dep.backends.into_iter().enumerate() {
        let bname: Arc<str> = Arc::from(be.name());
        let state = BackendState::new(&bname);
        let bm = Arc::new(Metrics::new());
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let (init_tx, init_rx) =
            mpsc::channel::<Result<ModelSignature>>();
        let ctx = WorkerCtx {
            index,
            n_backends,
            max_batch,
            jobs: job_rx,
            init_tx,
            state: state.clone(),
            metrics: bm.clone(),
            dep_metrics: dm.clone(),
            global: global.clone(),
            retry: retry.clone(),
            pending: pending.clone(),
            tracker: tracker.clone(),
            dep_name: dep_name.clone(),
        };
        let handle =
            std::thread::spawn(move || backend_worker(be, ctx));
        init_rxs.push((bname.clone(), init_rx));
        jobs.push(job_tx);
        states.push(state);
        bms.push((bname, bm));
        workers.push(handle);
    }
    Ok(SpawnedDep {
        name: dep_name.clone(),
        dep: LeaderDep {
            jobs,
            states,
            router: batch_router,
            metrics: dm.clone(),
        },
        variant: Variant::live(dep_name, accuracy, prior_latency_ms,
                               dm.clone(), tracker),
        workers,
        bms,
        metrics: dm,
        plan,
        init_rxs,
    })
}

/// The serving coordinator: named deployments behind one client.
pub struct Coordinator {
    client: Client,
    /// Aggregate metrics across all deployments.
    pub metrics: Arc<Metrics>,
    dep_metrics: SharedDepMetrics,
    lifecycle: Lifecycle,
    closing: Arc<AtomicBool>,
    leader: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start building a multi-deployment coordinator.
    pub fn builder() -> CoordinatorBuilder {
        CoordinatorBuilder {
            deployments: Vec::new(),
            policy: BatchPolicy::default(),
            sla: SlaPolicy::default(),
            queue_cap: DEFAULT_QUEUE_CAP,
        }
    }

    /// Serve `cfg.model` on the PJRT runtime alone — kept for callers
    /// that only want the AOT path. Equivalent to registering one
    /// [`Deployment::pjrt`].
    pub fn start(cfg: ServeConfig) -> Result<Coordinator> {
        let policy = cfg.policy;
        let name = format!("pjrt:{}", cfg.model);
        Coordinator::builder()
            .policy(policy)
            .register(Deployment::pjrt(&name, cfg))
            .start()
    }

    /// Serve one anonymous deployment (`"default"`) across `backends`
    /// under `policy`, routing each formed batch per `router` — the
    /// pre-`Deployment` entry point, kept as a thin wrapper over
    /// [`Coordinator::builder`].
    pub fn start_with(backends: Vec<Box<dyn Backend>>,
                      policy: BatchPolicy, router: RouterPolicy)
                      -> Result<Coordinator> {
        Coordinator::builder()
            .policy(policy)
            .register(
                Deployment::from_backends("default", backends)
                    .with_router(router),
            )
            .start()
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// A cloneable control-plane handle: register, canary, retune and
    /// retire deployment versions on this *running* coordinator (see
    /// [`Lifecycle`]).
    pub fn lifecycle(&self) -> Lifecycle {
        self.lifecycle.clone()
    }

    /// The deployment names currently accepting work, in registration
    /// order (retired versions drop out; live registrations appear).
    pub fn deployments(&self) -> Vec<Arc<str>> {
        self.client.deployments()
    }

    /// Submit a typed request through the coordinator's own client
    /// handle (see [`Client::infer`]).
    pub fn infer(&self, req: InferRequest<'_>)
                 -> Result<Receiver<PredictionResult>, ServeError> {
        self.client.infer(req)
    }

    /// Submit an image through the coordinator's own client handle;
    /// returns the receiver for the prediction.
    ///
    /// ```
    /// use cocopie::ir::{Chw, IrBuilder};
    /// use cocopie::prelude::*;
    ///
    /// let mut b = IrBuilder::new("doc", Chw::new(3, 8, 8));
    /// b.conv("c1", 3, 4, 1, true).gap("g").dense("fc", 3, false);
    /// let ir = b.build().unwrap();
    /// let coord = Coordinator::builder()
    ///     .register(
    ///         Deployment::builder("cocogen", &ir)
    ///             .scheme(Scheme::CocoGen)
    ///             .build()
    ///             .unwrap(),
    ///     )
    ///     .start()
    ///     .unwrap();
    /// let pred = coord.submit(vec![0.5; 8 * 8 * 3]).unwrap()
    ///     .recv().unwrap().unwrap();
    /// assert!(pred.class < 3);
    /// assert_eq!(&*pred.deployment, "cocogen");
    /// coord.shutdown();
    /// ```
    pub fn submit(&self, image: Vec<f32>)
                  -> Result<Receiver<PredictionResult>, ServeError> {
        self.client.submit(image)
    }

    /// Stop accepting requests, drain in-flight work, and join the
    /// workers. Outstanding [`Client`] clones see
    /// [`ServeError::Stopped`] from the moment this is called. Returns
    /// the aggregate summary; use [`Coordinator::shutdown_report`] for
    /// the per-deployment view.
    pub fn shutdown(self) -> Summary {
        self.shutdown_report().overall
    }

    /// Like [`Coordinator::shutdown`], with per-deployment (and
    /// per-backend) summaries.
    pub fn shutdown_report(mut self) -> ServeReport {
        self.closing.store(true, Ordering::SeqCst);
        drop(self.client);
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
        ServeReport {
            overall: self.metrics.summary(),
            deployments: self
                .dep_metrics
                .lock()
                .unwrap()
                .iter()
                .map(|(name, dm, bms)| DeploymentReport {
                    name: name.clone(),
                    summary: dm.summary(),
                    backends: bms
                        .iter()
                        .map(|(bn, bm)| BackendReport {
                            name: bn.clone(),
                            summary: bm.summary(),
                        })
                        .collect(),
                })
                .collect(),
        }
    }
}

/// Everything a backend worker thread owns besides the backend itself.
struct WorkerCtx {
    /// This backend's index within its deployment (bit position in
    /// `Request::failed`).
    index: usize,
    /// Backend count of this deployment (exhaustion bitmask width).
    n_backends: usize,
    max_batch: usize,
    jobs: Receiver<Job>,
    init_tx: Sender<Result<ModelSignature>>,
    state: Arc<BackendState>,
    metrics: Arc<Metrics>,
    dep_metrics: Arc<Metrics>,
    global: Arc<Metrics>,
    retry: Sender<Vec<Request>>,
    pending: Arc<AtomicUsize>,
    /// The deployment's outstanding-request counter (the SLA router's
    /// load signal); decremented as requests finish here.
    tracker: Arc<AtomicU64>,
    dep_name: Arc<str>,
}

fn backend_worker(mut be: Box<dyn Backend>, ctx: WorkerCtx) {
    // Compile on this thread: PJRT handles are thread-affine.
    let sig = match be.compile(ctx.max_batch) {
        Ok(s) => {
            let _ = ctx.init_tx.send(Ok(s.clone()));
            s
        }
        Err(e) => {
            let _ = ctx.init_tx.send(Err(e));
            return;
        }
    };
    let (h, w, c) =
        (sig.input_shape[0], sig.input_shape[1], sig.input_shape[2]);
    let elems = sig.image_elems();
    let classes = sig.classes;
    let name: Arc<str> = Arc::from(be.name());
    while let Ok(mut job) = ctx.jobs.recv() {
        // Forward the dispatch-time congestion signal: elastic pools
        // grow toward their max under sustained depth and shrink back
        // once it subsides.
        be.queue_hint(job.depth);
        let t0 = Instant::now();
        let n = job.reqs.len();
        let mut x = vec![0f32; n * elems];
        for (i, r) in job.reqs.iter().enumerate() {
            x[i * elems..(i + 1) * elems].copy_from_slice(&r.image);
        }
        let images = HostTensor::f32(&[n, h, w, c], x);
        // `Backend` is a public extension seam: a panicking
        // `infer_batch` must become a failed batch (failover path), not
        // a dead worker thread — a dead worker would leak the batch's
        // `pending` count and hang shutdown.
        let infer = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| be.infer_batch(&images)),
        );
        // Either a validated logits row per request, or the reason this
        // batch failed (kept for the operator: metrics alone can't say
        // *why* a backend started failing over).
        let failure: Option<String> = match infer {
            Err(_) => Some("infer_batch panicked".to_string()),
            Ok(Err(e)) => Some(format!("{e:#}")),
            Ok(Ok(t)) => match t.as_f32() {
                Err(e) => Some(format!("{e:#}")),
                Ok(lv) if lv.len() < n * classes => Some(format!(
                    "returned {} logits for {n} images x {classes} classes",
                    lv.len()
                )),
                Ok(lv) => {
                    let done = Instant::now();
                    for (i, r) in job.reqs.drain(..).enumerate() {
                        let row = &lv[i * classes..(i + 1) * classes];
                        // total_cmp: a NaN logit must not panic the
                        // worker (a panic here would leak `pending` and
                        // hang shutdown).
                        let (class, score) = row
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.total_cmp(b.1))
                            .map(|(cl, s)| (cl, *s))
                            .unwrap();
                        let total = done - r.enqueued;
                        ctx.metrics.record(total, t0 - r.enqueued, n);
                        ctx.dep_metrics.record(total, t0 - r.enqueued, n);
                        ctx.global.record(total, t0 - r.enqueued, n);
                        let _ = r.reply.send(Ok(Prediction {
                            class,
                            score,
                            latency_ms: total.as_secs_f64() * 1e3,
                            backend: name.clone(),
                            deployment: ctx.dep_name.clone(),
                        }));
                    }
                    ctx.tracker.fetch_sub(n as u64, Ordering::Relaxed);
                    ctx.pending.fetch_sub(n, Ordering::SeqCst);
                    None
                }
            },
        };
        if let Some(err) = failure {
            eprintln!(
                "coordinator: backend '{name}' of deployment '{}' \
                 failed a batch of {n}: {err}",
                ctx.dep_name
            );
            // Cool this backend down; requests that still have untried
            // backends go back to the leader.
            ctx.state.mark_unhealthy();
            let all_failed = if ctx.n_backends >= 64 {
                u64::MAX
            } else {
                (1u64 << ctx.n_backends) - 1
            };
            let mut forward = Vec::new();
            let mut finished = 0usize;
            for mut r in job.reqs.drain(..) {
                r.failed |= 1u64 << ctx.index;
                r.tries += 1;
                // Rejected once it failed on every backend — or, as a
                // termination bound when routing can only reach
                // already-failed backends (the others' threads are
                // gone), after 2x n_backends hops.
                if r.failed == all_failed || r.tries >= 2 * ctx.n_backends {
                    finished += 1;
                    ctx.metrics.record_rejected();
                    ctx.dep_metrics.record_rejected();
                    ctx.global.record_rejected();
                    let _ = r.reply.send(Err(ServeError::Exhausted));
                } else {
                    ctx.metrics.record_failover();
                    ctx.dep_metrics.record_failover();
                    ctx.global.record_failover();
                    forward.push(r);
                }
            }
            if !forward.is_empty() {
                let fwd_len = forward.len();
                if let Err(mpsc::SendError(lost)) = ctx.retry.send(forward)
                {
                    // Leader already gone; nothing can serve these.
                    for r in lost {
                        let _ = r.reply.send(Err(ServeError::Stopped));
                        ctx.metrics.record_rejected();
                        ctx.dep_metrics.record_rejected();
                        ctx.global.record_rejected();
                    }
                    finished += fwd_len;
                }
            }
            ctx.tracker.fetch_sub(finished as u64, Ordering::Relaxed);
            ctx.pending.fetch_sub(finished, Ordering::SeqCst);
        }
        ctx.state.end();
    }
}

/// One deployment's routing state, leader side.
pub(crate) struct LeaderDep {
    jobs: Vec<Sender<Job>>,
    states: Vec<Arc<BackendState>>,
    router: BatchRouter,
    metrics: Arc<Metrics>,
}

/// Everything the leader thread owns.
struct LeaderCtx {
    rx: Receiver<Submit>,
    retry_rx: Receiver<Vec<Request>>,
    /// Lifecycle control plane: install/retire/canary ops, applied
    /// between batches so the data path never takes a lock against
    /// the control path.
    control_rx: Receiver<Control>,
    deps: Vec<LeaderDep>,
    sla_router: Router,
    policy: BatchPolicy,
    queue_cap: usize,
    /// The shared registry — lifecycle state plus per-slot image size
    /// (the SLA router's eligibility mask is derived from it per
    /// request). The leader is its only writer.
    registry: Arc<RwLock<Registry>>,
    global: Arc<Metrics>,
    pending: Arc<AtomicUsize>,
    closing: Arc<AtomicBool>,
    workers: Vec<JoinHandle<()>>,
    /// Retires in progress, polled each loop turn.
    drains: Vec<DrainWaiter>,
    /// The (single) active canary traffic split, if any.
    canary: Option<CanaryState>,
}

fn leader_main(mut ctx: LeaderCtx) {
    // Short enough that failover retries are picked up promptly, long
    // enough that an idle coordinator barely wakes.
    let idle = Duration::from_millis(20);
    let mut shards: ShardBatcher<Request> =
        ShardBatcher::with_queue_cap(ctx.deps.len(), ctx.policy,
                                     ctx.queue_cap);
    let mut open = true;
    while open || ctx.pending.load(Ordering::SeqCst) > 0 {
        while let Ok(op) = ctx.control_rx.try_recv() {
            handle_control(&mut ctx, &mut shards, op);
        }
        while let Ok(reqs) = ctx.retry_rx.try_recv() {
            dispatch_retry(&mut ctx, reqs);
        }
        let now = Instant::now();
        for (d, batch) in shards.take_expired(now) {
            dispatch(&mut ctx, d, batch);
        }
        service_drains(&mut ctx, &shards);
        if open {
            // Block until new work or the earliest shard deadline.
            let timeout = shards
                .next_deadline()
                .map(|dl| dl.saturating_duration_since(now).min(idle))
                .unwrap_or(idle);
            match ctx.rx.recv_timeout(timeout) {
                Ok(sub) => accept(&mut ctx, &mut shards, sub),
                Err(RecvTimeoutError::Timeout) => {
                    // A shutdown with lingering client clones never
                    // disconnects the channel: drain what made it in,
                    // then stop accepting.
                    if ctx.closing.load(Ordering::SeqCst) {
                        while let Ok(sub) = ctx.rx.try_recv() {
                            accept(&mut ctx, &mut shards, sub);
                        }
                        open = false;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
            if !open {
                for (d, batch) in shards.drain() {
                    dispatch(&mut ctx, d, batch);
                }
            }
        } else if let Ok(reqs) = ctx.retry_rx.recv_timeout(idle) {
            dispatch_retry(&mut ctx, reqs);
        }
    }
    // Out of the loop means `pending == 0` and the shards are empty,
    // so every in-progress retire has drained — answer the waiters
    // before tearing the workers down.
    service_drains(&mut ctx, &shards);
    // A request that raced past the closing flag gets a typed error
    // instead of a silently dropped reply channel.
    drain_stopped(&ctx);
    // Close the job channels so workers exit, then join them.
    for d in &mut ctx.deps {
        d.jobs.clear();
    }
    for h in ctx.workers.drain(..) {
        let _ = h.join();
    }
    // Joining can take a full batch's service time — long enough for a
    // submit that loaded `closing == false` before the store to land in
    // the channel. Drain once more so those see Stopped too, not a
    // dropped reply.
    drain_stopped(&ctx);
}

/// Reply [`ServeError::Stopped`] to every submission still sitting in
/// the intake channel.
fn drain_stopped(ctx: &LeaderCtx) {
    while let Ok(sub) = ctx.rx.try_recv() {
        let _ = sub.reply.send(Err(ServeError::Stopped));
        ctx.global.record_rejected();
    }
}

/// Apply one lifecycle control operation between batches.
fn handle_control(ctx: &mut LeaderCtx,
                  shards: &mut ShardBatcher<Request>, op: Control) {
    match op {
        Control::Install { msg, reply } => {
            let _ = reply.send(install(ctx, shards, *msg));
        }
        Control::Retire {
            slot,
            successor,
            reply,
        } => retire_begin(ctx, shards, slot, successor, reply),
        Control::CanarySet {
            incumbent,
            canary,
            weight,
            reply,
        } => {
            let _ = reply
                .send(canary_set(ctx, incumbent, canary, weight));
        }
        Control::CanaryEnd { promote, reply } => {
            let _ = reply.send(canary_end(ctx, promote));
        }
    }
}

/// Install a spawned deployment into every leader-side structure. The
/// indices stay in lockstep (registry slot == SLA variant == leader
/// dep == shard) and are append-only, so an in-flight request's slot
/// index survives any later registration.
fn install(ctx: &mut LeaderCtx, shards: &mut ShardBatcher<Request>,
           m: Installed) -> std::result::Result<usize, String> {
    {
        let mut reg = ctx.registry.write().unwrap();
        if reg.slots.iter().any(|s| s.name == m.name) {
            return Err(format!("duplicate deployment name '{}'",
                               m.name));
        }
        if reg.slots.len() >= router::MAX_VARIANTS {
            return Err(format!(
                "at most {} deployments over a coordinator's lifetime",
                router::MAX_VARIANTS
            ));
        }
        reg.slots.push(Slot {
            name: m.name.clone(),
            elems: m.elems,
            state: m.state,
            successor: None,
            metrics: m.metrics,
            plan: m.plan,
        });
    }
    let slot = ctx.sla_router.push(m.variant);
    ctx.deps.push(m.dep);
    ctx.workers.extend(m.workers);
    let shard = shards.add_shard();
    debug_assert_eq!(slot, shard);
    Ok(slot)
}

/// Flip a slot to `Draining` and flush its shard queue to the
/// backends — retire *drains* queued work, it never drops it. The
/// reply is parked on a [`DrainWaiter`]; [`service_drains`] answers it
/// once the slot's outstanding count reaches zero.
fn retire_begin(
    ctx: &mut LeaderCtx, shards: &mut ShardBatcher<Request>,
    slot: usize, successor: Option<Arc<str>>,
    reply: Sender<std::result::Result<Summary, String>>,
) {
    if slot >= ctx.deps.len() {
        let _ = reply.send(Err(format!("no such slot {slot}")));
        return;
    }
    if let Some(cs) = &ctx.canary {
        if cs.incumbent == slot || cs.canary == slot {
            let _ = reply.send(Err(
                "slot is part of the active canary split; end the \
                 canary first"
                    .to_string(),
            ));
            return;
        }
    }
    {
        let mut reg = ctx.registry.write().unwrap();
        let s = &mut reg.slots[slot];
        if matches!(s.state,
                    SlotState::Draining | SlotState::Retired)
        {
            let _ = reply.send(Err(format!(
                "deployment '{}' is already retired",
                s.name
            )));
            return;
        }
        s.state = SlotState::Draining;
        s.successor = successor;
    }
    if let Some(batch) = shards.take_shard(slot) {
        dispatch(ctx, slot, batch);
    }
    ctx.drains.push(DrainWaiter { slot, reply });
}

/// Put the (single) canary traffic split in place, or retarget its
/// weight for the next rollout stage.
fn canary_set(ctx: &mut LeaderCtx, incumbent: usize, canary: usize,
              weight: f64) -> std::result::Result<(), String> {
    if !weight.is_finite() || !(0.0..=1.0).contains(&weight) {
        return Err(format!("canary weight {weight} outside [0, 1]"));
    }
    if incumbent == canary {
        return Err("incumbent and canary must be distinct"
            .to_string());
    }
    if incumbent.max(canary) >= ctx.deps.len() {
        return Err(format!(
            "no such slot {}",
            incumbent.max(canary)
        ));
    }
    let canary_elems = {
        let reg = ctx.registry.read().unwrap();
        if reg.slots[incumbent].state != SlotState::Live {
            return Err(format!("incumbent '{}' is not live",
                               reg.slots[incumbent].name));
        }
        if reg.slots[canary].state != SlotState::Canary {
            return Err(format!(
                "canary '{}' is not in the Canary state",
                reg.slots[canary].name
            ));
        }
        reg.slots[canary].elems
    };
    // `Split` requires strictly positive weights; the degenerate ends
    // route everything one way without a router.
    let split = if weight > 0.0 && weight < 1.0 {
        Some(
            BatchRouter::new(
                RouterPolicy::Split(vec![1.0 - weight, weight]),
                2,
            )
            .map_err(|e| format!("{e:#}"))?,
        )
    } else {
        None
    };
    ctx.canary = Some(CanaryState {
        incumbent,
        canary,
        weight,
        split,
        duo: [BackendState::new("incumbent"),
              BackendState::new("canary")],
        canary_elems,
    });
    Ok(())
}

/// Tear the canary split down; on promote the canary slot joins the
/// unpinned Live rotation (rollback leaves it Canary for the
/// controller to retire).
fn canary_end(ctx: &mut LeaderCtx, promote: bool)
              -> std::result::Result<(), String> {
    let cs = match ctx.canary.take() {
        Some(cs) => cs,
        None => return Err("no active canary split".to_string()),
    };
    if promote {
        ctx.registry.write().unwrap().slots[cs.canary].state =
            SlotState::Live;
    }
    Ok(())
}

/// Answer every pending retire whose slot has fully drained: shard
/// queue empty *and* outstanding count zero (failover-forwarded
/// requests keep the count up, so a drain waits for them too). The
/// drained slot's job senders are dropped — its workers exit — and the
/// registry marks it `Retired`.
fn service_drains(ctx: &mut LeaderCtx,
                  shards: &ShardBatcher<Request>) {
    let mut i = 0;
    while i < ctx.drains.len() {
        let slot = ctx.drains[i].slot;
        let done = shards.depth(slot) == 0
            && ctx.sla_router.variants()[slot].load() == 0;
        if !done {
            i += 1;
            continue;
        }
        let w = ctx.drains.swap_remove(i);
        ctx.deps[slot].jobs.clear();
        ctx.registry.write().unwrap().slots[slot].state =
            SlotState::Retired;
        let _ = w.reply.send(Ok(ctx.deps[slot].metrics.summary()));
    }
}

/// Resolve a submission to a deployment (explicit name wins; otherwise
/// the live SLA router picks), run SLA-aware admission against that
/// deployment's queue depth, and queue the survivor on its shard.
fn accept(ctx: &mut LeaderCtx, shards: &mut ShardBatcher<Request>,
          sub: Submit) {
    let d = match sub.deployment {
        Some(d) => {
            // Re-check lifecycle state leader-side: the slot may have
            // begun draining after the client resolved the pin, and a
            // draining slot must admit nothing new or its drain never
            // terminates.
            let successor = {
                let reg = ctx.registry.read().unwrap();
                match reg.slots[d].state {
                    SlotState::Draining | SlotState::Retired => {
                        Some(reg.slots[d].successor.clone())
                    }
                    _ => None,
                }
            };
            if let Some(current_version) = successor {
                let _ = sub.reply.send(Err(ServeError::Retired {
                    current_version,
                }));
                ctx.global.record_rejected();
                return;
            }
            d
        }
        None => {
            // Route only among *live* deployments whose input
            // signature matches the submitted image — with conv and
            // sequence models registered side by side, the families
            // accept different flattened sizes, and canary/draining
            // versions are outside the unpinned rotation.
            let mask = {
                let reg = ctx.registry.read().unwrap();
                reg.slots.iter().enumerate().fold(
                    0u64,
                    |m, (i, s)| {
                        if s.state == SlotState::Live
                            && s.elems == sub.image.len()
                        {
                            m | (1u64 << i)
                        } else {
                            m
                        }
                    },
                )
            };
            let mut d = match ctx.sla_router.select_masked(sub.sla,
                                                           mask) {
                Ok(d) => d,
                Err(e) => {
                    let _ = sub.reply.send(Err(e));
                    ctx.global.record_rejected();
                    return;
                }
            };
            // Staged rollout: a fraction of the incumbent's unpinned
            // traffic (deficit-round-robin over the split weights)
            // goes to the canary instead.
            if let Some(cs) = ctx.canary.as_mut() {
                if d == cs.incumbent
                    && cs.canary_elems == sub.image.len()
                {
                    d = cs.pick();
                }
            }
            d
        }
    };
    // Admission control before the request costs anything: shed by
    // depth and live latency so Standard/Quality give way first and
    // the deployment's outstanding work stays <= queue_cap. Sheds are
    // counted on their own gauge — never in rejected/latency state.
    let depth = ctx.sla_router.variants()[d].load() as usize;
    if let Err(e) = ctx.sla_router.admit(sub.sla, d, depth,
                                         ctx.queue_cap) {
        let _ = sub.reply.send(Err(e));
        ctx.global.record_shed();
        ctx.deps[d].metrics.record_shed();
        return;
    }
    ctx.pending.fetch_add(1, Ordering::SeqCst);
    ctx.sla_router.variants()[d].begin();
    ctx.deps[d].metrics.set_queue_depth(depth + 1);
    ctx.global.set_queue_depth(ctx.pending.load(Ordering::SeqCst));
    let enqueued = sub.enqueued;
    let req = Request {
        image: sub.image,
        deployment: d,
        enqueued,
        reply: sub.reply,
        failed: 0,
        tries: 0,
    };
    match shards.push(d, req, enqueued) {
        Push::Full(batch) => dispatch(ctx, d, batch),
        Push::Queued => {}
        // Second line of defense (admission already bounds outstanding
        // work): a capped shard hands the request back; undo its
        // accounting and shed it typed.
        Push::Shed(req) => {
            let hint = router::retry_after_ms(
                depth,
                ctx.sla_router.variants()[d].latency_ms(),
            );
            let _ = req.reply.send(Err(ServeError::Overloaded {
                retry_after_ms: hint,
            }));
            ctx.global.record_shed();
            ctx.deps[d].metrics.record_shed();
            ctx.sla_router.variants()[d].end();
            ctx.pending.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Re-dispatch a failed-over batch (every request of a retry batch
/// resolved to the same deployment when it was first accepted).
fn dispatch_retry(ctx: &mut LeaderCtx, reqs: Vec<Request>) {
    let d = reqs[0].deployment;
    dispatch(ctx, d, reqs);
}

/// Route one batch to a backend of deployment `d` (every request
/// already counted in `pending`). The batch router always yields a
/// backend (degraded mode falls back to unhealthy ones); rejection
/// happens either in the worker once a request has failed on every
/// backend, or here when *every* worker thread of the deployment is
/// gone.
fn dispatch(ctx: &mut LeaderCtx, d: usize, reqs: Vec<Request>) {
    // A retired slot's job senders are cleared and its workers are
    // gone; nothing should reach here for one (drains wait for every
    // outstanding request, including failover retries), but a typed
    // rejection beats indexing an empty sender list.
    if ctx.deps[d].jobs.is_empty() {
        reject(ctx, d, reqs);
        return;
    }
    let dep = &mut ctx.deps[d];
    let mut first = dep.router.pick(&dep.states);
    // Backends every request in this batch has already failed on
    // (non-zero only for failover retries). Steering the retry away
    // from them is what makes "rejected only after failing on every
    // backend" hold even when the router is in degraded mode.
    let avoid: u64 = reqs.iter().fold(u64::MAX, |m, r| m & r.failed);
    if avoid & (1u64 << first) != 0 {
        let fresh = (0..dep.jobs.len())
            .filter(|&k| avoid & (1u64 << k) == 0)
            .min_by_key(|&k| (!dep.states[k].healthy(), k));
        if let Some(k) = fresh {
            first = k;
        }
    }
    let depth = ctx.sla_router.variants()[d].load() as usize;
    let mut job = Job { reqs, depth };
    dep.states[first].begin();
    match dep.jobs[first].send(job) {
        Ok(()) => return,
        Err(mpsc::SendError(j)) => {
            // This worker's thread is gone (panic) — not a request
            // failure. Cool it down and scan the others, healthy
            // first, before giving up on the batch.
            dep.states[first].mark_unhealthy();
            dep.states[first].end();
            job = j;
        }
    }
    let mut order: Vec<usize> =
        (0..dep.jobs.len()).filter(|&k| k != first).collect();
    // Untried-by-this-batch first, then healthy, then declaration order.
    order.sort_by_key(|&k| {
        (avoid & (1u64 << k) != 0, !dep.states[k].healthy())
    });
    for k in order {
        dep.states[k].begin();
        match dep.jobs[k].send(job) {
            Ok(()) => return,
            Err(mpsc::SendError(j)) => {
                dep.states[k].mark_unhealthy();
                dep.states[k].end();
                job = j;
            }
        }
    }
    reject(ctx, d, job.reqs);
}

fn reject(ctx: &mut LeaderCtx, d: usize, reqs: Vec<Request>) {
    let n = reqs.len();
    for r in reqs {
        // A typed rejection: the client's recv yields the error rather
        // than hanging on a silently dropped channel.
        let _ = r.reply.send(Err(ServeError::Exhausted));
        ctx.global.record_rejected();
        ctx.deps[d].metrics.record_rejected();
        ctx.sla_router.variants()[d].end();
    }
    ctx.pending.fetch_sub(n, Ordering::SeqCst);
}
