//! Request router: dispatches requests across model variants/replicas.
//!
//! The co-design story at serving time: CoCo-Gen produces multiple
//! deployment variants of the same model (dense, pattern-pruned at
//! several rates) with different latency/accuracy points; the router
//! picks a variant per request according to its SLA class and balances
//! load across replicas (least-outstanding-requests).

use std::sync::atomic::{AtomicU64, Ordering};

/// Request SLA class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sla {
    /// Minimize latency: route to the most-pruned (fastest) variant.
    Realtime,
    /// Balanced default.
    Standard,
    /// Maximize accuracy: dense variant.
    Quality,
}

/// One routable backend.
pub struct Backend {
    pub name: String,
    /// Expected single-batch latency (ms) — from the tuner/bench.
    pub latency_ms: f64,
    /// Expected accuracy of this variant.
    pub accuracy: f64,
    outstanding: AtomicU64,
}

impl Backend {
    pub fn new(name: &str, latency_ms: f64, accuracy: f64) -> Backend {
        Backend {
            name: name.to_string(),
            latency_ms,
            accuracy,
            outstanding: AtomicU64::new(0),
        }
    }
    pub fn begin(&self) {
        self.outstanding.fetch_add(1, Ordering::Relaxed);
    }
    pub fn end(&self) {
        self.outstanding.fetch_sub(1, Ordering::Relaxed);
    }
    pub fn load(&self) -> u64 {
        self.outstanding.load(Ordering::Relaxed)
    }
}

/// The router: SLA-filtered, least-loaded selection.
pub struct Router {
    backends: Vec<Backend>,
}

impl Router {
    pub fn new(backends: Vec<Backend>) -> Router {
        assert!(!backends.is_empty());
        Router { backends }
    }

    /// Candidate set for an SLA class: Realtime = fastest third,
    /// Quality = most-accurate third, Standard = all.
    fn candidates(&self, sla: Sla) -> Vec<usize> {
        let n = self.backends.len();
        let k = n.div_ceil(3);
        let mut idx: Vec<usize> = (0..n).collect();
        match sla {
            Sla::Realtime => {
                idx.sort_by(|&a, &b| {
                    self.backends[a]
                        .latency_ms
                        .partial_cmp(&self.backends[b].latency_ms)
                        .unwrap()
                });
                idx.truncate(k);
            }
            Sla::Quality => {
                idx.sort_by(|&a, &b| {
                    self.backends[b]
                        .accuracy
                        .partial_cmp(&self.backends[a].accuracy)
                        .unwrap()
                });
                idx.truncate(k);
            }
            Sla::Standard => {}
        }
        idx
    }

    /// Pick a backend for `sla`: least outstanding load among candidates,
    /// ties broken by latency.
    pub fn route(&self, sla: Sla) -> &Backend {
        let cands = self.candidates(sla);
        let best = cands
            .into_iter()
            .min_by(|&a, &b| {
                let ba = &self.backends[a];
                let bb = &self.backends[b];
                ba.load()
                    .cmp(&bb.load())
                    .then(
                        ba.latency_ms
                            .partial_cmp(&bb.latency_ms)
                            .unwrap(),
                    )
            })
            .unwrap();
        &self.backends[best]
    }

    pub fn backends(&self) -> &[Backend] {
        &self.backends
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn mk() -> Router {
        Router::new(vec![
            Backend::new("dense", 10.0, 0.95),
            Backend::new("pattern-3x", 4.0, 0.93),
            Backend::new("pattern-8x", 2.0, 0.90),
        ])
    }

    #[test]
    fn realtime_prefers_fastest() {
        let r = mk();
        assert_eq!(r.route(Sla::Realtime).name, "pattern-8x");
    }

    #[test]
    fn quality_prefers_most_accurate() {
        let r = mk();
        assert_eq!(r.route(Sla::Quality).name, "dense");
    }

    #[test]
    fn standard_balances_by_load() {
        let r = mk();
        // Load up the fastest backend; Standard must avoid it.
        let fast = r.route(Sla::Realtime);
        fast.begin();
        fast.begin();
        let chosen = r.route(Sla::Standard);
        assert_ne!(chosen.name, "pattern-8x");
        fast.end();
        fast.end();
    }

    #[test]
    fn load_accounting_round_trips() {
        prop::check("router-load", 50, |g| {
            let r = mk();
            let n = g.usize(0, 20);
            let b = r.route(Sla::Standard);
            for _ in 0..n {
                b.begin();
            }
            if b.load() != n as u64 {
                return Err("load mismatch".into());
            }
            for _ in 0..n {
                b.end();
            }
            if b.load() != 0 {
                return Err("load not drained".into());
            }
            Ok(())
        });
    }
}
