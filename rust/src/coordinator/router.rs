//! Request routing, at two granularities.
//!
//! **Variant routing** (the co-design story at serving time): CoCo-Gen
//! produces multiple deployment variants of the same model (dense,
//! pattern-pruned, int8, auto-tuned) with different latency/accuracy
//! points; [`Router`] picks a [`Variant`] per request according to its
//! SLA class. This is a *live* router: each variant's latency point is
//! read back from the deployment's [`Metrics`] (an exponentially
//! decayed mean that tracks drift), falling back to a measured prior
//! only until the first completion — the operating points the paper's
//! menu promises are observed, not declared.
//!
//! **Batch routing** (the `Backend` seam): once the dynamic batcher has
//! formed a batch, [`BatchRouter`] decides which live backend of the
//! chosen deployment executes it — always-primary with hot standbys
//! ([`RouterPolicy::Failover`]), a weighted traffic split
//! ([`RouterPolicy::Split`]), or least outstanding batches
//! ([`RouterPolicy::LeastLoaded`]). Health is tracked per backend in
//! [`BackendState`]: a backend whose `infer_batch` fails is marked
//! unhealthy and drops out of the candidate set, which is what makes
//! failover work.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{ensure, Result};

use super::metrics::Metrics;
use super::ServeError;

/// Deployment-count ceiling: [`Router::select`] classifies variants on
/// fixed stack buffers, and the coordinator's per-request bookkeeping
/// assumes small variant sets.
pub const MAX_VARIANTS: usize = 64;

/// Request SLA class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Sla {
    /// Minimize latency: route to a fast (aggressively compressed)
    /// variant.
    Realtime,
    /// Balanced default.
    Standard,
    /// Maximize accuracy: the densest admissible variant.
    Quality,
}

impl Sla {
    /// Parse a CLI-style class name.
    pub fn parse(s: &str) -> Option<Sla> {
        match s {
            "realtime" | "rt" => Some(Sla::Realtime),
            "standard" | "std" => Some(Sla::Standard),
            "quality" | "hq" => Some(Sla::Quality),
            _ => None,
        }
    }

    /// Stable lowercase label (CLI/report strings).
    pub fn label(&self) -> &'static str {
        match self {
            Sla::Realtime => "realtime",
            Sla::Standard => "standard",
            Sla::Quality => "quality",
        }
    }

    /// The deterministic mixed-traffic cycle the CLI, serve example,
    /// and serving bench all drive: 2 realtime : 3 standard :
    /// 1 quality per 6 requests, keyed by request index.
    pub fn mixed(i: usize) -> Sla {
        match i % 6 {
            0 | 3 => Sla::Realtime,
            5 => Sla::Quality,
            _ => Sla::Standard,
        }
    }
}

/// Per-SLA admission limits. `None` falls back to relative admission
/// (fastest / most-accurate third of the registered variants); `Some`
/// makes the class a hard constraint, under which a request can find
/// *no* admissible variant and is rejected with a typed error.
#[derive(Debug, Clone, Copy, Default)]
pub struct SlaPolicy {
    /// Realtime requests only admit variants whose live mean latency is
    /// at or below this budget (ms).
    pub realtime_budget_ms: Option<f64>,
    /// Quality requests only admit variants whose declared accuracy
    /// point is at or above this floor.
    pub quality_floor: Option<f64>,
}

/// One routable deployment variant: a named operating point on the
/// co-design menu, with a *live* latency estimate and a declared
/// accuracy point.
pub struct Variant {
    pub name: Arc<str>,
    /// Declared accuracy point of this variant (operator-provided, or a
    /// plan-derived proxy — accuracy cannot be observed online without
    /// labels).
    pub accuracy: f64,
    /// Latency estimate used until `metrics` has served anything (ms) —
    /// measured at deployment build time, not a hard-coded constant.
    prior_latency_ms: f64,
    /// The deployment's live metrics sink; its decayed-mean latency
    /// is this variant's operating point once traffic has flowed.
    metrics: Arc<Metrics>,
    outstanding: Arc<AtomicU64>,
}

impl Variant {
    /// A live variant over a deployment's metrics sink. `tracker` is
    /// the shared outstanding-request counter (the worker side
    /// decrements it as requests finish).
    pub fn live(name: Arc<str>, accuracy: f64, prior_latency_ms: f64,
                metrics: Arc<Metrics>, tracker: Arc<AtomicU64>)
                -> Variant {
        Variant {
            name,
            accuracy,
            prior_latency_ms,
            metrics,
            outstanding: tracker,
        }
    }

    /// Test/offline convenience: a variant with no traffic yet, whose
    /// latency estimate is the given prior.
    pub fn new(name: &str, latency_ms: f64, accuracy: f64) -> Variant {
        Variant::live(
            Arc::from(name),
            accuracy,
            latency_ms,
            Arc::new(Metrics::new()),
            Arc::new(AtomicU64::new(0)),
        )
    }

    /// The live latency operating point: the deployment's
    /// exponentially decayed mean once it has served traffic (so the
    /// point follows a deployment that degrades or warms up), the
    /// measured prior before that.
    pub fn latency_ms(&self) -> f64 {
        self.metrics
            .live_latency_ms()
            .unwrap_or(self.prior_latency_ms)
    }

    pub fn begin(&self) {
        self.outstanding.fetch_add(1, Ordering::Relaxed);
    }
    pub fn end(&self) {
        self.outstanding.fetch_sub(1, Ordering::Relaxed);
    }
    pub fn load(&self) -> u64 {
        self.outstanding.load(Ordering::Relaxed)
    }

    /// Clone of the shared outstanding counter, for the worker side.
    pub fn tracker(&self) -> Arc<AtomicU64> {
        self.outstanding.clone()
    }
}

/// The per-request variant router: SLA-filtered admission over *live*
/// latency points, then least-loaded selection among the admitted set.
///
/// Admission is recomputed per request from each variant's current
/// [`Variant::latency_ms`] — latencies drift as traffic warms caches or
/// a variant degrades, and the candidate set must drift with them. The
/// scan runs over fixed stack buffers (at most [`MAX_VARIANTS`]
/// variants), so the hot path stays allocation-free.
pub struct Router {
    variants: Vec<Variant>,
    policy: SlaPolicy,
}

impl Router {
    pub fn new(variants: Vec<Variant>) -> Router {
        Router::with_policy(variants, SlaPolicy::default())
    }

    pub fn with_policy(variants: Vec<Variant>, policy: SlaPolicy)
                       -> Router {
        assert!(!variants.is_empty(), "router needs at least one variant");
        assert!(variants.len() <= MAX_VARIANTS,
                "at most {MAX_VARIANTS} variants");
        Router { variants, policy }
    }

    /// Pick a variant index for `sla`, or a typed error when the SLA
    /// admits none.
    ///
    /// Admission: `Realtime` admits variants within the configured
    /// latency budget (default: the fastest third by live latency);
    /// `Quality` admits variants at or above the accuracy floor
    /// (default: the most-accurate third); `Standard` admits all.
    /// Among admitted variants the pick is least outstanding load, ties
    /// broken by latency (`Realtime`/`Standard`) or accuracy-then-
    /// latency (`Quality`).
    pub fn select(&self, sla: Sla) -> Result<usize, ServeError> {
        self.select_masked(sla, u64::MAX)
    }

    /// [`Router::select`] restricted to the variants whose bit is set
    /// in `mask` (bit `i` = variant `i`). The multi-family coordinator
    /// uses this to route each request only among deployments whose
    /// input signature matches the submitted image — a `[T, D]` text
    /// request must never land on a conv variant. The fastest-third /
    /// most-accurate-third admission thresholds are computed over the
    /// *eligible* subset, so a tier of slow text models next to fast
    /// conv models still gets a meaningful Realtime cut among its own.
    pub fn select_masked(&self, sla: Sla, mask: u64)
                         -> Result<usize, ServeError> {
        // Compact the eligible variants into dense stack buffers; `j`
        // indexes those, `idx[j]` maps back to the variant index.
        let mut idx = [0usize; MAX_VARIANTS];
        let mut m = 0;
        for i in 0..self.variants.len() {
            if mask & (1u64 << i) != 0 {
                idx[m] = i;
                m += 1;
            }
        }
        if m == 0 {
            return Err(ServeError::NoAdmissibleVariant { sla });
        }
        let mut lat = [0f64; MAX_VARIANTS];
        for j in 0..m {
            lat[j] = self.variants[idx[j]].latency_ms();
        }
        let k = m.div_ceil(3);
        // One admission threshold per request, then a flat scan. Under
        // a hard budget, a variant with no measurement at all (infinite
        // prior — `from_backends`/`pjrt` deployments) is admitted
        // rather than starved: its live estimate can only ever form
        // from traffic it is allowed to serve, and after the first
        // completion the measured point governs.
        let lat_cap = match (sla, self.policy.realtime_budget_ms) {
            (Sla::Realtime, Some(budget)) => {
                for l in &mut lat[..m] {
                    if l.is_infinite() {
                        *l = budget;
                    }
                }
                budget
            }
            (Sla::Realtime, None) => kth_smallest(&lat[..m], k),
            _ => f64::INFINITY,
        };
        let acc_floor = match (sla, self.policy.quality_floor) {
            (Sla::Quality, Some(floor)) => floor,
            (Sla::Quality, None) => {
                let mut neg = [0f64; MAX_VARIANTS];
                for j in 0..m {
                    neg[j] = -self.variants[idx[j]].accuracy;
                }
                -kth_smallest(&neg[..m], k)
            }
            _ => f64::NEG_INFINITY,
        };
        (0..m)
            .filter(|&j| {
                lat[j] <= lat_cap
                    && self.variants[idx[j]].accuracy >= acc_floor
            })
            .min_by(|&a, &b| {
                let (va, vb) =
                    (&self.variants[idx[a]], &self.variants[idx[b]]);
                let load = va.load().cmp(&vb.load());
                if sla == Sla::Quality {
                    load.then(vb.accuracy.total_cmp(&va.accuracy))
                        .then(lat[a].total_cmp(&lat[b]))
                } else {
                    load.then(lat[a].total_cmp(&lat[b]))
                }
            })
            .map(|j| idx[j])
            .ok_or(ServeError::NoAdmissibleVariant { sla })
    }

    /// Pick a variant for `sla` (see [`Router::select`]).
    pub fn route(&self, sla: Sla) -> Result<&Variant, ServeError> {
        self.select(sla).map(|i| &self.variants[i])
    }

    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }

    /// Append a variant on a *running* router (live deployment
    /// registration); returns its index. Indices are stable — the
    /// lifecycle registry retires variants by masking them out of
    /// [`Router::select_masked`] eligibility, never by removal, so a
    /// variant index pinned inside an in-flight request stays valid
    /// for the life of the coordinator.
    pub fn push(&mut self, v: Variant) -> usize {
        assert!(self.variants.len() < MAX_VARIANTS,
                "at most {MAX_VARIANTS} variants over a \
                 coordinator's lifetime");
        self.variants.push(v);
        self.variants.len() - 1
    }

    /// Admission control for deployment `dep`'s bounded queue,
    /// currently `depth` requests deep under capacity `cap`.
    ///
    /// Shed order is strict and SLA-aware: `Standard`/`Quality` shed
    /// first — at the soft watermark (`cap / 2`), or as soon as the
    /// deployment's live latency exceeds the configured Realtime
    /// budget while anything is queued — and `Realtime` sheds only
    /// when the queue is hard-full. The embedded `retry_after_ms`
    /// grows with `depth` (see [`retry_after_ms`]), so callers back
    /// off harder the deeper the congestion.
    pub fn admit(&self, sla: Sla, dep: usize, depth: usize, cap: usize)
                 -> Result<(), ServeError> {
        let lat = self.variants[dep].latency_ms();
        let over_budget = self
            .policy
            .realtime_budget_ms
            .is_some_and(|b| lat > b);
        let shed = depth >= cap
            || (sla != Sla::Realtime
                && (depth >= cap / 2 || (depth > 0 && over_budget)));
        if shed {
            Err(ServeError::Overloaded {
                retry_after_ms: retry_after_ms(depth, lat),
            })
        } else {
            Ok(())
        }
    }
}

/// Back-off hint embedded in [`ServeError::Overloaded`]: roughly the
/// time for the queue ahead to drain at the deployment's live per-
/// request latency, clamped to a sane range so an unmeasured (infinite
/// prior) or sub-millisecond deployment still yields a usable hint.
/// Strictly monotone in `depth`.
pub fn retry_after_ms(depth: usize, latency_ms: f64) -> u64 {
    let per = if latency_ms.is_finite() {
        latency_ms.clamp(1.0, 1000.0)
    } else {
        1.0
    };
    ((depth as f64 + 1.0) * per).ceil() as u64
}

/// The k-th smallest value of `v` (1-based), on a stack copy — the
/// admission threshold for "fastest third" semantics.
fn kth_smallest(v: &[f64], k: usize) -> f64 {
    debug_assert!(k >= 1 && k <= v.len() && v.len() <= MAX_VARIANTS);
    let mut buf = [0f64; MAX_VARIANTS];
    buf[..v.len()].copy_from_slice(v);
    let buf = &mut buf[..v.len()];
    buf.sort_unstable_by(|a, b| a.total_cmp(b));
    buf[k - 1]
}

/// Cooldown after an infer failure, in routing decisions: the backend
/// re-enters the candidate set after this many picks (a half-open
/// circuit breaker — a flaky backend gets probed again instead of being
/// removed forever, and a transient error does not brick a
/// single-backend coordinator).
const UNHEALTHY_COOLDOWN: u64 = 32;

/// Live health/load state of one serving backend, shared between the
/// leader (which routes batches) and the backend's worker thread (which
/// reports failures).
pub struct BackendState {
    pub name: String,
    /// 0 = healthy; otherwise routing decisions left until recovery.
    penalty: AtomicU64,
    outstanding: AtomicU64,
    dispatched: AtomicU64,
}

impl BackendState {
    pub fn new(name: &str) -> Arc<BackendState> {
        Arc::new(BackendState {
            name: name.to_string(),
            penalty: AtomicU64::new(0),
            outstanding: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
        })
    }
    pub fn healthy(&self) -> bool {
        self.penalty.load(Ordering::SeqCst) == 0
    }
    /// An infer failure takes the backend out of rotation for
    /// `UNHEALTHY_COOLDOWN` routing decisions.
    pub fn mark_unhealthy(&self) {
        self.penalty.store(UNHEALTHY_COOLDOWN, Ordering::SeqCst);
    }
    /// One routing decision elapsed; unhealthy backends creep back
    /// toward rotation.
    fn decay(&self) {
        let _ = self.penalty.fetch_update(
            Ordering::SeqCst,
            Ordering::SeqCst,
            |p| if p > 0 { Some(p - 1) } else { None },
        );
    }
    /// A batch was dispatched to this backend.
    pub fn begin(&self) {
        self.outstanding.fetch_add(1, Ordering::SeqCst);
        self.dispatched.fetch_add(1, Ordering::SeqCst);
    }
    /// The batch finished (successfully or not).
    pub fn end(&self) {
        self.outstanding.fetch_sub(1, Ordering::SeqCst);
    }
    /// Batches dispatched and not yet finished.
    pub fn load(&self) -> u64 {
        self.outstanding.load(Ordering::SeqCst)
    }
    /// Total batches ever dispatched to this backend.
    pub fn dispatched(&self) -> u64 {
        self.dispatched.load(Ordering::SeqCst)
    }
}

/// How the leader spreads batches across backends.
#[derive(Debug, Clone)]
pub enum RouterPolicy {
    /// All traffic to the first *healthy* backend in declaration order;
    /// later backends are hot standbys that take over on failure.
    Failover,
    /// Split traffic across healthy backends proportionally to the given
    /// weights (deficit round-robin; one weight per backend, all > 0).
    Split(Vec<f64>),
    /// Send each batch to the healthy backend with the fewest
    /// outstanding batches, ties broken by declaration order.
    LeastLoaded,
}

/// Stateful batch router implementing a [`RouterPolicy`] over the
/// backends' shared [`BackendState`]s.
pub struct BatchRouter {
    policy: RouterPolicy,
    /// Deficit counters for `Split`.
    credit: Vec<f64>,
}

impl BatchRouter {
    pub fn new(policy: RouterPolicy, n_backends: usize)
               -> Result<BatchRouter> {
        ensure!(n_backends > 0, "router needs at least one backend");
        if let RouterPolicy::Split(w) = &policy {
            ensure!(
                w.len() == n_backends,
                "split weights ({}) must match backend count ({})",
                w.len(),
                n_backends
            );
            ensure!(
                w.iter().all(|x| *x > 0.0 && x.is_finite()),
                "split weights must be positive and finite"
            );
        }
        Ok(BatchRouter {
            policy,
            credit: vec![0.0; n_backends],
        })
    }

    /// Pick the backend for the next batch. Unhealthy backends are
    /// skipped while any healthy one remains; when none does, the
    /// policy runs over the full set ordered by ascending cooldown
    /// (degraded mode: attempting the least-recently-failed backend
    /// beats dropping traffic on the floor, and is what lets a sole
    /// backend recover from a transient error). Every policy honors
    /// that ordering — `LeastLoaded` breaks load ties by ascending
    /// penalty, and `Split` suspends deficit-round-robin accounting
    /// entirely while degraded (out-of-rotation backends must not
    /// accrue credit, or a recovering backend would absorb a burst of
    /// consecutive batches the moment it comes back). Each call also
    /// ticks every backend's cooldown.
    pub fn pick(&mut self, states: &[Arc<BackendState>]) -> usize {
        for s in states {
            s.decay();
        }
        let mut rotation: Vec<usize> = (0..states.len())
            .filter(|&i| states[i].healthy())
            .collect();
        let degraded = rotation.is_empty();
        if degraded {
            rotation = (0..states.len()).collect();
            // Stable sort: ascending cooldown, declaration order on
            // ties.
            rotation.sort_by_key(|&i| {
                states[i].penalty.load(Ordering::SeqCst)
            });
        }
        match &self.policy {
            RouterPolicy::Failover => rotation[0],
            RouterPolicy::LeastLoaded => rotation
                .iter()
                .copied()
                .min_by_key(|&i| {
                    let tie = if degraded {
                        states[i].penalty.load(Ordering::SeqCst)
                    } else {
                        0
                    };
                    (states[i].load(), tie, i)
                })
                .unwrap(),
            RouterPolicy::Split(w) => {
                if degraded {
                    // No backend is in rotation: probe by ascending
                    // cooldown and leave every deficit counter
                    // untouched.
                    return rotation[0];
                }
                // Deficit round-robin: in-rotation backends accrue
                // credit at their weight; the richest one serves and
                // pays the round's total, giving a `w`-proportional
                // long-run split that adapts when backends drop out.
                let total: f64 = rotation.iter().map(|&i| w[i]).sum();
                for &i in &rotation {
                    self.credit[i] += w[i];
                }
                let pick = rotation
                    .iter()
                    .copied()
                    .max_by(|&a, &b| {
                        self.credit[a]
                            .partial_cmp(&self.credit[b])
                            .unwrap()
                            .then(b.cmp(&a))
                    })
                    .unwrap();
                self.credit[pick] -= total;
                pick
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn mk() -> Router {
        Router::new(vec![
            Variant::new("dense", 10.0, 0.95),
            Variant::new("pattern-3x", 4.0, 0.93),
            Variant::new("pattern-8x", 2.0, 0.90),
        ])
    }

    #[test]
    fn realtime_prefers_fastest() {
        let r = mk();
        assert_eq!(&*r.route(Sla::Realtime).unwrap().name, "pattern-8x");
    }

    #[test]
    fn quality_prefers_most_accurate() {
        let r = mk();
        assert_eq!(&*r.route(Sla::Quality).unwrap().name, "dense");
    }

    #[test]
    fn standard_balances_by_load() {
        let r = mk();
        // Load up the fastest variant; Standard must avoid it.
        let fast = r.route(Sla::Realtime).unwrap();
        fast.begin();
        fast.begin();
        let chosen = r.route(Sla::Standard).unwrap();
        assert_ne!(&*chosen.name, "pattern-8x");
        fast.end();
        fast.end();
    }

    #[test]
    fn live_latency_overrides_the_prior() {
        // "dense" claims a slow prior; once its metrics show it is
        // actually the fastest variant, Realtime must follow the
        // measurement, not the prior.
        let dense_metrics = Arc::new(Metrics::new());
        let variants = vec![
            Variant::live(
                Arc::from("dense"),
                0.95,
                50.0,
                dense_metrics.clone(),
                Arc::new(AtomicU64::new(0)),
            ),
            Variant::new("pattern-8x", 2.0, 0.90),
        ];
        let r = Router::new(variants);
        assert_eq!(&*r.route(Sla::Realtime).unwrap().name, "pattern-8x");
        for _ in 0..4 {
            dense_metrics.record(
                std::time::Duration::from_micros(500),
                std::time::Duration::ZERO,
                1,
            );
        }
        assert_eq!(
            &*r.route(Sla::Realtime).unwrap().name,
            "dense",
            "live mean (0.5 ms) must replace the 50 ms prior"
        );
    }

    #[test]
    fn hard_limits_reject_with_typed_errors() {
        let policy = SlaPolicy {
            realtime_budget_ms: Some(3.0),
            quality_floor: Some(0.99),
        };
        let r = Router::with_policy(
            vec![
                Variant::new("dense", 10.0, 0.95),
                Variant::new("pattern-8x", 2.0, 0.90),
            ],
            policy,
        );
        // Realtime budget admits only the fast variant.
        assert_eq!(&*r.route(Sla::Realtime).unwrap().name, "pattern-8x");
        // No variant reaches the 0.99 accuracy floor.
        assert!(matches!(
            r.select(Sla::Quality),
            Err(ServeError::NoAdmissibleVariant { sla: Sla::Quality })
        ));
        // Standard is never constrained.
        assert!(r.select(Sla::Standard).is_ok());
    }

    #[test]
    fn mask_restricts_the_candidate_set() {
        let r = mk();
        // All bits set: identical to plain select.
        assert_eq!(r.select_masked(Sla::Realtime, u64::MAX).unwrap(), 2);
        // Fastest variant masked out: Realtime falls to the next.
        assert_eq!(r.select_masked(Sla::Realtime, 0b011).unwrap(), 1);
        // Singleton mask pins the choice regardless of SLA.
        for sla in [Sla::Realtime, Sla::Standard, Sla::Quality] {
            assert_eq!(r.select_masked(sla, 0b001).unwrap(), 0);
        }
        // Empty mask: typed rejection, not a panic.
        assert!(matches!(
            r.select_masked(Sla::Standard, 0),
            Err(ServeError::NoAdmissibleVariant { sla: Sla::Standard })
        ));
    }

    #[test]
    fn mask_thresholds_run_over_the_eligible_subset() {
        // Two families behind one router: fast conv variants (bits 0-1)
        // and slow text variants (bits 2-3). With the conv variants
        // masked out, the fastest-third cut must be computed among the
        // text variants — not leave text traffic inadmissible because
        // every text model is slower than the global fastest third.
        let r = Router::new(vec![
            Variant::new("conv-a", 1.0, 0.95),
            Variant::new("conv-b", 2.0, 0.93),
            Variant::new("text-a", 40.0, 0.91),
            Variant::new("text-b", 80.0, 0.90),
        ]);
        assert_eq!(r.select_masked(Sla::Realtime, 0b1100).unwrap(), 2);
        // And the most-accurate-third cut likewise: conv-a has the top
        // global accuracy, but among text variants text-a wins Quality.
        assert_eq!(r.select_masked(Sla::Quality, 0b1100).unwrap(), 2);
    }

    #[test]
    fn unmeasured_variant_is_admitted_under_a_hard_budget() {
        // A deployment with no latency prior (from_backends/pjrt:
        // INFINITY) must not be starved by realtime_budget_ms — its
        // live estimate can only form from traffic it is allowed to
        // serve.
        let policy = SlaPolicy {
            realtime_budget_ms: Some(10.0),
            quality_floor: None,
        };
        let r = Router::with_policy(
            vec![Variant::new("unmeasured", f64::INFINITY, 1.0)],
            policy,
        );
        assert_eq!(&*r.route(Sla::Realtime).unwrap().name, "unmeasured");
        // Once a measurement exists, the budget is enforced for real.
        let slow_metrics = Arc::new(Metrics::new());
        slow_metrics.record(
            std::time::Duration::from_millis(40),
            std::time::Duration::ZERO,
            1,
        );
        let r = Router::with_policy(
            vec![Variant::live(
                Arc::from("slow"),
                1.0,
                f64::INFINITY,
                slow_metrics,
                Arc::new(AtomicU64::new(0)),
            )],
            policy,
        );
        assert!(matches!(
            r.select(Sla::Realtime),
            Err(ServeError::NoAdmissibleVariant { sla: Sla::Realtime })
        ));
    }

    #[test]
    fn push_extends_a_live_router_with_stable_indices() {
        let mut r = mk();
        assert_eq!(r.variants().len(), 3);
        let i = r.push(Variant::new("pattern-16x", 1.0, 0.88));
        assert_eq!(i, 3);
        assert_eq!(&*r.variants()[3].name, "pattern-16x");
        // Existing indices are untouched and the new variant is
        // immediately routable under its own mask bit.
        assert_eq!(&*r.variants()[0].name, "dense");
        assert_eq!(r.select_masked(Sla::Realtime, 0b1000).unwrap(), 3);
        // Masked out, it is invisible: the old menu still routes as
        // before.
        assert_eq!(r.select_masked(Sla::Realtime, 0b0111).unwrap(), 2);
    }

    #[test]
    fn load_accounting_round_trips() {
        prop::check("router-load", 50, |g| {
            let r = mk();
            let n = g.usize(0, 20);
            let v = r.route(Sla::Standard).unwrap();
            for _ in 0..n {
                v.begin();
            }
            if v.load() != n as u64 {
                return Err("load mismatch".into());
            }
            for _ in 0..n {
                v.end();
            }
            if v.load() != 0 {
                return Err("load not drained".into());
            }
            Ok(())
        });
    }

    #[test]
    fn admission_sheds_standard_before_realtime() {
        let r = Router::new(vec![Variant::new("only", 5.0, 0.9)]);
        let cap = 8;
        // Below the soft watermark everyone is admitted.
        assert!(r.admit(Sla::Standard, 0, 3, cap).is_ok());
        assert!(r.admit(Sla::Realtime, 0, 3, cap).is_ok());
        // From the soft watermark (cap/2) only Realtime still enters.
        for depth in cap / 2..cap {
            assert!(matches!(
                r.admit(Sla::Standard, 0, depth, cap),
                Err(ServeError::Overloaded { .. })
            ));
            assert!(matches!(
                r.admit(Sla::Quality, 0, depth, cap),
                Err(ServeError::Overloaded { .. })
            ));
            assert!(r.admit(Sla::Realtime, 0, depth, cap).is_ok(),
                    "realtime must survive to the hard cap");
        }
        // Hard-full sheds every class.
        assert!(matches!(
            r.admit(Sla::Realtime, 0, cap, cap),
            Err(ServeError::Overloaded { .. })
        ));
    }

    #[test]
    fn latency_over_budget_sheds_non_realtime_when_queued() {
        let policy = SlaPolicy {
            realtime_budget_ms: Some(3.0),
            quality_floor: None,
        };
        let r = Router::with_policy(
            vec![Variant::new("slow", 20.0, 0.9)],
            policy,
        );
        // Empty queue: admitted even over budget (nothing to drain).
        assert!(r.admit(Sla::Standard, 0, 0, 64).is_ok());
        // Anything queued while the live latency exceeds the Realtime
        // budget: Standard sheds so Realtime keeps its headroom.
        assert!(matches!(
            r.admit(Sla::Standard, 0, 1, 64),
            Err(ServeError::Overloaded { .. })
        ));
        assert!(r.admit(Sla::Realtime, 0, 1, 64).is_ok());
    }

    #[test]
    fn retry_after_grows_with_queue_depth() {
        let mut last = 0u64;
        for depth in 0..200 {
            let hint = retry_after_ms(depth, 5.0);
            assert!(hint > last, "hint must grow with depth");
            last = hint;
        }
        // Unmeasured deployments still produce a finite positive hint.
        assert!(retry_after_ms(10, f64::INFINITY) >= 1);
        // And the typed error carries the hint through `admit`.
        let r = Router::new(vec![Variant::new("v", 5.0, 0.9)]);
        let e1 = r.admit(Sla::Standard, 0, 8, 8).unwrap_err();
        let e2 = r.admit(Sla::Standard, 0, 16, 8).unwrap_err();
        match (e1, e2) {
            (
                ServeError::Overloaded { retry_after_ms: a },
                ServeError::Overloaded { retry_after_ms: b },
            ) => assert!(b > a, "deeper queue must back off longer"),
            other => panic!("expected Overloaded pair, got {other:?}"),
        }
    }

    fn states(n: usize) -> Vec<Arc<BackendState>> {
        (0..n).map(|i| BackendState::new(&format!("b{i}"))).collect()
    }

    #[test]
    fn failover_skips_unhealthy() {
        let st = states(3);
        let mut r = BatchRouter::new(RouterPolicy::Failover, 3).unwrap();
        assert_eq!(r.pick(&st), 0);
        st[0].mark_unhealthy();
        assert_eq!(r.pick(&st), 1);
        st[1].mark_unhealthy();
        assert_eq!(r.pick(&st), 2);
        // All unhealthy: degraded mode falls back to declaration order
        // rather than dropping traffic.
        st[2].mark_unhealthy();
        assert_eq!(r.pick(&st), 0);
    }

    #[test]
    fn unhealthy_backend_recovers_after_cooldown() {
        let st = states(2);
        let mut r = BatchRouter::new(RouterPolicy::Failover, 2).unwrap();
        st[0].mark_unhealthy();
        assert_eq!(r.pick(&st), 1);
        // Each pick ticks the cooldown; eventually the primary is probed
        // again (half-open circuit breaker).
        let mut recovered = false;
        for _ in 0..UNHEALTHY_COOLDOWN + 1 {
            if r.pick(&st) == 0 {
                recovered = true;
                break;
            }
        }
        assert!(recovered, "backend never re-entered rotation");
    }

    #[test]
    fn split_tracks_weights() {
        let st = states(2);
        let mut r =
            BatchRouter::new(RouterPolicy::Split(vec![3.0, 1.0]), 2)
                .unwrap();
        let mut counts = [0usize; 2];
        for _ in 0..400 {
            counts[r.pick(&st)] += 1;
        }
        assert_eq!(counts[0] + counts[1], 400);
        assert!(
            (counts[0] as f64 / 400.0 - 0.75).abs() < 0.05,
            "split drifted: {counts:?}"
        );
    }

    #[test]
    fn split_redirects_when_backend_dies() {
        let st = states(2);
        let mut r =
            BatchRouter::new(RouterPolicy::Split(vec![1.0, 1.0]), 2)
                .unwrap();
        st[0].mark_unhealthy();
        for _ in 0..10 {
            assert_eq!(r.pick(&st), 1);
        }
    }

    #[test]
    fn least_loaded_avoids_busy_backend() {
        let st = states(2);
        let mut r = BatchRouter::new(RouterPolicy::LeastLoaded, 2).unwrap();
        st[0].begin();
        st[0].begin();
        assert_eq!(r.pick(&st), 1);
        st[1].begin();
        st[1].begin();
        st[1].begin();
        assert_eq!(r.pick(&st), 0);
    }

    #[test]
    fn degraded_split_follows_ascending_cooldown() {
        // b1 failed first (lower remaining cooldown), b0 most recently.
        // Degraded-mode Split must probe the least-recently-failed
        // backend, not fall back to declaration order.
        let st = states(2);
        let mut r =
            BatchRouter::new(RouterPolicy::Split(vec![1.0, 1.0]), 2)
                .unwrap();
        st[1].mark_unhealthy();
        for _ in 0..3 {
            assert_eq!(r.pick(&st), 0); // b0 still healthy
        }
        st[0].mark_unhealthy();
        // degraded: b1's penalty has decayed below b0's
        assert_eq!(r.pick(&st), 1);
    }

    #[test]
    fn degraded_least_loaded_breaks_ties_by_cooldown() {
        let st = states(2);
        let mut r = BatchRouter::new(RouterPolicy::LeastLoaded, 2).unwrap();
        st[1].mark_unhealthy();
        for _ in 0..3 {
            assert_eq!(r.pick(&st), 0);
        }
        st[0].mark_unhealthy();
        // equal load: the tie must go to the least cooldown (b1), not
        // declaration order (b0, the most-recently-failed backend).
        assert_eq!(r.pick(&st), 1);
        // an actual load difference still dominates
        st[1].begin();
        assert_eq!(r.pick(&st), 0);
        st[1].end();
    }

    #[test]
    fn degraded_split_accrues_no_credit() {
        // While no backend is in rotation, deficit counters must not
        // move: otherwise the never-picked backend banks credit and
        // absorbs a burst of consecutive batches once it recovers.
        let st = states(2);
        let mut r =
            BatchRouter::new(RouterPolicy::Split(vec![1.0, 1.0]), 2)
                .unwrap();
        st[0].mark_unhealthy();
        st[1].mark_unhealthy();
        for _ in 0..10 {
            r.pick(&st); // degraded picks
        }
        // Force both back into rotation.
        st[0].penalty.store(0, Ordering::SeqCst);
        st[1].penalty.store(0, Ordering::SeqCst);
        // With untouched counters an equal-weight split alternates
        // exactly; a banked deficit would hand one backend a run of
        // consecutive picks.
        let mut counts = [0usize; 2];
        let mut longest_run = 0usize;
        let mut run = 0usize;
        let mut last = usize::MAX;
        for _ in 0..20 {
            let p = r.pick(&st);
            counts[p] += 1;
            if p == last {
                run += 1;
            } else {
                run = 1;
                last = p;
            }
            longest_run = longest_run.max(run);
        }
        assert_eq!(counts, [10, 10], "degraded phase skewed the split");
        assert!(longest_run <= 1, "recovering backend absorbed a burst \
                                   of {longest_run} consecutive picks");
    }

    #[test]
    fn split_weights_validated() {
        assert!(BatchRouter::new(RouterPolicy::Split(vec![1.0]), 2)
            .is_err());
        assert!(
            BatchRouter::new(RouterPolicy::Split(vec![1.0, 0.0]), 2)
                .is_err()
        );
        assert!(BatchRouter::new(RouterPolicy::Failover, 0).is_err());
    }
}
