//! Request routing, at two granularities.
//!
//! **Variant routing** (the co-design story at serving time): CoCo-Gen
//! produces multiple deployment variants of the same model (dense,
//! pattern-pruned at several rates) with different latency/accuracy
//! points; [`Router`] picks a [`Variant`] per request according to its
//! SLA class and balances load across replicas
//! (least-outstanding-requests).
//!
//! **Batch routing** (the `Backend` seam): once the dynamic batcher has
//! formed a batch, [`BatchRouter`] decides which live backend executes
//! it — always-primary with hot standbys ([`RouterPolicy::Failover`]),
//! a weighted traffic split ([`RouterPolicy::Split`]), or least
//! outstanding batches ([`RouterPolicy::LeastLoaded`]). Health is
//! tracked per backend in [`BackendState`]: a backend whose
//! `infer_batch` fails is marked unhealthy and drops out of the
//! candidate set, which is what makes failover work.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{ensure, Result};

/// Request SLA class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sla {
    /// Minimize latency: route to the most-pruned (fastest) variant.
    Realtime,
    /// Balanced default.
    Standard,
    /// Maximize accuracy: dense variant.
    Quality,
}

/// One routable deployment variant.
pub struct Variant {
    pub name: String,
    /// Expected single-batch latency (ms) — from the tuner/bench.
    pub latency_ms: f64,
    /// Expected accuracy of this variant.
    pub accuracy: f64,
    outstanding: AtomicU64,
}

impl Variant {
    pub fn new(name: &str, latency_ms: f64, accuracy: f64) -> Variant {
        Variant {
            name: name.to_string(),
            latency_ms,
            accuracy,
            outstanding: AtomicU64::new(0),
        }
    }
    pub fn begin(&self) {
        self.outstanding.fetch_add(1, Ordering::Relaxed);
    }
    pub fn end(&self) {
        self.outstanding.fetch_sub(1, Ordering::Relaxed);
    }
    pub fn load(&self) -> u64 {
        self.outstanding.load(Ordering::Relaxed)
    }
}

/// The per-request variant router: SLA-filtered, least-loaded selection.
///
/// The per-SLA candidate sets depend only on the variant list, so they
/// are computed once at construction — [`Router::route`] on the request
/// hot path is an allocation-free scan over a precomputed slice.
pub struct Router {
    variants: Vec<Variant>,
    /// Precomputed candidate indices: fastest third.
    realtime: Vec<usize>,
    /// Precomputed candidate indices: most-accurate third.
    quality: Vec<usize>,
    /// Precomputed candidate indices: everything.
    standard: Vec<usize>,
}

impl Router {
    pub fn new(variants: Vec<Variant>) -> Router {
        assert!(!variants.is_empty());
        let n = variants.len();
        let k = n.div_ceil(3);
        let mut realtime: Vec<usize> = (0..n).collect();
        realtime.sort_by(|&a, &b| {
            variants[a]
                .latency_ms
                .partial_cmp(&variants[b].latency_ms)
                .unwrap()
        });
        realtime.truncate(k);
        let mut quality: Vec<usize> = (0..n).collect();
        quality.sort_by(|&a, &b| {
            variants[b]
                .accuracy
                .partial_cmp(&variants[a].accuracy)
                .unwrap()
        });
        quality.truncate(k);
        Router {
            variants,
            realtime,
            quality,
            standard: (0..n).collect(),
        }
    }

    /// Candidate set for an SLA class: Realtime = fastest third,
    /// Quality = most-accurate third, Standard = all. Precomputed at
    /// [`Router::new`] — no per-request allocation or sort.
    fn candidates(&self, sla: Sla) -> &[usize] {
        match sla {
            Sla::Realtime => &self.realtime,
            Sla::Quality => &self.quality,
            Sla::Standard => &self.standard,
        }
    }

    /// Pick a variant for `sla`: least outstanding load among candidates,
    /// ties broken by latency.
    pub fn route(&self, sla: Sla) -> &Variant {
        let best = self
            .candidates(sla)
            .iter()
            .copied()
            .min_by(|&a, &b| {
                let va = &self.variants[a];
                let vb = &self.variants[b];
                va.load()
                    .cmp(&vb.load())
                    .then(
                        va.latency_ms
                            .partial_cmp(&vb.latency_ms)
                            .unwrap(),
                    )
            })
            .unwrap();
        &self.variants[best]
    }

    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }
}

/// Cooldown after an infer failure, in routing decisions: the backend
/// re-enters the candidate set after this many picks (a half-open
/// circuit breaker — a flaky backend gets probed again instead of being
/// removed forever, and a transient error does not brick a
/// single-backend coordinator).
const UNHEALTHY_COOLDOWN: u64 = 32;

/// Live health/load state of one serving backend, shared between the
/// leader (which routes batches) and the backend's worker thread (which
/// reports failures).
pub struct BackendState {
    pub name: String,
    /// 0 = healthy; otherwise routing decisions left until recovery.
    penalty: AtomicU64,
    outstanding: AtomicU64,
    dispatched: AtomicU64,
}

impl BackendState {
    pub fn new(name: &str) -> Arc<BackendState> {
        Arc::new(BackendState {
            name: name.to_string(),
            penalty: AtomicU64::new(0),
            outstanding: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
        })
    }
    pub fn healthy(&self) -> bool {
        self.penalty.load(Ordering::SeqCst) == 0
    }
    /// An infer failure takes the backend out of rotation for
    /// `UNHEALTHY_COOLDOWN` routing decisions.
    pub fn mark_unhealthy(&self) {
        self.penalty.store(UNHEALTHY_COOLDOWN, Ordering::SeqCst);
    }
    /// One routing decision elapsed; unhealthy backends creep back
    /// toward rotation.
    fn decay(&self) {
        let _ = self.penalty.fetch_update(
            Ordering::SeqCst,
            Ordering::SeqCst,
            |p| if p > 0 { Some(p - 1) } else { None },
        );
    }
    /// A batch was dispatched to this backend.
    pub fn begin(&self) {
        self.outstanding.fetch_add(1, Ordering::SeqCst);
        self.dispatched.fetch_add(1, Ordering::SeqCst);
    }
    /// The batch finished (successfully or not).
    pub fn end(&self) {
        self.outstanding.fetch_sub(1, Ordering::SeqCst);
    }
    /// Batches dispatched and not yet finished.
    pub fn load(&self) -> u64 {
        self.outstanding.load(Ordering::SeqCst)
    }
    /// Total batches ever dispatched to this backend.
    pub fn dispatched(&self) -> u64 {
        self.dispatched.load(Ordering::SeqCst)
    }
}

/// How the leader spreads batches across backends.
#[derive(Debug, Clone)]
pub enum RouterPolicy {
    /// All traffic to the first *healthy* backend in declaration order;
    /// later backends are hot standbys that take over on failure.
    Failover,
    /// Split traffic across healthy backends proportionally to the given
    /// weights (deficit round-robin; one weight per backend, all > 0).
    Split(Vec<f64>),
    /// Send each batch to the healthy backend with the fewest
    /// outstanding batches, ties broken by declaration order.
    LeastLoaded,
}

/// Stateful batch router implementing a [`RouterPolicy`] over the
/// backends' shared [`BackendState`]s.
pub struct BatchRouter {
    policy: RouterPolicy,
    /// Deficit counters for `Split`.
    credit: Vec<f64>,
}

impl BatchRouter {
    pub fn new(policy: RouterPolicy, n_backends: usize)
               -> Result<BatchRouter> {
        ensure!(n_backends > 0, "router needs at least one backend");
        if let RouterPolicy::Split(w) = &policy {
            ensure!(
                w.len() == n_backends,
                "split weights ({}) must match backend count ({})",
                w.len(),
                n_backends
            );
            ensure!(
                w.iter().all(|x| *x > 0.0 && x.is_finite()),
                "split weights must be positive and finite"
            );
        }
        Ok(BatchRouter {
            policy,
            credit: vec![0.0; n_backends],
        })
    }

    /// Pick the backend for the next batch. Unhealthy backends are
    /// skipped while any healthy one remains; when none does, the
    /// policy runs over the full set ordered by ascending cooldown
    /// (degraded mode: attempting the least-recently-failed backend
    /// beats dropping traffic on the floor, and is what lets a sole
    /// backend recover from a transient error). Every policy honors
    /// that ordering — `LeastLoaded` breaks load ties by ascending
    /// penalty, and `Split` suspends deficit-round-robin accounting
    /// entirely while degraded (out-of-rotation backends must not
    /// accrue credit, or a recovering backend would absorb a burst of
    /// consecutive batches the moment it comes back). Each call also
    /// ticks every backend's cooldown.
    pub fn pick(&mut self, states: &[Arc<BackendState>]) -> usize {
        for s in states {
            s.decay();
        }
        let mut rotation: Vec<usize> = (0..states.len())
            .filter(|&i| states[i].healthy())
            .collect();
        let degraded = rotation.is_empty();
        if degraded {
            rotation = (0..states.len()).collect();
            // Stable sort: ascending cooldown, declaration order on
            // ties.
            rotation.sort_by_key(|&i| {
                states[i].penalty.load(Ordering::SeqCst)
            });
        }
        match &self.policy {
            RouterPolicy::Failover => rotation[0],
            RouterPolicy::LeastLoaded => rotation
                .iter()
                .copied()
                .min_by_key(|&i| {
                    let tie = if degraded {
                        states[i].penalty.load(Ordering::SeqCst)
                    } else {
                        0
                    };
                    (states[i].load(), tie, i)
                })
                .unwrap(),
            RouterPolicy::Split(w) => {
                if degraded {
                    // No backend is in rotation: probe by ascending
                    // cooldown and leave every deficit counter
                    // untouched.
                    return rotation[0];
                }
                // Deficit round-robin: in-rotation backends accrue
                // credit at their weight; the richest one serves and
                // pays the round's total, giving a `w`-proportional
                // long-run split that adapts when backends drop out.
                let total: f64 = rotation.iter().map(|&i| w[i]).sum();
                for &i in &rotation {
                    self.credit[i] += w[i];
                }
                let pick = rotation
                    .iter()
                    .copied()
                    .max_by(|&a, &b| {
                        self.credit[a]
                            .partial_cmp(&self.credit[b])
                            .unwrap()
                            .then(b.cmp(&a))
                    })
                    .unwrap();
                self.credit[pick] -= total;
                pick
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn mk() -> Router {
        Router::new(vec![
            Variant::new("dense", 10.0, 0.95),
            Variant::new("pattern-3x", 4.0, 0.93),
            Variant::new("pattern-8x", 2.0, 0.90),
        ])
    }

    #[test]
    fn realtime_prefers_fastest() {
        let r = mk();
        assert_eq!(r.route(Sla::Realtime).name, "pattern-8x");
    }

    #[test]
    fn quality_prefers_most_accurate() {
        let r = mk();
        assert_eq!(r.route(Sla::Quality).name, "dense");
    }

    #[test]
    fn standard_balances_by_load() {
        let r = mk();
        // Load up the fastest variant; Standard must avoid it.
        let fast = r.route(Sla::Realtime);
        fast.begin();
        fast.begin();
        let chosen = r.route(Sla::Standard);
        assert_ne!(chosen.name, "pattern-8x");
        fast.end();
        fast.end();
    }

    #[test]
    fn load_accounting_round_trips() {
        prop::check("router-load", 50, |g| {
            let r = mk();
            let n = g.usize(0, 20);
            let v = r.route(Sla::Standard);
            for _ in 0..n {
                v.begin();
            }
            if v.load() != n as u64 {
                return Err("load mismatch".into());
            }
            for _ in 0..n {
                v.end();
            }
            if v.load() != 0 {
                return Err("load not drained".into());
            }
            Ok(())
        });
    }

    fn states(n: usize) -> Vec<Arc<BackendState>> {
        (0..n).map(|i| BackendState::new(&format!("b{i}"))).collect()
    }

    #[test]
    fn failover_skips_unhealthy() {
        let st = states(3);
        let mut r = BatchRouter::new(RouterPolicy::Failover, 3).unwrap();
        assert_eq!(r.pick(&st), 0);
        st[0].mark_unhealthy();
        assert_eq!(r.pick(&st), 1);
        st[1].mark_unhealthy();
        assert_eq!(r.pick(&st), 2);
        // All unhealthy: degraded mode falls back to declaration order
        // rather than dropping traffic.
        st[2].mark_unhealthy();
        assert_eq!(r.pick(&st), 0);
    }

    #[test]
    fn unhealthy_backend_recovers_after_cooldown() {
        let st = states(2);
        let mut r = BatchRouter::new(RouterPolicy::Failover, 2).unwrap();
        st[0].mark_unhealthy();
        assert_eq!(r.pick(&st), 1);
        // Each pick ticks the cooldown; eventually the primary is probed
        // again (half-open circuit breaker).
        let mut recovered = false;
        for _ in 0..UNHEALTHY_COOLDOWN + 1 {
            if r.pick(&st) == 0 {
                recovered = true;
                break;
            }
        }
        assert!(recovered, "backend never re-entered rotation");
    }

    #[test]
    fn split_tracks_weights() {
        let st = states(2);
        let mut r =
            BatchRouter::new(RouterPolicy::Split(vec![3.0, 1.0]), 2)
                .unwrap();
        let mut counts = [0usize; 2];
        for _ in 0..400 {
            counts[r.pick(&st)] += 1;
        }
        assert_eq!(counts[0] + counts[1], 400);
        assert!(
            (counts[0] as f64 / 400.0 - 0.75).abs() < 0.05,
            "split drifted: {counts:?}"
        );
    }

    #[test]
    fn split_redirects_when_backend_dies() {
        let st = states(2);
        let mut r =
            BatchRouter::new(RouterPolicy::Split(vec![1.0, 1.0]), 2)
                .unwrap();
        st[0].mark_unhealthy();
        for _ in 0..10 {
            assert_eq!(r.pick(&st), 1);
        }
    }

    #[test]
    fn least_loaded_avoids_busy_backend() {
        let st = states(2);
        let mut r = BatchRouter::new(RouterPolicy::LeastLoaded, 2).unwrap();
        st[0].begin();
        st[0].begin();
        assert_eq!(r.pick(&st), 1);
        st[1].begin();
        st[1].begin();
        st[1].begin();
        assert_eq!(r.pick(&st), 0);
    }

    #[test]
    fn degraded_split_follows_ascending_cooldown() {
        // b1 failed first (lower remaining cooldown), b0 most recently.
        // Degraded-mode Split must probe the least-recently-failed
        // backend, not fall back to declaration order.
        let st = states(2);
        let mut r =
            BatchRouter::new(RouterPolicy::Split(vec![1.0, 1.0]), 2)
                .unwrap();
        st[1].mark_unhealthy();
        for _ in 0..3 {
            assert_eq!(r.pick(&st), 0); // b0 still healthy
        }
        st[0].mark_unhealthy();
        // degraded: b1's penalty has decayed below b0's
        assert_eq!(r.pick(&st), 1);
    }

    #[test]
    fn degraded_least_loaded_breaks_ties_by_cooldown() {
        let st = states(2);
        let mut r = BatchRouter::new(RouterPolicy::LeastLoaded, 2).unwrap();
        st[1].mark_unhealthy();
        for _ in 0..3 {
            assert_eq!(r.pick(&st), 0);
        }
        st[0].mark_unhealthy();
        // equal load: the tie must go to the least cooldown (b1), not
        // declaration order (b0, the most-recently-failed backend).
        assert_eq!(r.pick(&st), 1);
        // an actual load difference still dominates
        st[1].begin();
        assert_eq!(r.pick(&st), 0);
        st[1].end();
    }

    #[test]
    fn degraded_split_accrues_no_credit() {
        // While no backend is in rotation, deficit counters must not
        // move: otherwise the never-picked backend banks credit and
        // absorbs a burst of consecutive batches once it recovers.
        let st = states(2);
        let mut r =
            BatchRouter::new(RouterPolicy::Split(vec![1.0, 1.0]), 2)
                .unwrap();
        st[0].mark_unhealthy();
        st[1].mark_unhealthy();
        for _ in 0..10 {
            r.pick(&st); // degraded picks
        }
        // Force both back into rotation.
        st[0].penalty.store(0, Ordering::SeqCst);
        st[1].penalty.store(0, Ordering::SeqCst);
        // With untouched counters an equal-weight split alternates
        // exactly; a banked deficit would hand one backend a run of
        // consecutive picks.
        let mut counts = [0usize; 2];
        let mut longest_run = 0usize;
        let mut run = 0usize;
        let mut last = usize::MAX;
        for _ in 0..20 {
            let p = r.pick(&st);
            counts[p] += 1;
            if p == last {
                run += 1;
            } else {
                run = 1;
                last = p;
            }
            longest_run = longest_run.max(run);
        }
        assert_eq!(counts, [10, 10], "degraded phase skewed the split");
        assert!(longest_run <= 1, "recovering backend absorbed a burst \
                                   of {longest_run} consecutive picks");
    }

    #[test]
    fn split_weights_validated() {
        assert!(BatchRouter::new(RouterPolicy::Split(vec![1.0]), 2)
            .is_err());
        assert!(
            BatchRouter::new(RouterPolicy::Split(vec![1.0, 0.0]), 2)
                .is_err()
        );
        assert!(BatchRouter::new(RouterPolicy::Failover, 0).is_err());
    }
}
