//! Dynamic batcher: groups incoming requests into fixed-capacity batches,
//! flushing on either a full batch or a deadline — the standard serving
//! trade between throughput (big batches) and tail latency (short waits).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy: when the dynamic batcher flushes a batch to a
/// backend.
///
/// ```
/// use std::time::Duration;
/// use cocopie::coordinator::BatchPolicy;
///
/// // Throughput-leaning: big batches, a little extra queueing latency.
/// let policy = BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(10) };
/// assert!(policy.max_batch > BatchPolicy::default().max_batch);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush when this many requests are pending.
    pub max_batch: usize,
    /// Flush a non-empty batch this long after its first request
    /// *arrived* (its enqueue timestamp — not when the batcher got
    /// around to reading it, so time a request already spent queued
    /// behind failover retries counts against the deadline).
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// One step of a polling batch loop (see [`next_batch_step`]).
pub enum BatchStep<T> {
    /// A batch formed under the policy.
    Batch(Vec<T>),
    /// No request arrived within the idle window; the caller can service
    /// other work (e.g. failover retries) and poll again.
    Idle,
    /// The channel is closed and drained.
    Closed,
}

/// Pull one batch from `rx` under `policy`; `enqueued` reports when an
/// item first entered the queue, anchoring the `max_wait` deadline (a
/// request that already sat in the channel — e.g. while the leader
/// serviced failover retries — must not wait the full `max_wait` again).
/// Returns None when the channel is closed and drained.
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy,
                     enqueued: impl Fn(&T) -> Instant)
                     -> Option<Vec<T>> {
    // Block for the first element.
    let first = rx.recv().ok()?;
    let deadline = enqueued(&first) + policy.max_wait;
    Some(fill_batch(rx, policy, first, deadline))
}

/// Like [`next_batch`], but waits at most `idle` for the first request so
/// the caller's loop can interleave other work. The serving leader uses
/// this to service failover retries while the request queue is quiet.
pub fn next_batch_step<T>(rx: &Receiver<T>, policy: &BatchPolicy,
                          idle: Duration,
                          enqueued: impl Fn(&T) -> Instant)
                          -> BatchStep<T> {
    let first = match rx.recv_timeout(idle) {
        Ok(item) => item,
        Err(RecvTimeoutError::Timeout) => return BatchStep::Idle,
        Err(RecvTimeoutError::Disconnected) => return BatchStep::Closed,
    };
    let deadline = enqueued(&first) + policy.max_wait;
    BatchStep::Batch(fill_batch(rx, policy, first, deadline))
}

/// Accumulate onto `first` until the batch is full or `deadline`
/// (anchored at the first item's enqueue time) hits. A deadline that
/// has already passed still drains whatever is immediately available —
/// a backlogged queue must keep forming full batches, it just stops
/// *waiting* for more.
fn fill_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy, first: T,
                 deadline: Instant) -> Vec<T> {
    let mut batch = vec![first];
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            while batch.len() < policy.max_batch {
                match rx.try_recv() {
                    Ok(item) => batch.push(item),
                    Err(_) => break,
                }
            }
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    batch
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    /// Enqueue-timestamp accessor for tests over plain values: "arrived
    /// just now", the pre-fix behavior.
    fn fresh<T>(_: &T) -> Instant {
        Instant::now()
    }

    #[test]
    fn flushes_full_batch_immediately() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
        };
        let b = next_batch(&rx, &policy, fresh).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = next_batch(&rx, &policy, fresh).unwrap();
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn flushes_partial_batch_on_deadline() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
        };
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy, fresh).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn returns_none_on_closed_channel() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default(), fresh).is_none());
    }

    #[test]
    fn step_reports_idle_then_batch_then_closed() {
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        };
        let idle = Duration::from_millis(5);
        assert!(matches!(next_batch_step(&rx, &policy, idle, fresh),
                         BatchStep::Idle));
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        match next_batch_step(&rx, &policy, idle, fresh) {
            BatchStep::Batch(b) => assert_eq!(b, vec![1, 2]),
            _ => panic!("expected a batch"),
        }
        drop(tx);
        assert!(matches!(next_batch_step(&rx, &policy, idle, fresh),
                         BatchStep::Closed));
    }

    #[test]
    fn drains_after_close() {
        let (tx, rx) = mpsc::channel();
        tx.send(7).unwrap();
        drop(tx);
        let b = next_batch(&rx, &BatchPolicy::default(), fresh).unwrap();
        assert_eq!(b, vec![7]);
        assert!(next_batch(&rx, &BatchPolicy::default(), fresh).is_none());
    }

    #[test]
    fn pre_aged_request_does_not_wait_max_wait_again() {
        // Regression: the deadline is anchored at the request's enqueue
        // time. A request that already sat in the channel longer than
        // max_wait (e.g. while the leader serviced failover retries)
        // flushes immediately instead of waiting max_wait a second time.
        let (tx, rx) = mpsc::channel();
        let max_wait = Duration::from_millis(200);
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait,
        };
        let aged = Instant::now() - 2 * max_wait;
        tx.send(("old", aged)).unwrap();
        tx.send(("queued-behind-it", aged)).unwrap();
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy, |r: &(&str, Instant)| r.1)
            .unwrap();
        let took = t0.elapsed();
        // Both queued items flush (an expired deadline still drains the
        // backlog), and nothing waits for the 200 ms window.
        assert_eq!(b.len(), 2);
        assert!(
            took < max_wait / 2,
            "expired deadline still waited {took:?}"
        );
    }

    #[test]
    fn fresh_request_still_gets_its_full_window() {
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(150),
        };
        let t0 = Instant::now();
        tx.send(((), Instant::now())).unwrap();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            let _ = tx.send(((), Instant::now()));
        });
        let b = next_batch(&rx, &policy, |r: &((), Instant)| r.1)
            .unwrap();
        // The late arrival lands inside the window anchored at the
        // first request's enqueue time.
        assert_eq!(b.len(), 2);
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }
}
