//! Dynamic batcher: groups incoming requests into fixed-capacity batches,
//! flushing on either a full batch or a deadline — the standard serving
//! trade between throughput (big batches) and tail latency (short waits).
//!
//! The leader forms batches with the [`ShardBatcher`]: one shard per
//! named deployment, each accumulating its own batch with its own
//! deadline, because a batch must be executable by one compiled
//! pipeline.

use std::time::{Duration, Instant};

/// Batching policy: when the dynamic batcher flushes a batch to a
/// backend.
///
/// ```
/// use std::time::Duration;
/// use cocopie::coordinator::BatchPolicy;
///
/// // Throughput-leaning: big batches, a little extra queueing latency.
/// let policy = BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(10) };
/// assert!(policy.max_batch > BatchPolicy::default().max_batch);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush when this many requests are pending.
    pub max_batch: usize,
    /// Flush a non-empty batch this long after its first request
    /// *arrived* (its enqueue timestamp — not when the batcher got
    /// around to reading it, so time a request already spent queued
    /// behind failover retries counts against the deadline).
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// Per-shard batch accumulation under one [`BatchPolicy`]: the
/// multi-deployment leader routes each request to a deployment (shard),
/// pushes it here, and flushes a shard's batch when it fills
/// ([`ShardBatcher::push`] returns it) or when its deadline — anchored
/// at the shard's *first* request's enqueue time, so time a request
/// already spent queued (e.g. behind failover retries) counts against
/// `max_wait` — expires ([`ShardBatcher::take_expired`]).
pub struct ShardBatcher<T> {
    max_batch: usize,
    max_wait: Duration,
    shards: Vec<Shard<T>>,
}

struct Shard<T> {
    items: Vec<T>,
    deadline: Option<Instant>,
}

impl<T> ShardBatcher<T> {
    pub fn new(n_shards: usize, policy: BatchPolicy) -> ShardBatcher<T> {
        ShardBatcher {
            max_batch: policy.max_batch.max(1),
            max_wait: policy.max_wait,
            shards: (0..n_shards)
                .map(|_| Shard {
                    items: Vec::new(),
                    deadline: None,
                })
                .collect(),
        }
    }

    /// Queue `item` on `shard`; returns the shard's full batch when
    /// this push fills it. A shard's deadline anchors at its first
    /// item's `enqueued` time (a pre-aged request flushes on the next
    /// [`ShardBatcher::take_expired`] instead of waiting `max_wait`
    /// again).
    pub fn push(&mut self, shard: usize, item: T, enqueued: Instant)
                -> Option<Vec<T>> {
        let s = &mut self.shards[shard];
        if s.items.is_empty() {
            s.deadline = Some(enqueued + self.max_wait);
        }
        s.items.push(item);
        if s.items.len() >= self.max_batch {
            s.deadline = None;
            Some(std::mem::take(&mut s.items))
        } else {
            None
        }
    }

    /// The earliest pending deadline across shards — how long the
    /// leader may block waiting for new requests.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.shards.iter().filter_map(|s| s.deadline).min()
    }

    /// Flush every shard whose deadline has passed.
    pub fn take_expired(&mut self, now: Instant)
                        -> Vec<(usize, Vec<T>)> {
        self.take_where(|s| s.deadline.is_some_and(|d| d <= now))
    }

    /// Flush everything (shutdown drain).
    pub fn drain(&mut self) -> Vec<(usize, Vec<T>)> {
        self.take_where(|s| !s.items.is_empty())
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.items.is_empty())
    }

    fn take_where(&mut self, pred: impl Fn(&Shard<T>) -> bool)
                  -> Vec<(usize, Vec<T>)> {
        let mut out = Vec::new();
        for (i, s) in self.shards.iter_mut().enumerate() {
            if !s.items.is_empty() && pred(s) {
                s.deadline = None;
                out.push((i, std::mem::take(&mut s.items)));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_batcher_fills_and_flushes_per_shard() {
        let policy = BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        };
        let mut b: ShardBatcher<u32> = ShardBatcher::new(2, policy);
        let now = Instant::now();
        assert!(b.push(0, 1, now).is_none());
        assert!(b.push(1, 10, now).is_none());
        assert!(b.push(0, 2, now).is_none());
        // Shard 0 fills independently of shard 1.
        assert_eq!(b.push(0, 3, now), Some(vec![1, 2, 3]));
        assert!(!b.is_empty(), "shard 1 still holds its item");
        assert_eq!(b.drain(), vec![(1, vec![10])]);
        assert!(b.is_empty());
    }

    #[test]
    fn full_shard_resets_its_deadline() {
        let policy = BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(50),
        };
        let mut b: ShardBatcher<u32> = ShardBatcher::new(1, policy);
        let now = Instant::now();
        b.push(0, 1, now);
        assert!(b.next_deadline().is_some());
        assert!(b.push(0, 2, now).is_some());
        // The flushed shard must not keep a stale deadline that would
        // wake the leader (or double-flush) later.
        assert!(b.next_deadline().is_none());
        assert!(b.take_expired(now + Duration::from_secs(1)).is_empty());
    }

    #[test]
    fn shard_deadline_anchors_at_first_enqueue() {
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(200),
        };
        let mut b: ShardBatcher<&str> = ShardBatcher::new(2, policy);
        let now = Instant::now();
        // A pre-aged request (it sat queued behind failover retries
        // longer than max_wait) must flush on the next sweep, not wait
        // the full window again.
        b.push(0, "old", now - Duration::from_millis(400));
        b.push(1, "fresh", now);
        assert_eq!(b.take_expired(now), vec![(0, vec!["old"])]);
        // The fresh shard keeps its (future) deadline: a fresh request
        // still gets its full batching window.
        let dl = b.next_deadline().expect("fresh shard has a deadline");
        assert!(dl > now && dl <= now + Duration::from_millis(200));
        assert!(b.take_expired(now).is_empty());
        assert_eq!(b.take_expired(dl), vec![(1, vec!["fresh"])]);
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn later_pushes_do_not_move_the_deadline() {
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(100),
        };
        let mut b: ShardBatcher<u32> = ShardBatcher::new(1, policy);
        let t0 = Instant::now();
        b.push(0, 1, t0);
        let dl = b.next_deadline().unwrap();
        // A second request arriving later joins the same window.
        b.push(0, 2, t0 + Duration::from_millis(60));
        assert_eq!(b.next_deadline(), Some(dl),
                   "deadline must stay anchored at the first request");
        assert_eq!(b.take_expired(dl), vec![(0, vec![1, 2])]);
    }
}
