//! Dynamic batcher: groups incoming requests into fixed-capacity batches,
//! flushing on either a full batch or a deadline — the standard serving
//! trade between throughput (big batches) and tail latency (short waits).
//!
//! The leader forms batches with the [`ShardBatcher`]: one shard per
//! named deployment, each accumulating its own batch with its own
//! deadline, because a batch must be executable by one compiled
//! pipeline.

use std::time::{Duration, Instant};

/// Batching policy: when the dynamic batcher flushes a batch to a
/// backend.
///
/// ```
/// use std::time::Duration;
/// use cocopie::coordinator::BatchPolicy;
///
/// // Throughput-leaning: big batches, a little extra queueing latency.
/// let policy = BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(10) };
/// assert!(policy.max_batch > BatchPolicy::default().max_batch);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush when this many requests are pending.
    pub max_batch: usize,
    /// Flush a non-empty batch this long after its first request
    /// *arrived* (its enqueue timestamp — not when the batcher got
    /// around to reading it, so time a request already spent queued
    /// behind failover retries counts against the deadline).
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// Outcome of one [`ShardBatcher::push`].
#[derive(Debug, PartialEq)]
pub enum Push<T> {
    /// The push filled the shard; its whole batch comes back.
    Full(Vec<T>),
    /// Queued; the shard waits for more items or its deadline.
    Queued,
    /// The shard is at its queue cap; the item is handed back so the
    /// caller can shed it with a typed error.
    Shed(T),
}

/// Per-shard batch accumulation under one [`BatchPolicy`]: the
/// multi-deployment leader routes each request to a deployment (shard),
/// pushes it here, and flushes a shard's batch when it fills
/// ([`ShardBatcher::push`] returns it) or when its deadline — anchored
/// at the shard's *first* request's enqueue time, so time a request
/// already spent queued (e.g. behind failover retries) counts against
/// `max_wait` — expires ([`ShardBatcher::take_expired`]).
///
/// Each shard's queue is bounded by [`ShardBatcher::with_queue_cap`]:
/// a push into a full shard returns [`Push::Shed`] *without* arming the
/// shard's deadline, so an interval in which every request is shed
/// leaves no pending deadline and the leader parks on its receive
/// timeout instead of busy-looping on phantom wakeups.
pub struct ShardBatcher<T> {
    max_batch: usize,
    max_wait: Duration,
    queue_cap: usize,
    shards: Vec<Shard<T>>,
}

struct Shard<T> {
    items: Vec<T>,
    deadline: Option<Instant>,
}

impl<T> ShardBatcher<T> {
    /// Unbounded shards (no admission at the batcher layer).
    pub fn new(n_shards: usize, policy: BatchPolicy) -> ShardBatcher<T> {
        Self::with_queue_cap(n_shards, policy, usize::MAX)
    }

    /// Shards bounded at `queue_cap` pending items each; a push past
    /// the cap returns [`Push::Shed`].
    pub fn with_queue_cap(n_shards: usize, policy: BatchPolicy,
                          queue_cap: usize) -> ShardBatcher<T> {
        ShardBatcher {
            max_batch: policy.max_batch.max(1),
            max_wait: policy.max_wait,
            queue_cap,
            shards: (0..n_shards)
                .map(|_| Shard {
                    items: Vec::new(),
                    deadline: None,
                })
                .collect(),
        }
    }

    /// Queue `item` on `shard`; returns [`Push::Full`] with the whole
    /// batch when this push fills it, [`Push::Shed`] handing the item
    /// back when the shard is at its queue cap. A shard's deadline
    /// anchors at its first item's `enqueued` time (a pre-aged request
    /// flushes on the next [`ShardBatcher::take_expired`] instead of
    /// waiting `max_wait` again).
    pub fn push(&mut self, shard: usize, item: T, enqueued: Instant)
                -> Push<T> {
        let s = &mut self.shards[shard];
        // The cap check precedes deadline arming: a shed push into an
        // empty shard (queue_cap == 0, or a full shed storm) must not
        // leave a deadline on a shard with nothing to flush — that
        // stale deadline would wake the leader every sweep and spin it.
        if s.items.len() >= self.queue_cap {
            return Push::Shed(item);
        }
        if s.items.is_empty() {
            s.deadline = Some(enqueued + self.max_wait);
        }
        s.items.push(item);
        if s.items.len() >= self.max_batch {
            s.deadline = None;
            Push::Full(std::mem::take(&mut s.items))
        } else {
            Push::Queued
        }
    }

    /// Grow the batcher by one empty shard (a deployment registered on
    /// a *running* coordinator); returns the new shard's index. Shard
    /// indices are stable: existing shards never move.
    pub fn add_shard(&mut self) -> usize {
        self.shards.push(Shard {
            items: Vec::new(),
            deadline: None,
        });
        self.shards.len() - 1
    }

    /// Flush one shard unconditionally — the retire drain: a
    /// deployment leaving the menu hands its queued batch to the
    /// dispatcher instead of dropping it. `None` when the shard holds
    /// nothing. Clears the shard's deadline either way.
    pub fn take_shard(&mut self, shard: usize) -> Option<Vec<T>> {
        let s = &mut self.shards[shard];
        s.deadline = None;
        if s.items.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut s.items))
        }
    }

    /// Pending (queued, not yet dispatched) items on `shard` — the
    /// admission controller's live congestion signal.
    pub fn depth(&self, shard: usize) -> usize {
        self.shards[shard].items.len()
    }

    /// The earliest pending deadline across shards — how long the
    /// leader may block waiting for new requests.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.shards.iter().filter_map(|s| s.deadline).min()
    }

    /// Flush every shard whose deadline has passed.
    pub fn take_expired(&mut self, now: Instant)
                        -> Vec<(usize, Vec<T>)> {
        self.take_where(|s| s.deadline.is_some_and(|d| d <= now))
    }

    /// Flush everything (shutdown drain).
    pub fn drain(&mut self) -> Vec<(usize, Vec<T>)> {
        self.take_where(|s| !s.items.is_empty())
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.items.is_empty())
    }

    fn take_where(&mut self, pred: impl Fn(&Shard<T>) -> bool)
                  -> Vec<(usize, Vec<T>)> {
        let mut out = Vec::new();
        for (i, s) in self.shards.iter_mut().enumerate() {
            if !s.items.is_empty() && pred(s) {
                s.deadline = None;
                out.push((i, std::mem::take(&mut s.items)));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_batcher_fills_and_flushes_per_shard() {
        let policy = BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        };
        let mut b: ShardBatcher<u32> = ShardBatcher::new(2, policy);
        let now = Instant::now();
        assert_eq!(b.push(0, 1, now), Push::Queued);
        assert_eq!(b.push(1, 10, now), Push::Queued);
        assert_eq!(b.push(0, 2, now), Push::Queued);
        // Shard 0 fills independently of shard 1.
        assert_eq!(b.push(0, 3, now), Push::Full(vec![1, 2, 3]));
        assert!(!b.is_empty(), "shard 1 still holds its item");
        assert_eq!(b.depth(0), 0);
        assert_eq!(b.depth(1), 1);
        assert_eq!(b.drain(), vec![(1, vec![10])]);
        assert!(b.is_empty());
    }

    #[test]
    fn full_shard_resets_its_deadline() {
        let policy = BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(50),
        };
        let mut b: ShardBatcher<u32> = ShardBatcher::new(1, policy);
        let now = Instant::now();
        b.push(0, 1, now);
        assert!(b.next_deadline().is_some());
        assert!(matches!(b.push(0, 2, now), Push::Full(_)));
        // The flushed shard must not keep a stale deadline that would
        // wake the leader (or double-flush) later.
        assert!(b.next_deadline().is_none());
        assert!(b.take_expired(now + Duration::from_secs(1)).is_empty());
    }

    #[test]
    fn shard_deadline_anchors_at_first_enqueue() {
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(200),
        };
        let mut b: ShardBatcher<&str> = ShardBatcher::new(2, policy);
        let now = Instant::now();
        // A pre-aged request (it sat queued behind failover retries
        // longer than max_wait) must flush on the next sweep, not wait
        // the full window again.
        b.push(0, "old", now - Duration::from_millis(400));
        b.push(1, "fresh", now);
        assert_eq!(b.take_expired(now), vec![(0, vec!["old"])]);
        // The fresh shard keeps its (future) deadline: a fresh request
        // still gets its full batching window.
        let dl = b.next_deadline().expect("fresh shard has a deadline");
        assert!(dl > now && dl <= now + Duration::from_millis(200));
        assert!(b.take_expired(now).is_empty());
        assert_eq!(b.take_expired(dl), vec![(1, vec!["fresh"])]);
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn later_pushes_do_not_move_the_deadline() {
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(100),
        };
        let mut b: ShardBatcher<u32> = ShardBatcher::new(1, policy);
        let t0 = Instant::now();
        b.push(0, 1, t0);
        let dl = b.next_deadline().unwrap();
        // A second request arriving later joins the same window.
        b.push(0, 2, t0 + Duration::from_millis(60));
        assert_eq!(b.next_deadline(), Some(dl),
                   "deadline must stay anchored at the first request");
        assert_eq!(b.take_expired(dl), vec![(0, vec![1, 2])]);
    }

    #[test]
    fn capped_shard_sheds_and_hands_the_item_back() {
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(100),
        };
        let mut b: ShardBatcher<u32> =
            ShardBatcher::with_queue_cap(1, policy, 2);
        let now = Instant::now();
        assert_eq!(b.push(0, 1, now), Push::Queued);
        assert_eq!(b.push(0, 2, now), Push::Queued);
        assert_eq!(b.depth(0), 2);
        // At the cap: the item comes back untouched for typed shedding.
        assert_eq!(b.push(0, 3, now), Push::Shed(3));
        assert_eq!(b.depth(0), 2, "a shed push must not grow the shard");
        // The queued batch still flushes normally on its deadline.
        let dl = b.next_deadline().unwrap();
        assert_eq!(b.take_expired(dl), vec![(0, vec![1, 2])]);
    }

    #[test]
    fn add_shard_extends_without_disturbing_existing_shards() {
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(100),
        };
        let mut b: ShardBatcher<u32> = ShardBatcher::new(1, policy);
        let now = Instant::now();
        b.push(0, 1, now);
        let dl = b.next_deadline().unwrap();
        // Live registration: the new shard appends; shard 0's queue
        // and deadline are untouched.
        assert_eq!(b.add_shard(), 1);
        assert_eq!(b.depth(0), 1);
        assert_eq!(b.depth(1), 0);
        assert_eq!(b.next_deadline(), Some(dl));
        assert_eq!(b.push(1, 10, now), Push::Queued);
        assert_eq!(b.take_expired(dl), vec![(0, vec![1]), (1, vec![10])]);
    }

    #[test]
    fn take_shard_drains_one_shard_and_clears_its_deadline() {
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(100),
        };
        let mut b: ShardBatcher<u32> = ShardBatcher::new(2, policy);
        let now = Instant::now();
        b.push(0, 1, now);
        b.push(0, 2, now);
        b.push(1, 10, now);
        // Retire drain: the retiring shard's queue comes back whole —
        // drained, not dropped — and its deadline is gone so the
        // leader never re-wakes for the dead shard.
        assert_eq!(b.take_shard(0), Some(vec![1, 2]));
        assert_eq!(b.take_shard(0), None, "second drain finds nothing");
        assert_eq!(b.depth(0), 0);
        // The surviving shard keeps its queue and deadline.
        assert_eq!(b.depth(1), 1);
        let dl = b.next_deadline().expect("survivor keeps its deadline");
        assert_eq!(b.take_expired(dl), vec![(1, vec![10])]);
    }

    #[test]
    fn full_shed_interval_leaves_no_deadline_to_spin_on() {
        // Regression: with a zero-capacity queue every push sheds. The
        // old code armed the deadline before the cap check, leaving an
        // empty shard with a pending deadline — next_deadline() would
        // then report an already-expired instant forever while
        // take_expired() flushed nothing, so the leader woke every
        // sweep and busy-looped. A fully-shed interval must leave the
        // batcher with no deadline at all so the leader parks.
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        };
        let mut b: ShardBatcher<u32> =
            ShardBatcher::with_queue_cap(2, policy, 0);
        let now = Instant::now();
        for i in 0..16 {
            assert_eq!(b.push((i % 2) as usize, i, now), Push::Shed(i));
        }
        assert!(b.is_empty());
        assert_eq!(b.next_deadline(), None,
                   "shed-only traffic must not arm a deadline");
        assert!(b.take_expired(now + Duration::from_secs(1)).is_empty());
    }
}
