//! The `Backend` seam: anything that can compile a model once and then
//! serve `HostTensor` batches can sit behind the coordinator.
//!
//! Two implementations ship today: [`PjrtBackend`] (the AOT-compiled
//! XLA/PJRT runtime path) and [`NativeBackend`] (the co-designed path
//! this repo is about — a pattern-pruned `ExecPlan` served by a pool of
//! native `ModelExecutor` workers). The coordinator treats them
//! identically: batches in, logits out, failures rerouted by the batch
//! router. Every future scaling PR (sharding, admission control, more
//! backends) plugs in at this trait.

use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::codegen::ExecPlan;
use crate::exec::{ElasticConfig, ExecutorPool, ModelExecutor, ScaleLog,
                  Tensor};
use crate::runtime::{DeviceInputs, Executable, HostTensor, Runtime};
use crate::util::threadpool;

use super::ServeConfig;

/// What the coordinator needs to know about a compiled model: the
/// per-image feed shape and the logit width.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSignature {
    /// Per-image input shape `[h, w, c]` — images are submitted as
    /// flattened NHWC rows, matching the AOT artifacts' feed layout.
    pub input_shape: Vec<usize>,
    /// Number of output classes (logits per image).
    pub classes: usize,
}

impl ModelSignature {
    /// Flattened elements per image.
    pub fn image_elems(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// A serving engine the coordinator can route batches to.
///
/// Lifecycle: the coordinator moves each backend onto a dedicated worker
/// thread, calls [`Backend::compile`] there exactly once (PJRT handles
/// are thread-affine, so compilation must happen on the owning thread),
/// then feeds it [`Backend::infer_batch`] calls until shutdown. A
/// returned error marks the backend unhealthy and the batch fails over
/// to the next backend in the router's rotation.
///
/// ```
/// use anyhow::Result;
/// use cocopie::coordinator::{Backend, ModelSignature};
/// use cocopie::runtime::HostTensor;
///
/// /// A backend that scores every image as class 0.
/// struct Constant;
///
/// impl Backend for Constant {
///     fn name(&self) -> &str {
///         "constant"
///     }
///     fn compile(&mut self, _max_batch: usize) -> Result<ModelSignature> {
///         Ok(ModelSignature { input_shape: vec![4, 4, 1], classes: 2 })
///     }
///     fn infer_batch(&mut self, images: &HostTensor) -> Result<HostTensor> {
///         let n = images.shape()[0];
///         Ok(HostTensor::f32(&[n, 2], [1.0f32, 0.0].repeat(n)))
///     }
/// }
///
/// let mut be = Constant;
/// let sig = be.compile(8).unwrap();
/// let logits = be
///     .infer_batch(&HostTensor::zeros(&[3, 4, 4, 1]))
///     .unwrap();
/// assert_eq!(logits.shape(), &[3, sig.classes]);
/// ```
pub trait Backend: Send {
    /// Stable display name (metrics labels, `Prediction::backend`).
    fn name(&self) -> &str;

    /// Prepare to serve batches of up to `max_batch` images. Called once
    /// on the worker thread that owns this backend, before any traffic.
    fn compile(&mut self, max_batch: usize) -> Result<ModelSignature>;

    /// Run one batch: `images` is `[n, h, w, c]` (NHWC, `n <= max_batch`,
    /// unpadded); returns logits `[n, classes]`. Backends that compiled
    /// for a fixed batch (PJRT) pad internally and slice the result.
    fn infer_batch(&mut self, images: &HostTensor) -> Result<HostTensor>;

    /// Congestion hint from the coordinator: the deployment's queue
    /// depth (requests admitted and not yet served) observed when the
    /// batch now arriving was dispatched. Called on the worker thread
    /// before each [`Backend::infer_batch`]. Elastic backends feed it
    /// to their pool's watermark controller
    /// ([`crate::exec::ExecutorPool::observe_queue_depth`]); the
    /// default ignores it.
    fn queue_hint(&mut self, _depth: usize) {}
}

/// Convert one flattened NHWC image into the planar CHW [`Tensor`] the
/// native engines consume.
pub fn nhwc_to_chw(img: &[f32], h: usize, w: usize, c: usize) -> Tensor {
    let mut t = Tensor::zeros(c, h, w);
    nhwc_to_chw_into(img, h, w, c, &mut t.data);
    t
}

/// [`nhwc_to_chw`] writing into a preassigned CHW slice — the fused
/// serving path converts straight into its packed `[N][C][H][W]` batch
/// buffer, with no per-image `Tensor` intermediate.
pub fn nhwc_to_chw_into(img: &[f32], h: usize, w: usize, c: usize,
                        out: &mut [f32]) {
    assert_eq!(img.len(), h * w * c, "image length mismatch");
    assert_eq!(out.len(), h * w * c, "output length mismatch");
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c {
                out[(ch * h + y) * w + x] = img[(y * w + x) * c + ch];
            }
        }
    }
}

/// How [`NativeBackend::infer_batch`] executes a routed batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NativeBatchMode {
    /// Fused batched pipeline for batches of 2 or more; per-image pool
    /// fan-out for singletons. The default.
    Auto,
    /// Always the fused batched pipeline (singletons included).
    Fused,
    /// Always per-image fan-out across the executor pool — the
    /// pre-batched behavior, kept for comparison and for machines where
    /// per-image parallelism wins (e.g. many idle cores, tiny models).
    FanOut,
}

/// The co-designed native path: a pattern-pruned [`ExecPlan`] served by
/// an [`ExecutorPool`] — one single-threaded `ModelExecutor` per core —
/// so live traffic runs on the FKW/CSR/Winograd engines with no PJRT (or
/// Python) anywhere on the request path. `compile()` builds the
/// execution paths the configured [`NativeBatchMode`] can reach (both
/// under `Auto`), sharing every weight `Arc`: the single-image pipeline
/// the pool fans out over, and a batch-compiled pipeline
/// (`ExecPlan::compile_batched`) whose fused walk streams each layer's
/// weights once per *batch* — at batch 8 that is 1/8 of the fan-out
/// path's weight traffic. Numerics are bit-identical to a direct
/// `ModelExecutor::run` on the same image either way.
pub struct NativeBackend {
    name: String,
    plan: Arc<ExecPlan>,
    workers: usize,
    mode: NativeBatchMode,
    classes: usize,
    pool: Option<ExecutorPool>,
    /// Batch-compiled executor for the fused path (multi-threaded: the
    /// whole batch runs as one walk, so intra-layer parallelism uses
    /// the cores the fan-out path would have spread images over).
    fused: Option<ModelExecutor>,
    /// Reusable packed `[N][C][H][W]` staging buffer for the fused
    /// path's NHWC conversion (warm after the first batch).
    packed: Vec<f32>,
    /// When set, `compile()` builds the fan-out pool elastic under this
    /// config instead of a fixed-width one.
    elastic: Option<ElasticConfig>,
    /// Scale-event record shared with the pool — created eagerly so
    /// callers can keep a handle ([`NativeBackend::scale_log`]) before
    /// registration consumes the backend.
    scale_log: Arc<ScaleLog>,
}

impl NativeBackend {
    /// Backend over a shared plan with one pool worker per core.
    pub fn new(name: &str, plan: Arc<ExecPlan>) -> NativeBackend {
        Self::with_workers(name, plan, threadpool::default_threads())
    }

    /// Backend with an explicit pool width (clamped to at least 1).
    pub fn with_workers(name: &str, plan: Arc<ExecPlan>, workers: usize)
                        -> NativeBackend {
        NativeBackend {
            name: name.to_string(),
            plan,
            workers: workers.max(1),
            mode: NativeBatchMode::Auto,
            classes: 0,
            pool: None,
            fused: None,
            packed: Vec::new(),
            elastic: None,
            scale_log: ScaleLog::new(),
        }
    }

    /// Select how batches execute (see [`NativeBatchMode`]); builder
    /// style, call before the backend is handed to the coordinator.
    pub fn with_batch_mode(mut self, mode: NativeBatchMode)
                           -> NativeBackend {
        self.mode = mode;
        self
    }

    /// Make the fan-out pool elastic: `cfg.max` slots allocated at
    /// compile time, `cfg.floor` active, resized at queue-depth
    /// watermark crossings fed in through [`Backend::queue_hint`].
    /// Only the fan-out pool scales, so this composes with
    /// [`NativeBatchMode::Auto`]/[`NativeBatchMode::FanOut`] (a forced
    /// `Fused` backend has no pool to scale). Keep a
    /// [`NativeBackend::scale_log`] handle before registering the
    /// backend to observe its scale decisions.
    pub fn with_elastic(mut self, cfg: ElasticConfig) -> NativeBackend {
        self.elastic = Some(cfg);
        self
    }

    /// The shared scale-event record (empty until traffic crosses a
    /// watermark; forever empty on non-elastic backends).
    pub fn scale_log(&self) -> Arc<ScaleLog> {
        self.scale_log.clone()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn compile(&mut self, max_batch: usize) -> Result<ModelSignature> {
        let last = self
            .plan
            .ir
            .layers
            .last()
            .ok_or_else(|| anyhow!("native backend: empty model"))?;
        ensure!(
            last.output.h == 1 && last.output.w == 1,
            "native backend: model must end in a classifier head, got \
             output {:?}",
            last.output
        );
        self.classes = last.output.c;
        // Build only the execution paths this mode can reach: a forced
        // mode pays one arena footprint, not two (a pool is workers x
        // peak_activation_bytes of arena; the fused pipeline is
        // max_batch x). Auto needs both.
        if self.mode != NativeBatchMode::Fused {
            self.pool = Some(match self.elastic {
                Some(cfg) => ExecutorPool::new_elastic(
                    self.plan.clone(),
                    cfg,
                    self.scale_log.clone(),
                ),
                None => {
                    ExecutorPool::new(self.plan.clone(), self.workers)
                }
            });
        }
        if self.mode != NativeBatchMode::FanOut {
            // The fused pipeline shares every weight Arc with the
            // pool's; only its (batch-scaled) arena is new.
            self.fused = Some(ModelExecutor::new_batched(
                &self.plan,
                self.workers,
                max_batch.max(1),
            ));
        }
        let inp = self.plan.ir.input;
        Ok(ModelSignature {
            input_shape: vec![inp.h, inp.w, inp.c],
            classes: self.classes,
        })
    }

    fn infer_batch(&mut self, images: &HostTensor) -> Result<HostTensor> {
        ensure!(self.pool.is_some() || self.fused.is_some(),
                "native backend: compile() not called");
        let shape = images.shape();
        ensure!(shape.len() == 4, "expected [n,h,w,c], got {shape:?}");
        let (n, h, w, c) = (shape[0], shape[1], shape[2], shape[3]);
        let inp = self.plan.ir.input;
        ensure!(
            h == inp.h && w == inp.w && c == inp.c,
            "image shape [{h},{w},{c}] does not match model input {inp:?}"
        );
        let data = images.as_f32()?;
        let elems = h * w * c;
        let use_fused = self.fused.is_some()
            && match self.mode {
                NativeBatchMode::FanOut => false,
                NativeBatchMode::Fused => true,
                NativeBatchMode::Auto => n >= 2 || self.pool.is_none(),
            };
        let outs = if use_fused {
            // Fused batched walk: one pass over the compiled ops for
            // the whole batch, per-layer weights streamed once. The
            // NHWC conversion writes straight into the reusable packed
            // batch buffer — no per-image Tensor intermediates, no
            // second pack copy.
            self.packed.clear();
            self.packed.resize(n * elems, 0.0);
            for i in 0..n {
                nhwc_to_chw_into(
                    &data[i * elems..(i + 1) * elems], h, w, c,
                    &mut self.packed[i * elems..(i + 1) * elems],
                );
            }
            self.fused
                .as_mut()
                .expect("fused executor checked above")
                .run_batch_packed(n, &self.packed)
        } else {
            // Per-image fan-out: layout conversion happens on the
            // claiming pool worker, in parallel with inference.
            let pool = self.pool.as_ref().expect("pool checked above");
            pool.run_batch_map(n, |i| {
                nhwc_to_chw(&data[i * elems..(i + 1) * elems], h, w, c)
            })
        };
        let mut logits = Vec::with_capacity(n * self.classes);
        for t in &outs {
            ensure!(
                t.data.len() == self.classes,
                "head produced {} values, expected {}",
                t.data.len(),
                self.classes
            );
            logits.extend_from_slice(&t.data);
        }
        Ok(HostTensor::f32(&[n, self.classes], logits))
    }

    fn queue_hint(&mut self, depth: usize) {
        if let Some(pool) = &self.pool {
            pool.observe_queue_depth(depth);
        }
    }
}

/// PJRT-compiled state, created on the worker thread (handles are
/// thread-affine and never move again).
struct PjrtCompiled {
    rt: Runtime,
    exe: Arc<Executable>,
    prefix: DeviceInputs,
    sig: ModelSignature,
    max_batch: usize,
}

/// The AOT XLA/PJRT runtime path behind the `Backend` seam: loads the
/// `infer_b{max_batch}` HLO artifact, keeps params + masks device-
/// resident, and uploads only the image batch per call (the hot-path
/// optimization from EXPERIMENTS.md §Perf).
///
/// In the offline build the vendored `xla` stub makes `compile` return
/// an error, which the coordinator handles like any unhealthy backend —
/// see `rust/vendor/xla/README.md`.
pub struct PjrtBackend {
    name: String,
    cfg: ServeConfig,
    compiled: Option<PjrtCompiled>,
}

impl PjrtBackend {
    /// Backend for `cfg.model`, reading artifacts from
    /// `cfg.artifacts_dir`. (`cfg.policy` is ignored here: the batch cap
    /// arrives via [`Backend::compile`].)
    pub fn new(cfg: ServeConfig) -> PjrtBackend {
        PjrtBackend {
            name: format!("pjrt:{}", cfg.model),
            cfg,
            compiled: None,
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn compile(&mut self, max_batch: usize) -> Result<ModelSignature> {
        let rt = Runtime::new(&self.cfg.artifacts_dir)?;
        let spec = rt.manifest.model(&self.cfg.model)?.clone();
        let art = format!("infer_b{max_batch}");
        let exe = rt.load_model_artifact(&self.cfg.model, &art)?;
        let params = self.cfg.params.clone().unwrap_or_else(|| {
            crate::cocotune::trainer::ModelState::init(&spec, 0x5EED).params
        });
        let masks: Vec<HostTensor> = spec
            .masks
            .iter()
            .map(|t| HostTensor::ones(&t.shape))
            .collect();
        // Params + masks live on the device; only the image batch is
        // uploaded per execution.
        let mut prefix_host = params;
        prefix_host.extend(masks);
        let prefix = exe.upload_prefix(rt.client(), &prefix_host)?;
        ensure!(
            spec.input_shape.len() == 3,
            "model input_shape must be [h,w,c], got {:?}",
            spec.input_shape
        );
        let sig = ModelSignature {
            input_shape: spec.input_shape.clone(),
            classes: spec.classes,
        };
        self.compiled = Some(PjrtCompiled {
            rt,
            exe,
            prefix,
            sig: sig.clone(),
            max_batch,
        });
        Ok(sig)
    }

    fn infer_batch(&mut self, images: &HostTensor) -> Result<HostTensor> {
        let cpl = self
            .compiled
            .as_ref()
            .ok_or_else(|| anyhow!("pjrt backend: compile() not called"))?;
        let shape = images.shape();
        ensure!(shape.len() == 4, "expected [n,h,w,c], got {shape:?}");
        let n = shape[0];
        ensure!(
            n <= cpl.max_batch,
            "batch of {n} exceeds compiled cap {}",
            cpl.max_batch
        );
        let (h, w, c) = (
            cpl.sig.input_shape[0],
            cpl.sig.input_shape[1],
            cpl.sig.input_shape[2],
        );
        ensure!(
            shape[1..] == [h, w, c],
            "image shape {:?} does not match model input [{h},{w},{c}]",
            &shape[1..]
        );
        // Pad to the compiled batch size; the artifact's shape is fixed.
        let elems = h * w * c;
        let mut x = vec![0f32; cpl.max_batch * elems];
        x[..n * elems].copy_from_slice(images.as_f32()?);
        let suffix = [HostTensor::f32(&[cpl.max_batch, h, w, c], x)];
        let out = cpl.exe.run_with_prefix(cpl.rt.client(), &cpl.prefix,
                                          &suffix)?;
        let logits = out[0].as_f32()?;
        let classes = cpl.sig.classes;
        ensure!(
            logits.len() >= n * classes,
            "artifact returned {} logits, expected at least {}",
            logits.len(),
            n * classes
        );
        Ok(HostTensor::f32(&[n, classes],
                           logits[..n * classes].to_vec()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{build_plan, PruneConfig, Scheme};
    use crate::exec::ModelExecutor;
    use crate::ir::{Chw, IrBuilder};
    use crate::util::rng::Rng;

    fn tiny_plan() -> Arc<ExecPlan> {
        let mut b = IrBuilder::new("t", Chw::new(3, 8, 8));
        b.conv("c1", 3, 8, 1, true)
            .conv("c2", 3, 8, 2, true)
            .gap("g")
            .dense("fc", 5, false);
        build_plan(&b.build().unwrap(), Scheme::CocoGen,
                   PruneConfig::default(), 42)
            .into_shared()
    }

    #[test]
    fn nhwc_to_chw_layout() {
        // 2x2x2 image: value encodes (y, x, ch).
        let img: Vec<f32> = (0..8).map(|v| v as f32).collect();
        let t = nhwc_to_chw(&img, 2, 2, 2);
        // NHWC index (y*2 + x)*2 + ch must land at CHW (ch, y, x).
        assert_eq!(t.at(0, 0, 0), 0.0);
        assert_eq!(t.at(1, 0, 0), 1.0);
        assert_eq!(t.at(0, 0, 1), 2.0);
        assert_eq!(t.at(0, 1, 0), 4.0);
        assert_eq!(t.at(1, 1, 1), 7.0);
    }

    #[test]
    fn native_backend_matches_direct_executor() {
        let plan = tiny_plan();
        let mut be = NativeBackend::with_workers("native", plan.clone(), 3);
        let sig = be.compile(8).unwrap();
        assert_eq!(sig.input_shape, vec![8, 8, 3]);
        assert_eq!(sig.classes, 5);
        let mut rng = Rng::seed_from(2);
        let n = 7;
        let elems = sig.image_elems();
        let data: Vec<f32> =
            (0..n * elems).map(|_| rng.normal_f32()).collect();
        let images = HostTensor::f32(&[n, 8, 8, 3], data.clone());
        let logits = be.infer_batch(&images).unwrap();
        assert_eq!(logits.shape(), &[n, 5]);
        let lv = logits.as_f32().unwrap();
        let mut exec = ModelExecutor::new(&plan, 1);
        for i in 0..n {
            let t = nhwc_to_chw(&data[i * elems..(i + 1) * elems], 8, 8, 3);
            let want = exec.run(&t);
            assert_eq!(&lv[i * 5..(i + 1) * 5], want.data.as_slice(),
                       "image {i} diverged");
        }
    }

    #[test]
    fn fused_and_fanout_modes_agree_bitwise() {
        let plan = tiny_plan();
        let mut rng = Rng::seed_from(7);
        let n = 6;
        let elems = 8 * 8 * 3;
        let data: Vec<f32> =
            (0..n * elems).map(|_| rng.normal_f32()).collect();
        let images = HostTensor::f32(&[n, 8, 8, 3], data.clone());
        let mut logits = Vec::new();
        for mode in [NativeBatchMode::Auto, NativeBatchMode::Fused,
                     NativeBatchMode::FanOut]
        {
            let mut be =
                NativeBackend::with_workers("native", plan.clone(), 2)
                    .with_batch_mode(mode);
            be.compile(8).unwrap();
            let out = be.infer_batch(&images).unwrap();
            assert_eq!(out.shape(), &[n, 5]);
            logits.push(out.as_f32().unwrap().to_vec());
        }
        assert_eq!(logits[0], logits[1],
                   "auto (fused) diverged from forced fused");
        assert_eq!(logits[0], logits[2],
                   "fused path diverged from per-image fan-out");
        // and both match the direct executor
        let mut exec = ModelExecutor::new(&plan, 1);
        for i in 0..n {
            let t = nhwc_to_chw(&data[i * elems..(i + 1) * elems], 8, 8, 3);
            let want = exec.run(&t);
            assert_eq!(&logits[0][i * 5..(i + 1) * 5],
                       want.data.as_slice(), "image {i} diverged");
        }
    }

    #[test]
    fn forced_modes_build_only_their_path() {
        let plan = tiny_plan();
        let mut be = NativeBackend::with_workers("native", plan.clone(), 2)
            .with_batch_mode(NativeBatchMode::FanOut);
        be.compile(8).unwrap();
        assert!(be.fused.is_none(),
                "FanOut mode must not build the batched pipeline");
        assert!(be
            .infer_batch(&HostTensor::zeros(&[3, 8, 8, 3]))
            .is_ok());
        let mut be = NativeBackend::with_workers("native", plan, 2)
            .with_batch_mode(NativeBatchMode::Fused);
        be.compile(8).unwrap();
        assert!(be.pool.is_none(),
                "Fused mode must not build the fan-out pool");
        // Singletons run fused too.
        assert!(be
            .infer_batch(&HostTensor::zeros(&[1, 8, 8, 3]))
            .is_ok());
    }

    #[test]
    fn native_backend_validates_input() {
        let plan = tiny_plan();
        let mut be = NativeBackend::new("native", plan);
        // infer before compile
        assert!(be.infer_batch(&HostTensor::zeros(&[1, 8, 8, 3])).is_err());
        be.compile(4).unwrap();
        // wrong rank / wrong spatial shape
        assert!(be.infer_batch(&HostTensor::zeros(&[8, 8, 3])).is_err());
        assert!(be.infer_batch(&HostTensor::zeros(&[1, 4, 4, 3])).is_err());
    }

    #[test]
    fn pjrt_backend_fails_cleanly_without_runtime() {
        // Offline build: the xla stub (or a missing artifacts dir) makes
        // compile error out instead of panicking — the property failover
        // relies on.
        let mut be = PjrtBackend::new(ServeConfig::new("resnet_mini"));
        assert_eq!(be.name(), "pjrt:resnet_mini");
        if be.compile(8).is_ok() {
            // Real runtime present: serving a batch must work too.
            let sig = be.compile(8).unwrap();
            let images =
                HostTensor::zeros(&[1, sig.input_shape[0],
                                    sig.input_shape[1],
                                    sig.input_shape[2]]);
            assert!(be.infer_batch(&images).is_ok());
        }
    }
}
