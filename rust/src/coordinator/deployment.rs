//! The `Deployment` abstraction: one named operating point of the
//! co-design menu, packaged as the staged pipeline the paper describes —
//! model IR → [`Scheme`] → prune/quant config → optional auto-tune at a
//! target batch size → compiled serving backends.
//!
//! [`Deployment::builder`] replaces the scattered
//! `build_plan`/`autotune_plan_batched`/`into_shared`/`NativeBackend::new`
//! call chain with one fluent constructor, and a built deployment is the
//! unit a [`super::Coordinator`] registers: several named deployments
//! (e.g. `dense`, `cocogen`, `cocogen-quant`, `coco-auto`) of the *same*
//! model serve behind one client, with per-request SLA routing picking
//! among them on the live path.
//!
//! ```
//! use cocopie::ir::{Chw, IrBuilder};
//! use cocopie::prelude::*;
//!
//! let mut b = IrBuilder::new("doc", Chw::new(3, 8, 8));
//! b.conv("c1", 3, 4, 1, true).gap("g").dense("fc", 3, false);
//! let ir = b.build().unwrap();
//! let dep = Deployment::builder("cocogen", &ir)
//!     .scheme(Scheme::CocoGen)
//!     .seed(7)
//!     .build()
//!     .unwrap();
//! assert_eq!(dep.name(), "cocogen");
//! assert!(dep.plan().is_some());
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::codegen::{autotune_plan_batched, build_plan, ExecPlan,
                     PruneConfig, Scheme};
use crate::exec::{ModelExecutor, Tensor};
use crate::ir::ModelIR;

use super::backend::{Backend, NativeBackend, NativeBatchMode,
                     PjrtBackend};
use super::router::RouterPolicy;
use super::ServeConfig;

/// A named, fully-built deployment: the backends that serve it, its
/// batch-routing policy across those backends, and the operating point
/// (declared accuracy + measured prior latency) the SLA router starts
/// from before live metrics take over.
pub struct Deployment {
    pub(crate) name: Arc<str>,
    pub(crate) backends: Vec<Box<dyn Backend>>,
    pub(crate) router: RouterPolicy,
    pub(crate) accuracy: f64,
    pub(crate) prior_latency_ms: f64,
    plan: Option<Arc<ExecPlan>>,
    kernel_tier: &'static str,
}

impl Deployment {
    /// Start the staged build pipeline for a native deployment of `ir`.
    pub fn builder(name: &str, ir: &ModelIR) -> DeploymentBuilder {
        DeploymentBuilder {
            name: name.to_string(),
            ir: ir.clone(),
            scheme: Scheme::CocoGen,
            prune: PruneConfig::default(),
            seed: 7,
            autotune_batch: None,
            tune_threads: 1,
            workers: None,
            batch_mode: NativeBatchMode::Auto,
            accuracy: None,
        }
    }

    /// A native deployment over an already-built plan (e.g. one shared
    /// with a direct [`ModelExecutor`] in tests, or tuned elsewhere).
    pub fn from_plan(name: &str, plan: Arc<ExecPlan>) -> Deployment {
        let prior = measure_prior_ms(&plan);
        Deployment {
            name: Arc::from(name),
            backends: vec![Box::new(NativeBackend::new(name,
                                                       plan.clone()))],
            router: RouterPolicy::Failover,
            accuracy: plan.flop_keep_ratio(),
            prior_latency_ms: prior,
            plan: Some(plan),
            kernel_tier: crate::exec::micro::tier().label(),
        }
    }

    /// A deployment over arbitrary backends (custom [`Backend`] impls,
    /// or a heterogeneous failover set). No plan is attached, the
    /// accuracy proxy defaults to 1.0, and the latency prior is unknown
    /// (`f64::INFINITY`) until live traffic measures it.
    pub fn from_backends(name: &str, backends: Vec<Box<dyn Backend>>)
                         -> Deployment {
        Deployment {
            name: Arc::from(name),
            backends,
            router: RouterPolicy::Failover,
            accuracy: 1.0,
            prior_latency_ms: f64::INFINITY,
            plan: None,
            kernel_tier: crate::exec::micro::tier().label(),
        }
    }

    /// The AOT XLA/PJRT path as a named deployment — the pre-redesign
    /// `Coordinator::start(cfg)` entry point folded into the same
    /// registry as the native deployments.
    pub fn pjrt(name: &str, cfg: ServeConfig) -> Deployment {
        Deployment::from_backends(name,
                                  vec![Box::new(PjrtBackend::new(cfg))])
    }

    /// Add a standby backend (failover target under this deployment's
    /// batch-routing policy).
    pub fn with_backend(mut self, backend: Box<dyn Backend>)
                        -> Deployment {
        self.backends.push(backend);
        self
    }

    /// Batch-routing policy across this deployment's backends.
    pub fn with_router(mut self, router: RouterPolicy) -> Deployment {
        self.router = router;
        self
    }

    /// Override the declared accuracy operating point.
    pub fn with_accuracy(mut self, accuracy: f64) -> Deployment {
        self.accuracy = accuracy;
        self
    }

    /// Override the latency prior used until live metrics exist (ms).
    pub fn with_prior_latency_ms(mut self, ms: f64) -> Deployment {
        self.prior_latency_ms = ms;
        self
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Declared accuracy operating point (the SLA router's quality
    /// axis; the surviving-FLOP proxy unless overridden).
    pub fn accuracy(&self) -> f64 {
        self.accuracy
    }

    /// The single-image latency prior seeding the SLA router (ms) —
    /// measured at build time for native deployments, `INFINITY` for
    /// `from_backends`/`pjrt` until live traffic measures it.
    pub fn prior_latency_ms(&self) -> f64 {
        self.prior_latency_ms
    }

    /// The compiled plan behind this deployment, when it is a native
    /// single-plan deployment — what serving tests run directly through
    /// a [`ModelExecutor`] to pin bit-identical results.
    pub fn plan(&self) -> Option<&Arc<ExecPlan>> {
        self.plan.as_ref()
    }

    /// The kernel dispatch tier the deployment was built (and, for
    /// tuned plans, autotuned) under — `"avx2+fma"` or `"scalar"`. See
    /// [`crate::exec::micro::tier`].
    pub fn kernel_tier(&self) -> &'static str {
        self.kernel_tier
    }
}

/// Fluent staged pipeline: IR → scheme → prune config → optional
/// autotune at a target batch size → compiled native deployment. See
/// [`Deployment::builder`].
pub struct DeploymentBuilder {
    name: String,
    ir: ModelIR,
    scheme: Scheme,
    prune: PruneConfig,
    seed: u64,
    autotune_batch: Option<usize>,
    tune_threads: usize,
    workers: Option<usize>,
    batch_mode: NativeBatchMode,
    accuracy: Option<f64>,
}

impl DeploymentBuilder {
    /// Compression/compilation scheme (default [`Scheme::CocoGen`]).
    pub fn scheme(mut self, scheme: Scheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Pruning hyper-parameters (default [`PruneConfig::default`]).
    pub fn prune(mut self, prune: PruneConfig) -> Self {
        self.prune = prune;
        self
    }

    /// Weight-init seed (default 7).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run the measured auto-tuner on the built plan at this serving
    /// batch size (tiles for the fixed-engine schemes, full per-layer
    /// engine selection under [`Scheme::CocoAuto`]). Without this,
    /// `CocoAuto` still tunes — at batch 1 — since an untuned CocoAuto
    /// plan is just CoCo-Gen; other schemes skip tuning.
    pub fn autotune_at(mut self, batch: usize) -> Self {
        self.autotune_batch = Some(batch.max(1));
        self
    }

    /// Threads the auto-tuner measures with (default 1).
    pub fn tune_threads(mut self, threads: usize) -> Self {
        self.tune_threads = threads.max(1);
        self
    }

    /// Executor-pool width of the native backend (default: one per
    /// core).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    /// How the native backend executes routed batches (default
    /// [`NativeBatchMode::Auto`]).
    pub fn batch_mode(mut self, mode: NativeBatchMode) -> Self {
        self.batch_mode = mode;
        self
    }

    /// Declared accuracy operating point. Default: the plan's
    /// surviving-FLOP ratio — a plan-derived proxy that ranks denser
    /// variants above aggressively pruned ones, for installs that have
    /// not measured real validation accuracy yet.
    pub fn accuracy(mut self, accuracy: f64) -> Self {
        self.accuracy = Some(accuracy);
        self
    }

    /// Run the pipeline: build the plan, optionally auto-tune it at the
    /// target batch size, measure the single-image latency prior, and
    /// compile the native backend.
    pub fn build(self) -> Result<Deployment> {
        ensure!(!self.name.is_empty(), "deployment name must be \
                                        non-empty");
        let mut plan =
            build_plan(&self.ir, self.scheme, self.prune, self.seed);
        let tune_batch = match self.autotune_batch {
            Some(b) => Some(b),
            // CocoAuto's whole point is measured per-layer engine
            // selection; default it on.
            None if self.scheme == Scheme::CocoAuto => Some(1),
            None => None,
        };
        if let Some(batch) = tune_batch {
            autotune_plan_batched(&mut plan, self.tune_threads, batch);
        }
        let plan = plan.into_shared();
        let mut batches = vec![1];
        if let Some(b) = tune_batch.filter(|&b| b > 1) {
            batches.push(b);
        }
        verify_for_serving(&self.name, &plan, &batches)?;
        let prior = measure_prior_ms(&plan);
        let accuracy =
            self.accuracy.unwrap_or_else(|| plan.flop_keep_ratio());
        let backend = match self.workers {
            Some(w) => NativeBackend::with_workers(&self.name,
                                                   plan.clone(), w),
            None => NativeBackend::new(&self.name, plan.clone()),
        }
        .with_batch_mode(self.batch_mode);
        Ok(Deployment {
            name: Arc::from(self.name.as_str()),
            backends: vec![Box::new(backend)],
            router: RouterPolicy::Failover,
            accuracy,
            prior_latency_ms: prior,
            plan: Some(plan),
            kernel_tier: crate::exec::micro::tier().label(),
        })
    }
}

/// Registration gate shared by [`DeploymentBuilder::build`] and the
/// live [`super::Lifecycle`]: refuse any plan the static verifier
/// (`codegen::verify`) cannot prove safe — dataflow, arena aliasing,
/// metadata bounds, and scheme legality — at each serving batch size
/// in `batches` (deduplicated; zero is checked as batch 1).
pub(crate) fn verify_for_serving(name: &str, plan: &ExecPlan,
                                 batches: &[usize]) -> Result<()> {
    let mut seen = Vec::new();
    for &b in batches {
        let b = b.max(1);
        if seen.contains(&b) {
            continue;
        }
        seen.push(b);
        if let Err(e) = plan.verify_batched(b) {
            bail!("deployment '{name}': plan rejected by static \
                   verifier at batch {b}: {e}");
        }
    }
    Ok(())
}

/// Measured single-image latency prior (ms): one warm-up plus best-of-2
/// direct executor runs on a zero image. This is what seeds the SLA
/// router's latency point until the deployment's own [`super::Metrics`]
/// has served real traffic — measured, not a hard-coded constant.
fn measure_prior_ms(plan: &Arc<ExecPlan>) -> f64 {
    let inp = plan.ir.input;
    let mut exec = ModelExecutor::new(plan, 1);
    let image = Tensor::zeros(inp.c, inp.h, inp.w);
    exec.run(&image); // warm: arena + scratch allocation
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let t0 = Instant::now();
        exec.run(&image);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::LayerPlan;
    use crate::ir::{Chw, IrBuilder};

    fn tiny_ir() -> ModelIR {
        let mut b = IrBuilder::new("dep_t", Chw::new(3, 8, 8));
        b.conv("c1", 3, 8, 1, true)
            .conv("c2", 3, 8, 2, true)
            .gap("g")
            .dense("fc", 4, false);
        b.build().unwrap()
    }

    #[test]
    fn builder_runs_the_staged_pipeline() {
        let dep = Deployment::builder("cocogen", &tiny_ir())
            .scheme(Scheme::CocoGen)
            .seed(42)
            .workers(2)
            .build()
            .unwrap();
        assert_eq!(dep.name(), "cocogen");
        assert_eq!(dep.backends.len(), 1);
        let plan = dep.plan().expect("native deployment keeps its plan");
        assert_eq!(plan.scheme, Scheme::CocoGen);
        // Prior latency was actually measured.
        assert!(dep.prior_latency_ms.is_finite()
                    && dep.prior_latency_ms > 0.0);
        // Accuracy proxy defaults to the surviving-FLOP ratio.
        assert!((dep.accuracy - plan.flop_keep_ratio()).abs() < 1e-12);
    }

    #[test]
    fn coco_auto_builder_tunes_by_default() {
        let dep = Deployment::builder("auto", &tiny_ir())
            .scheme(Scheme::CocoAuto)
            .seed(1)
            .build()
            .unwrap();
        // The tuner ran: every pattern layer holds either the fp32 or
        // int8 pattern format with a measured tile (structure alone
        // can't prove measurement, but the plan must still be CocoAuto
        // and servable).
        let plan = dep.plan().unwrap();
        assert_eq!(plan.scheme, Scheme::CocoAuto);
        assert!(plan.layers.iter().any(|l| matches!(
            l,
            LayerPlan::Fkw { .. } | LayerPlan::QuantFkw { .. }
        )));
    }

    #[test]
    fn builder_rejects_empty_name() {
        assert!(Deployment::builder("", &tiny_ir()).build().is_err());
    }

    #[test]
    fn accuracy_and_prior_overrides_stick() {
        let dep = Deployment::builder("dense", &tiny_ir())
            .scheme(Scheme::DenseIm2col)
            .accuracy(0.97)
            .build()
            .unwrap()
            .with_prior_latency_ms(123.0);
        assert!((dep.accuracy - 0.97).abs() < 1e-12);
        assert!((dep.prior_latency_ms - 123.0).abs() < 1e-12);
    }
}
