//! Calibration model for the scaled tier (Tables 3-5).
//!
//! The paper's headline numbers come from 500-config explorations of
//! ResNet-50/Inception-V3 with hour-scale trainings on a GPU cluster —
//! hardware we do not have. The quantities that drive those numbers are
//! measured for real on the mini tier (explore.rs):
//!
//!   1. the accuracy-vs-pruning curve (convex: flat up to a kink, then
//!      steep), with a per-dataset hardness scale;
//!   2. the *recovery fraction*: block-trained networks recover a large
//!      share of the pruning damage (paper Fig. 11(c,d): a 70%-pruned
//!      default collapses while the block-trained one stays close to the
//!      full model) — this, not a uniform boost, is what produces the
//!      paper's 99.6% configuration savings;
//!   3. the convergence-speed ratio (steps to reach the final level).
//!
//! `Calibration::from_runs` fits these from real ExploreOutcomes;
//! `Calibration::paper_scale` provides paper-consistent defaults.
//! cluster.rs replays the exploration protocol at full scale with these
//! parameters. See DESIGN.md §2 (substitution table).

use super::explore::ExploreOutcome;
use crate::util::rng::Rng;
use crate::util::stats;

/// Fitted behavioural model of pruned-network training.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// Full-model test accuracy.
    pub base_acc: f64,
    /// Per-dataset hardness multiplier on the drop curve (Flowers-easy
    /// ~0.8, CUB-hard ~6).
    pub hardness: f64,
    /// Shallow early slope of the drop curve.
    pub s_early: f64,
    /// Quadratic late coefficient past the kink.
    pub s_late: f64,
    /// Kink position (fraction pruned) where damage accelerates.
    pub kink: f64,
    /// Residual noise (std) around the curve.
    pub acc_noise: f64,
    /// Fraction of the pruning damage that block-trained init recovers
    /// (paper Fig. 11: large; fitted from the mini tier).
    pub recovery: f64,
    /// Initial-accuracy advantage of block-trained init (absolute).
    pub init_boost: f64,
    /// Mean steps-to-converge for the default init.
    pub default_steps: f64,
    /// steps(block) / steps(default).
    pub block_steps_ratio: f64,
    /// Hours per training step at paper scale (~5.7 h per default config
    /// on a K20X).
    pub step_hours: f64,
}

impl Calibration {
    /// The damage curve: accuracy drop at `frac` pruned (before recovery).
    pub fn drop_at(&self, frac: f64) -> f64 {
        let late = (frac - self.kink).max(0.0);
        self.hardness * (self.s_early * frac + self.s_late * late * late)
    }

    /// Fit from real mini-tier runs (default + block explorations over
    /// the same config set, trained WITHOUT early stop).
    pub fn from_runs(base_acc: f64, default: &ExploreOutcome,
                     block: &ExploreOutcome) -> Calibration {
        let mut c = Calibration::paper_scale(base_acc);
        let max_size = default
            .results
            .iter()
            .map(|r| r.model_size)
            .max()
            .unwrap_or(1) as f64;
        let mut hard_samples = Vec::new();
        let mut recov_samples = Vec::new();
        let mut init_d = Vec::new();
        let mut init_b = Vec::new();
        for rd in &default.results {
            let Some(rb) =
                block.results.iter().find(|r| r.config == rd.config)
            else {
                continue;
            };
            let frac = 1.0 - rd.model_size as f64 / max_size;
            let drop_d = (base_acc - rd.final_acc).max(0.0);
            // hardness: observed drop / unit-curve drop
            let unit = {
                let late = (frac - c.kink).max(0.0);
                c.s_early * frac + c.s_late * late * late
            };
            if unit > 1e-6 && drop_d > 0.0 {
                hard_samples.push(drop_d / unit);
            }
            if drop_d > 0.01 {
                recov_samples
                    .push(((rb.final_acc - rd.final_acc) / drop_d)
                        .clamp(0.0, 0.95));
            }
            init_d.push(rd.initial_acc);
            init_b.push(rb.initial_acc);
        }
        if !hard_samples.is_empty() {
            c.hardness = stats::median(&hard_samples).clamp(0.2, 10.0);
        }
        if !recov_samples.is_empty() {
            c.recovery = stats::median(&recov_samples);
        }
        c.init_boost =
            (stats::mean(&init_b) - stats::mean(&init_d)).max(0.0);
        // Convergence ratio from the accuracy curves: the step at which
        // each run first crosses a common target (works for runs trained
        // without early stop, where raw step counts are identical).
        let mut ratio_samples = Vec::new();
        let mut steps_d_all = Vec::new();
        for rd in &default.results {
            let Some(rb) =
                block.results.iter().find(|r| r.config == rd.config)
            else {
                continue;
            };
            let target = rd.final_acc.min(rb.final_acc) - 0.005;
            let cross = |init: f64, curve: &[(usize, f64)], cap: usize| {
                if init >= target {
                    return 0.0;
                }
                curve
                    .iter()
                    .find(|(_, a)| *a >= target)
                    .map(|(s, _)| *s as f64)
                    .unwrap_or(cap as f64)
            };
            let sd = cross(rd.initial_acc, &rd.acc_curve, rd.steps);
            let sb = cross(rb.initial_acc, &rb.acc_curve, rb.steps);
            steps_d_all.push(rd.steps as f64);
            if sd > 0.0 {
                ratio_samples.push((sb / sd).clamp(0.0, 1.5));
            }
        }
        c.default_steps = stats::mean(&steps_d_all).max(1.0);
        if !ratio_samples.is_empty() {
            c.block_steps_ratio =
                stats::median(&ratio_samples).clamp(0.05, 1.0);
        }
        c.step_hours = 5.7 / c.default_steps;
        c.base_acc = base_acc;
        c
    }

    /// Paper-consistent defaults (mid-points of the reported ranges:
    /// 1-4% final boost at moderate pruning, 50-90% initial advantage,
    /// 30-100% training-time saving, Fig. 11 damage-recovery behaviour).
    pub fn paper_scale(base_acc: f64) -> Calibration {
        Calibration {
            base_acc,
            hardness: 1.0,
            s_early: 0.02,
            s_late: 0.6,
            kink: 0.55,
            acc_noise: 0.006,
            recovery: 0.75,
            init_boost: 0.30,
            default_steps: 200.0,
            block_steps_ratio: 0.45,
            step_hours: 5.7 / 200.0,
        }
    }

    /// Per-dataset hardness presets matching the paper's Table 2 spread
    /// (used when no real calibration for that dataset exists).
    pub fn with_dataset(mut self, name: &str) -> Calibration {
        self.hardness = match name {
            n if n.contains("Flowers") => 0.8,
            n if n.contains("CUB") => 6.0,
            n if n.contains("Cars") => 2.5,
            n if n.contains("Dogs") => 4.0,
            _ => self.hardness,
        };
        self
    }

    fn noise_for(&self, config_id: u64, salt: u64) -> f64 {
        let mut rng = Rng::seed_from(config_id ^ salt);
        rng.normal() * self.acc_noise
    }

    /// Predicted FINAL accuracy of a config with `frac_pruned` removed.
    pub fn predict_acc(&self, config_id: u64, frac_pruned: f64,
                       block_trained: bool) -> f64 {
        let drop = self.drop_at(frac_pruned);
        let effective = if block_trained {
            drop * (1.0 - self.recovery)
        } else {
            drop
        };
        (self.base_acc - effective + self.noise_for(config_id, 0x5EED))
            .clamp(0.0, 1.0)
    }

    /// Predicted steps-to-converge. `quality` in [0,1] is the tuning-block
    /// quality bonus (multi-module blocks give better inits -> fewer
    /// fine-tuning steps; Table 5's mechanism).
    pub fn predict_steps(&self, config_id: u64, block_trained: bool,
                         quality: f64) -> f64 {
        let mut rng = Rng::seed_from(config_id ^ 0x57E9);
        let jitter = 1.0 + 0.15 * rng.normal().clamp(-2.0, 2.0);
        let steps = self.default_steps * jitter;
        if block_trained {
            steps * self.block_steps_ratio
                * (1.0 - 0.05 * quality.clamp(0.0, 1.0))
        } else {
            steps
        }
    }

    /// Hours to train one config at paper scale.
    pub fn config_hours(&self, config_id: u64, block_trained: bool,
                        quality: f64) -> f64 {
        self.predict_steps(config_id, block_trained, quality)
            * self.step_hours
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn damage_curve_shape() {
        let c = Calibration::paper_scale(0.9);
        // convex: flat early, steep late
        assert!(c.drop_at(0.2) < 0.01);
        assert!(c.drop_at(0.75) > 3.0 * c.drop_at(0.4));
        // monotone
        let mut prev = 0.0;
        for i in 0..=20 {
            let d = c.drop_at(i as f64 / 20.0);
            assert!(d >= prev);
            prev = d;
        }
    }

    #[test]
    fn block_recovers_damage() {
        let c = Calibration::paper_scale(0.85);
        let d = c.predict_acc(2, 0.7, false);
        let b = c.predict_acc(2, 0.7, true);
        assert!(b > d);
        // heavily pruned: recovery is large (Fig 11 c,d behaviour)
        assert!(b - d > 0.5 * c.drop_at(0.7));
        // and converges faster, more so with high-quality blocks
        assert!(c.predict_steps(3, true, 0.0) < c.predict_steps(3, false, 0.0));
        assert!(c.predict_steps(3, true, 1.0) < c.predict_steps(3, true, 0.0));
    }

    #[test]
    fn dataset_hardness_ordering() {
        let f = Calibration::paper_scale(0.97).with_dataset("Flowers102");
        let cub = Calibration::paper_scale(0.77).with_dataset("CUB200");
        assert!(cub.drop_at(0.5) > f.drop_at(0.5));
    }

    #[test]
    fn predictions_deterministic() {
        let c = Calibration::paper_scale(0.8);
        assert_eq!(c.predict_acc(9, 0.3, true), c.predict_acc(9, 0.3, true));
        assert_eq!(c.predict_steps(9, false, 0.0),
                   c.predict_steps(9, false, 0.0));
    }

    #[test]
    fn from_runs_fits_recovery_and_hardness() {
        use crate::cocotune::explore::{ConfigResult, ExploreOutcome};
        let mk = |acc: f64, steps: usize, init: f64, size: u64,
                  cfg: Vec<u8>| ConfigResult {
            config: cfg,
            model_size: size,
            final_acc: acc,
            steps,
            initial_acc: init,
            acc_curve: vec![],
        };
        // base 0.9; config A frac 0.2 (size 80/100), config B frac 0.5
        let default = ExploreOutcome {
            results: vec![
                mk(0.86, 200, 0.10, 80, vec![1]),
                mk(0.80, 200, 0.08, 50, vec![2]),
            ],
            found: None,
            total_steps: 400,
        };
        let block = ExploreOutcome {
            results: vec![
                mk(0.89, 100, 0.55, 80, vec![1]),
                mk(0.88, 100, 0.50, 50, vec![2]),
            ],
            found: None,
            total_steps: 200,
        };
        let c = Calibration::from_runs(0.9, &default, &block);
        // recovery: (0.03/0.04 = .75, 0.08/0.10 = .8) -> median ~.775
        assert!((c.recovery - 0.775).abs() < 1e-9);
        assert!(c.hardness > 0.2);
        assert!((c.block_steps_ratio - 0.5).abs() < 1e-9);
        assert!(c.init_boost > 0.4);
    }
}
