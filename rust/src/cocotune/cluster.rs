//! Discrete-event cluster simulator — the scaled tier that replays the
//! paper's Table 3/4/5 exploration protocol (500-config subspaces, 1/4/16
//! nodes, hour-scale trainings) with the behaviour model calibrated from
//! the real PJRT tier (calib.rs).
//!
//! Protocol (paper §2.2.3): configurations are explored smallest-first;
//! each node trains one network at a time; exploration stops when a
//! finished network meets the accuracy threshold. The composability mode
//! first pre-trains the tuning blocks (also on the cluster), then
//! fine-tunes block-trained networks, which (a) converge in fewer steps
//! and (b) reach higher accuracy — so a smaller network passes the
//! threshold sooner. Both effects are the measured ones.

use super::blocks::BlockSelection;
use super::calib::Calibration;
use crate::util::rng::Rng;

/// A simulated pruned-network configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub id: u64,
    /// Fraction of parameters pruned (0..1); size order = 1-frac order.
    pub frac_pruned: f64,
}

/// Generate a `n`-config subspace with a close-to-uniform size
/// distribution (paper's random sampling).
pub fn sample_sim_subspace(n: usize, seed: u64) -> Vec<SimConfig> {
    let mut rng = Rng::seed_from(seed);
    let mut cfgs: Vec<SimConfig> = (0..n)
        .map(|i| SimConfig {
            id: seed.wrapping_mul(1_000_003) ^ (i as u64),
            // pruning fractions roughly uniform over [0.15, 0.75]
            frac_pruned: rng.range_f64(0.15, 0.75),
        })
        .collect();
    // explore smallest model (largest pruned fraction) first
    cfgs.sort_by(|a, b| b.frac_pruned.partial_cmp(&a.frac_pruned).unwrap());
    cfgs
}

/// Simulation result for one (mode, nodes, threshold) cell.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// Configurations whose training completed before stop.
    pub configs_evaluated: usize,
    /// Wall-clock hours (simulated).
    pub hours: f64,
    /// Winner's surviving-size fraction (1 - frac_pruned), if any.
    pub winner_size_frac: Option<f64>,
    /// Pre-training overhead fraction of total time (block mode).
    pub overhead_frac: f64,
}

/// Execution mode.
pub enum SimMode<'a> {
    Default,
    /// Block-trained with the given tuning-block selection (pre-training
    /// cost = module_units x per-block hours).
    Block(&'a BlockSelection),
}

/// Hours to pre-train one tuning block: one Teacher-Student job. Its
/// modules train concurrently against the shared teacher activations
/// (paper Fig. 10(b); our real tier's block_pretrain graph does exactly
/// this), so the cost scales with the number of BLOCKS, not the modules
/// inside them — the mechanism behind Table 5's extra speedup from
/// fewer, larger blocks. Default: 1/8 of a full config's training cost.
pub fn block_unit_hours(calib: &Calibration) -> f64 {
    calib.default_steps * calib.step_hours / 16.0
}

/// Run the discrete-event simulation.
pub fn simulate(configs: &[SimConfig], calib: &Calibration, mode: SimMode,
                nodes: usize, thr_acc: f64, stop_at_target: bool)
                -> SimOutcome {
    let nodes = nodes.max(1);
    let block = matches!(mode, SimMode::Block(_));
    // Tuning-block quality: multi-module blocks produce better inits,
    // so assembled networks fine-tune in fewer steps (Table 5's
    // mechanism). quality = fraction of module-units covered by
    // multi-module blocks.
    let quality = match &mode {
        SimMode::Default => 0.0,
        SimMode::Block(sel) => {
            let total: usize = sel.pretrain_module_units();
            let multi: usize = sel
                .blocks
                .iter()
                .filter(|b| b.len() > 1)
                .map(|b| b.len())
                .sum();
            if total == 0 {
                0.0
            } else {
                multi as f64 / total as f64
            }
        }
    };
    // Pre-training phase (block mode): module-units spread over nodes.
    let overhead_h = match &mode {
        SimMode::Default => 0.0,
        SimMode::Block(sel) => {
            let jobs = sel.blocks.len() as f64;
            let per = block_unit_hours(calib);
            (jobs * per / nodes as f64).max(per)
        }
    };
    // Event loop: node_free[i] = time node i becomes free.
    let mut node_free = vec![overhead_h; nodes];
    let mut completed: Vec<(f64, usize)> = Vec::new(); // (finish time, idx)
    let mut stop_time: Option<f64> = None;
    for (idx, cfg) in configs.iter().enumerate() {
        // earliest-free node
        let (ni, &start) = node_free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        // If a winner already finished before this config could start,
        // the scheduler stops dispatching.
        if let Some(t) = stop_time {
            if start >= t {
                break;
            }
        }
        let dur = calib.config_hours(cfg.id, block, quality);
        let finish = start + dur;
        node_free[ni] = finish;
        completed.push((finish, idx));
        let acc = calib.predict_acc(cfg.id, cfg.frac_pruned, block);
        if stop_at_target && acc >= thr_acc {
            let t = stop_time.get_or_insert(finish);
            if finish < *t {
                *t = finish;
            }
        }
    }
    completed.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let end = stop_time.unwrap_or_else(|| {
        completed.last().map(|(t, _)| *t).unwrap_or(overhead_h)
    });
    let evaluated = completed.iter().filter(|(t, _)| *t <= end).count();
    // Winner: smallest model among those completed by `end` that meet thr.
    let winner = completed
        .iter()
        .filter(|(t, _)| *t <= end)
        .map(|(_, i)| &configs[*i])
        .filter(|c| {
            calib.predict_acc(c.id, c.frac_pruned, block) >= thr_acc
        })
        .max_by(|a, b| {
            a.frac_pruned.partial_cmp(&b.frac_pruned).unwrap()
        });
    SimOutcome {
        configs_evaluated: evaluated.max(1),
        hours: end,
        winner_size_frac: winner.map(|c| 1.0 - c.frac_pruned),
        overhead_frac: if end > 0.0 { overhead_h / end } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cocotune::blocks::{BlockSelection, TuningBlock};

    fn blocks(units: usize) -> BlockSelection {
        BlockSelection {
            blocks: (0..units)
                .map(|i| TuningBlock {
                    start_module: i,
                    rates: vec![1],
                })
                .collect(),
            frequencies: vec![2; units],
            grammar_rules: 0,
        }
    }

    #[test]
    fn subspace_sorted_smallest_first() {
        let s = sample_sim_subspace(100, 1);
        for w in s.windows(2) {
            assert!(w[0].frac_pruned >= w[1].frac_pruned);
        }
    }

    #[test]
    fn block_mode_is_faster_and_finds_smaller_models() {
        let calib = Calibration::paper_scale(0.85);
        let cfgs = sample_sim_subspace(500, 7);
        let thr = calib.base_acc; // alpha = 0
        let sel = blocks(18);
        let base = simulate(&cfgs, &calib, SimMode::Default, 1, thr, true);
        let comp = simulate(&cfgs, &calib, SimMode::Block(&sel), 1, thr,
                            true);
        assert!(
            comp.hours < base.hours,
            "comp {} vs base {}",
            comp.hours,
            base.hours
        );
        assert!(comp.configs_evaluated <= base.configs_evaluated);
        if let (Some(b), Some(c)) =
            (base.winner_size_frac, comp.winner_size_frac)
        {
            assert!(c <= b + 1e-9);
        }
        assert!(comp.overhead_frac > 0.0);
    }

    #[test]
    fn more_nodes_cut_wall_clock() {
        let calib = Calibration::paper_scale(0.85);
        let cfgs = sample_sim_subspace(200, 9);
        let thr = calib.base_acc - 0.0;
        let t1 = simulate(&cfgs, &calib, SimMode::Default, 1, thr, true);
        let t16 = simulate(&cfgs, &calib, SimMode::Default, 16, thr, true);
        assert!(t16.hours < t1.hours);
    }

    #[test]
    fn no_stop_explores_everything() {
        let calib = Calibration::paper_scale(0.85);
        let cfgs = sample_sim_subspace(50, 3);
        let out = simulate(&cfgs, &calib, SimMode::Default, 4, 2.0, false);
        assert_eq!(out.configs_evaluated, 50);
        assert!(out.winner_size_frac.is_none());
    }

    #[test]
    fn lower_threshold_stops_sooner() {
        let calib = Calibration::paper_scale(0.85);
        let cfgs = sample_sim_subspace(300, 5);
        let hard =
            simulate(&cfgs, &calib, SimMode::Default, 1,
                     calib.base_acc - 0.01, true);
        let easy =
            simulate(&cfgs, &calib, SimMode::Default, 1,
                     calib.base_acc - 0.06, true);
        assert!(easy.configs_evaluated <= hard.configs_evaluated);
        assert!(easy.hours <= hard.hours);
    }
}
