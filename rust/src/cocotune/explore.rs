//! Promising-subspace exploration (paper §2.2.3): train every pruned
//! configuration — default (from inherited weights) or block-trained
//! (assembled from the pre-trained bank) — in ascending model-size order,
//! stopping at the first configuration that meets the accuracy objective.
//!
//! This is the REAL tier: every training run executes the AOT train_step
//! through PJRT. The scaled tier (cluster.rs + calib.rs) replays the
//! paper's full 500-config protocol using a model calibrated from these
//! runs.

use anyhow::Result;

use super::pretrain::{assemble, BlockBank};
use super::trainer::{
    config_masks, config_model_size, Config, ModelState, TrainOpts,
    Trainer,
};
use crate::runtime::manifest::DatasetSpec;

/// How a pruned network is initialized before fine-tuning.
pub enum InitMode<'a> {
    /// Baseline: inherit the surviving weights of the full model.
    Default,
    /// CoCo-Tune: assemble from the pre-trained tuning-block bank.
    BlockTrained(&'a BlockBank),
}

/// Result for one explored configuration.
#[derive(Debug, Clone)]
pub struct ConfigResult {
    pub config: Config,
    pub model_size: u64,
    pub final_acc: f64,
    pub steps: usize,
    pub initial_acc: f64,
    pub acc_curve: Vec<(usize, f64)>,
}

/// Exploration outcome.
#[derive(Debug, Clone)]
pub struct ExploreOutcome {
    pub results: Vec<ConfigResult>,
    /// Index (into `results`) of the first config meeting the objective.
    pub found: Option<usize>,
    pub total_steps: usize,
}

/// Sort configs by ascending effective model size (the paper's
/// exploration order for the min-size objective).
pub fn order_by_size(trainer: &Trainer, teacher: &ModelState,
                     configs: &[Config]) -> Vec<(Config, u64)> {
    let mut sized: Vec<(Config, u64)> = configs
        .iter()
        .map(|c| {
            let masks = config_masks(&trainer.spec, teacher, c);
            (c.clone(), config_model_size(&trainer.spec, &masks))
        })
        .collect();
    sized.sort_by_key(|(_, s)| *s);
    sized
}

/// Explore `configs` (ascending size) until one reaches `target_acc`
/// (or all are exhausted if `stop_at_target` is false).
#[allow(clippy::too_many_arguments)]
pub fn explore(trainer: &Trainer, teacher: &ModelState,
               ds: &DatasetSpec, configs: &[Config], mode: InitMode,
               opts: &TrainOpts, target_acc: f64, stop_at_target: bool)
               -> Result<ExploreOutcome> {
    let sized = order_by_size(trainer, teacher, configs);
    let mut results = Vec::new();
    let mut found = None;
    let mut total_steps = 0;
    for (ci, (config, model_size)) in sized.iter().enumerate() {
        let masks = config_masks(&trainer.spec, teacher, config);
        let mut state = match &mode {
            InitMode::Default => {
                let mut s = teacher.clone();
                s.zero_vels();
                s
            }
            InitMode::BlockTrained(bank) => {
                assemble(&trainer.spec, teacher, bank, config)
            }
        };
        let initial_acc = trainer.evaluate(
            &state, &masks, ds, opts.eval_batches, opts.seed ^ 0xACC)?;
        // Block-trained networks can already meet the objective before
        // any fine-tuning (paper: pre-trained blocks give a "much
        // improved starting setting") — skip training entirely then.
        let (final_acc, steps, acc_curve) = if initial_acc >= target_acc {
            (initial_acc, 0, vec![(0, initial_acc)])
        } else {
            let mut run_opts = opts.clone();
            run_opts.target_acc = Some(target_acc);
            run_opts.seed = opts.seed.wrapping_add(ci as u64 * 7_577);
            let res = trainer.train(&mut state, &masks, ds, &run_opts)?;
            (res.final_acc, res.steps, res.acc_curve)
        };
        total_steps += steps;
        let hit = final_acc >= target_acc;
        results.push(ConfigResult {
            config: config.clone(),
            model_size: *model_size,
            final_acc,
            steps,
            initial_acc,
            acc_curve,
        });
        if hit && found.is_none() {
            found = Some(results.len() - 1);
            if stop_at_target {
                break;
            }
        }
    }
    Ok(ExploreOutcome {
        results,
        found,
        total_steps,
    })
}

#[cfg(test)]
mod tests {
    // Exploration over real PJRT training is covered by the integration
    // test rust/tests/cocotune_e2e.rs (requires artifacts).
    use super::*;

    #[test]
    fn config_result_is_cloneable_and_debug() {
        let r = ConfigResult {
            config: vec![1, 2],
            model_size: 100,
            final_acc: 0.5,
            steps: 10,
            initial_acc: 0.1,
            acc_curve: vec![(10, 0.5)],
        };
        let s = format!("{:?}", r.clone());
        assert!(s.contains("model_size"));
    }
}
