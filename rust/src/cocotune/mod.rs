//! CoCo-Tune: composability-based CNN pruning (paper §2.2).
//!
//! * `sequitur`    — hierarchical grammar inference over layer sequences
//! * `blocks`      — tuning-block identification (paper's two heuristics)
//! * `trainer`     — PJRT-driven training/eval loops (the real tier)
//! * `pretrain`    — Teacher-Student concurrent block pre-training
//! * `explore`     — smallest-first subspace exploration
//! * `calib`       — behaviour model fitted from real-tier runs
//! * `cluster`     — discrete-event replay of the paper's full protocol
//! * `admm_driver` — CoCo-Gen's ADMM pattern-pruning training stage

pub mod admm_driver;
pub mod blocks;
pub mod calib;
pub mod cluster;
pub mod explore;
pub mod pretrain;
pub mod sequitur;
pub mod trainer;
