//! ADMM pattern-pruning driver (paper §2.1.3 "pattern-based training
//! stage"): alternates PJRT `admm_train_step` mini-batches (which add the
//! proximal pull rho*(W - Z + U) to the gradient) with host-side Z/U
//! updates, where the Z-update is the Euclidean projection of W + U onto
//! the pattern-constraint set (patterns::project_kernel) plus optional
//! connectivity pruning. Ends with a hard projection + masked fine-tune.

use anyhow::Result;
use std::collections::HashMap;

use super::trainer::{ModelState, Trainer};
use crate::data;
use crate::patterns;
use crate::runtime::manifest::DatasetSpec;
use crate::runtime::HostTensor;

/// ADMM hyper-parameters.
#[derive(Debug, Clone)]
pub struct AdmmOpts {
    pub rho: f32,
    pub lr: f32,
    pub steps: usize,
    /// Z/U update every this many SGD steps.
    pub project_every: usize,
    pub seed: u64,
}

impl Default for AdmmOpts {
    fn default() -> Self {
        AdmmOpts {
            rho: 0.05,
            lr: 0.03,
            steps: 120,
            project_every: 20,
            seed: 0,
        }
    }
}

/// Result of the ADMM stage.
pub struct AdmmResult {
    pub losses: Vec<f32>,
    /// Final pattern masks per mask tensor name.
    pub masks: HashMap<String, HostTensor>,
    /// Mean distance ||W - Z|| at each projection point (should shrink).
    pub primal_residuals: Vec<f64>,
}

/// Project every 3x3 conv weight of `name`d tensor onto the pattern set;
/// returns (projected tensor, binary mask).
fn project_tensor(shape: &[usize], w: &[f32]) -> (Vec<f32>, Vec<f32>) {
    // HWIO layout [kh, kw, cin, cout], only 3x3 get pattern projection.
    if shape.len() == 4 && shape[0] == 3 && shape[1] == 3 {
        let (cin, cout) = (shape[2], shape[3]);
        let mut z = vec![0f32; w.len()];
        let mut m = vec![0f32; w.len()];
        for ci in 0..cin {
            for co in 0..cout {
                let mut k = [0f32; 9];
                for t in 0..9 {
                    k[t] = w[t * cin * cout + ci * cout + co];
                }
                let (proj, pid) = patterns::project_kernel(&k);
                for t in 0..9 {
                    z[t * cin * cout + ci * cout + co] = proj[t];
                }
                for &(dy, dx) in
                    &patterns::PATTERN_SET_4[pid as usize]
                {
                    m[(dy * 3 + dx) * cin * cout + ci * cout + co] = 1.0;
                }
            }
        }
        (z, m)
    } else {
        // non-3x3 (1x1 convs, depthwise): no pattern constraint
        (w.to_vec(), vec![1f32; w.len()])
    }
}

/// Run ADMM pattern pruning on a trained model.
pub fn admm_pattern_prune(trainer: &Trainer, state: &mut ModelState,
                          ds: &DatasetSpec, opts: &AdmmOpts)
                          -> Result<AdmmResult> {
    let rt = trainer.rt;
    let spec = &trainer.spec;
    let exe = rt.load_model_artifact(&spec.name, "admm_train_step")?;
    let size = rt.manifest.image_size;
    let ones_masks: Vec<HostTensor> = spec
        .masks
        .iter()
        .map(|t| HostTensor::ones(&t.shape))
        .collect();
    // Z init = projection of W; U = 0.
    let mask_param_idx: Vec<usize> = spec
        .masks
        .iter()
        .map(|t| {
            spec.params
                .iter()
                .position(|p| p.name == t.name)
                .expect("mask matches param")
        })
        .collect();
    let mut zs: Vec<HostTensor> = Vec::new();
    let mut us: Vec<HostTensor> = Vec::new();
    for (mi, t) in spec.masks.iter().enumerate() {
        let w = state.params[mask_param_idx[mi]].as_f32()?;
        let (z, _) = project_tensor(&t.shape, w);
        zs.push(HostTensor::f32(&t.shape, z));
        us.push(HostTensor::zeros(&t.shape));
    }

    let mut losses = Vec::new();
    let mut primal = Vec::new();
    for s in 0..opts.steps {
        let batch = data::make_batch(ds, size, spec.train_batch,
                                     opts.seed.wrapping_add(s as u64 * 31));
        let np = state.params.len();
        let mut inputs = Vec::new();
        inputs.extend(state.params.iter().cloned());
        inputs.extend(state.vels.iter().cloned());
        inputs.extend(ones_masks.iter().cloned());
        inputs.extend(zs.iter().cloned());
        inputs.extend(us.iter().cloned());
        inputs.push(HostTensor::f32(
            &[batch.n, batch.size, batch.size, 3],
            batch.x.clone(),
        ));
        inputs.push(HostTensor::i32(&[batch.n], batch.y.clone()));
        inputs.push(HostTensor::scalar_f32(opts.lr));
        inputs.push(HostTensor::scalar_f32(opts.rho));
        let mut out = exe.run(&inputs)?;
        let _acc = out.pop().unwrap();
        let loss = out.pop().unwrap().scalar()?;
        losses.push(loss);
        let vels = out.split_off(np);
        state.params = out;
        state.vels = vels;

        if (s + 1) % opts.project_every == 0 {
            // Z-update: project W + U; U-update: U += W - Z.
            let mut resid = 0f64;
            let mut count = 0usize;
            for (mi, t) in spec.masks.iter().enumerate() {
                let w = state.params[mask_param_idx[mi]].as_f32()?;
                let u = us[mi].as_f32()?;
                let wu: Vec<f32> =
                    w.iter().zip(u).map(|(a, b)| a + b).collect();
                let (z, _) = project_tensor(&t.shape, &wu);
                let new_u: Vec<f32> = wu
                    .iter()
                    .zip(&z)
                    .map(|(a, b)| a - b)
                    .collect();
                resid += w
                    .iter()
                    .zip(&z)
                    .map(|(a, b)| ((a - b) * (a - b)) as f64)
                    .sum::<f64>();
                count += w.len();
                zs[mi] = HostTensor::f32(&t.shape, z);
                us[mi] = HostTensor::f32(&t.shape, new_u);
            }
            primal.push((resid / count.max(1) as f64).sqrt());
        }
    }

    // Hard projection: final masks from the converged W.
    let mut masks = HashMap::new();
    for (mi, t) in spec.masks.iter().enumerate() {
        let pi = mask_param_idx[mi];
        let w = state.params[pi].as_f32()?.to_vec();
        let (z, m) = project_tensor(&t.shape, &w);
        state.params[pi] = HostTensor::f32(&t.shape, z);
        masks.insert(t.name.clone(), HostTensor::f32(&t.shape, m));
    }
    Ok(AdmmResult {
        losses,
        masks,
        primal_residuals: primal,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn project_tensor_3x3_keeps_4_of_9() {
        let (cin, cout) = (3, 5);
        let shape = vec![3, 3, cin, cout];
        let w: Vec<f32> = (0..9 * cin * cout)
            .map(|i| ((i * 37 % 19) as f32) - 9.0)
            .collect();
        let (z, m) = project_tensor(&shape, &w);
        // mask keeps exactly 4 taps per kernel
        for ci in 0..cin {
            for co in 0..cout {
                let kept: f32 = (0..9)
                    .map(|t| m[t * cin * cout + ci * cout + co])
                    .sum();
                assert_eq!(kept, 4.0);
            }
        }
        // z zeroes exactly the masked-out entries
        for (i, (zv, mv)) in z.iter().zip(&m).enumerate() {
            if *mv == 0.0 {
                assert_eq!(*zv, 0.0, "index {i}");
            } else {
                assert_eq!(*zv, w[i]);
            }
        }
    }

    #[test]
    fn project_tensor_non3x3_is_identity() {
        let shape = vec![1, 1, 4, 4];
        let w: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let (z, m) = project_tensor(&shape, &w);
        assert_eq!(z, w);
        assert!(m.iter().all(|v| *v == 1.0));
    }
}
