//! PJRT-driven model training — the real tier of the CoCo-Tune
//! experiments. Rust owns the training loop, data generation, masking and
//! evaluation; the compute graph is the AOT-compiled `train_step`
//! artifact. Python never runs here.

use anyhow::{anyhow, Result};
use std::sync::Arc;

use crate::data;
use crate::runtime::manifest::DatasetSpec;
use crate::runtime::{Executable, HostTensor, ModelSpec, Runtime};
use crate::util::rng::Rng;

/// Pruning rates of the promising subspace (paper: Γ = {30%, 50%, 70%},
/// rate index 0 = unpruned).
pub const RATES: [f64; 4] = [0.0, 0.3, 0.5, 0.7];

/// A pruned-network configuration: rate index per prunable module.
pub type Config = Vec<u8>;

/// Host-side parameter state of a model.
#[derive(Clone)]
pub struct ModelState {
    pub params: Vec<HostTensor>,
    pub vels: Vec<HostTensor>,
}

impl ModelState {
    /// He-initialized fresh state.
    pub fn init(spec: &ModelSpec, seed: u64) -> ModelState {
        let mut rng = Rng::seed_from(seed);
        let params = spec
            .params
            .iter()
            .map(|t| {
                let n = t.elements();
                let fan_in: usize = match t.shape.len() {
                    4 => t.shape[0] * t.shape[1] * t.shape[2],
                    3 => t.shape[0] * t.shape[1],
                    2 => t.shape[0],
                    _ => 1,
                };
                let data = if t.name.ends_with(".b") {
                    vec![0f32; n]
                } else if t.shape.len() == 2 {
                    // FC layers: Xavier at reduced gain keeps initial
                    // logits small (stable with momentum SGD).
                    let scale = (1.0 / fan_in as f64).sqrt() * 0.5;
                    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
                } else {
                    let scale = (2.0 / fan_in as f64).sqrt();
                    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
                };
                HostTensor::f32(&t.shape, data)
            })
            .collect::<Vec<_>>();
        let vels = spec
            .params
            .iter()
            .map(|t| HostTensor::zeros(&t.shape))
            .collect();
        ModelState { params, vels }
    }

    pub fn zero_vels(&mut self) {
        for v in self.vels.iter_mut() {
            if let HostTensor::F32 { data, .. } = v {
                data.iter_mut().for_each(|x| *x = 0.0);
            }
        }
    }

    /// Parameter tensor by name.
    pub fn param<'a>(&'a self, spec: &ModelSpec, name: &str)
                     -> Option<&'a HostTensor> {
        spec.params
            .iter()
            .position(|t| t.name == name)
            .map(|i| &self.params[i])
    }
}

/// Filter-pruning masks for a configuration: within each prunable module,
/// the FIRST conv's least-important output filters (L1 norm over the
/// reference weights) are removed at the module's rate; the module's top
/// layer stays unpruned (paper §2.2.3 practice).
pub fn config_masks(spec: &ModelSpec, reference: &ModelState,
                    config: &Config) -> Vec<HostTensor> {
    assert_eq!(config.len(), spec.prunable_modules.len());
    let mut masks: Vec<HostTensor> =
        spec.masks.iter().map(|t| HostTensor::ones(&t.shape)).collect();
    for (mi, module) in spec.prunable_modules.iter().enumerate() {
        let rate = RATES[config[mi] as usize];
        if rate == 0.0 {
            continue;
        }
        // first mask of this module = its first conv
        let prefix = format!("{module}.");
        let Some(mask_idx) =
            spec.masks.iter().position(|t| t.name.starts_with(&prefix))
        else {
            continue;
        };
        let tspec = &spec.masks[mask_idx];
        let w = reference
            .param(spec, &tspec.name)
            .expect("reference param")
            .as_f32()
            .expect("f32 param");
        let shape = &tspec.shape;
        let cout = *shape.last().unwrap();
        let per_filter = tspec.elements() / cout;
        // L1 norm per output filter (last axis).
        let mut norms = vec![0f64; cout];
        for (i, v) in w.iter().enumerate() {
            norms[i % cout] += v.abs() as f64;
        }
        let n_drop = ((rate * cout as f64).floor() as usize).min(cout - 1);
        let mut order: Vec<usize> = (0..cout).collect();
        order.sort_by(|&a, &b| norms[a].partial_cmp(&norms[b]).unwrap());
        let dropped: std::collections::HashSet<usize> =
            order.into_iter().take(n_drop).collect();
        let mut m = vec![1f32; tspec.elements()];
        for i in 0..tspec.elements() {
            if dropped.contains(&(i % cout)) {
                m[i] = 0.0;
            }
        }
        let _ = per_filter;
        masks[mask_idx] = HostTensor::f32(shape, m);
        // Filter pruning also removes the consumers' input slices: the
        // next conv in the module whose cin equals this conv's cout reads
        // zero activations on the dropped channels, so those weights are
        // dead — masking them is function-preserving and is how filter
        // pruning actually shrinks the model (its real size saving).
        // Only the immediately following conv is a known consumer
        // (conv1->conv2 in res/vgg modules); branchy modules (inception)
        // are left alone — a later conv with matching cin need not read
        // this conv's output.
        let later = mask_idx + 1;
        if let Some(t2) = spec.masks.get(later) {
            if t2.name.starts_with(&prefix)
                && t2.shape.len() == 4
                && t2.shape[2] == cout
            {
                let cout2 = t2.shape[3];
                let mut m2 = masks[later].as_f32().unwrap().to_vec();
                for (i, v) in m2.iter_mut().enumerate() {
                    let ci = (i / cout2) % cout;
                    if dropped.contains(&ci) {
                        *v = 0.0;
                    }
                }
                masks[later] = HostTensor::f32(&t2.shape, m2);
            }
        }
    }
    masks
}

/// Effective model size (surviving parameters) of a configuration.
pub fn config_model_size(spec: &ModelSpec, masks: &[HostTensor]) -> u64 {
    let mut dropped = 0u64;
    for m in masks {
        if let Ok(d) = m.as_f32() {
            dropped += d.iter().filter(|v| **v == 0.0).count() as u64;
        }
    }
    spec.param_count - dropped
}

/// One training run's outcome.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub losses: Vec<f32>,
    pub final_acc: f64,
    pub steps: usize,
    /// Accuracy measured every `eval_every` steps (step, acc).
    pub acc_curve: Vec<(usize, f64)>,
}

/// Training-loop options.
#[derive(Debug, Clone)]
pub struct TrainOpts {
    pub steps: usize,
    pub lr: f32,
    pub eval_every: usize,
    /// Test batches per evaluation (batch size = infer artifact's batch).
    pub eval_batches: usize,
    /// Stop early once test accuracy reaches this value (if set).
    pub target_acc: Option<f64>,
    pub seed: u64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 200,
            lr: 0.02,
            eval_every: 50,
            eval_batches: 12,
            target_acc: None,
            seed: 0,
        }
    }
}

/// Trainer bound to one model's artifacts.
pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub spec: ModelSpec,
    train_exe: Arc<Executable>,
    infer_exe: Arc<Executable>,
    infer_batch: usize,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, model: &str) -> Result<Trainer<'rt>> {
        let spec = rt.manifest.model(model)?.clone();
        let train_exe = rt.load_model_artifact(model, "train_step")?;
        let infer_exe = rt.load_model_artifact(model, "infer_b8")?;
        let infer_batch = infer_exe
            .spec
            .inputs
            .last()
            .map(|t| t.shape[0])
            .ok_or_else(|| anyhow!("infer artifact missing x"))?;
        Ok(Trainer {
            rt,
            spec,
            train_exe,
            infer_exe,
            infer_batch,
        })
    }

    /// One SGD step; updates `state` in place; returns (loss, batch acc).
    pub fn step(&self, state: &mut ModelState, masks: &[HostTensor],
                batch: &data::Batch, lr: f32) -> Result<(f32, f32)> {
        let np = state.params.len();
        let mut inputs = Vec::with_capacity(2 * np + masks.len() + 3);
        inputs.extend(state.params.iter().cloned());
        inputs.extend(state.vels.iter().cloned());
        inputs.extend(masks.iter().cloned());
        inputs.push(HostTensor::f32(
            &[batch.n, batch.size, batch.size, 3],
            batch.x.clone(),
        ));
        inputs.push(HostTensor::i32(&[batch.n], batch.y.clone()));
        inputs.push(HostTensor::scalar_f32(lr));
        let mut out = self.train_exe.run(&inputs)?;
        let acc = out.pop().unwrap().scalar()?;
        let loss = out.pop().unwrap().scalar()?;
        let vels = out.split_off(np);
        state.params = out;
        state.vels = vels;
        Ok((loss, acc))
    }

    /// Test accuracy over `n_batches` generated test batches.
    pub fn evaluate(&self, state: &ModelState, masks: &[HostTensor],
                    ds: &DatasetSpec, n_batches: usize, seed: u64)
                    -> Result<f64> {
        let size = self.rt.manifest.image_size;
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in 0..n_batches {
            let batch = data::make_batch(ds, size, self.infer_batch,
                                         seed ^ (0xE5A1 + b as u64));
            let mut inputs = Vec::new();
            inputs.extend(state.params.iter().cloned());
            inputs.extend(masks.iter().cloned());
            inputs.push(HostTensor::f32(
                &[batch.n, batch.size, batch.size, 3],
                batch.x.clone(),
            ));
            let out = self.infer_exe.run(&inputs)?;
            let logits = out[0].as_f32()?;
            let classes = self.spec.classes;
            for i in 0..batch.n {
                let row = &logits[i * classes..(i + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(c, _)| c as i32)
                    .unwrap();
                if pred == batch.y[i] {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }

    /// Full training loop with periodic evaluation and optional early
    /// stop at `target_acc`.
    pub fn train(&self, state: &mut ModelState, masks: &[HostTensor],
                 ds: &DatasetSpec, opts: &TrainOpts) -> Result<TrainResult> {
        let size = self.rt.manifest.image_size;
        let mut losses = Vec::with_capacity(opts.steps);
        let mut acc_curve = Vec::new();
        let mut steps_done = 0;
        let mut final_acc = 0.0;
        for s in 0..opts.steps {
            let batch = data::make_batch(
                ds,
                size,
                self.spec.train_batch,
                opts.seed.wrapping_add(s as u64 * 7919),
            );
            let (loss, _) = self.step(state, masks, &batch, opts.lr)?;
            losses.push(loss);
            steps_done = s + 1;
            if (s + 1) % opts.eval_every == 0 || s + 1 == opts.steps {
                let acc = self.evaluate(state, masks, ds,
                                        opts.eval_batches,
                                        opts.seed ^ 0xDEAD)?;
                acc_curve.push((s + 1, acc));
                final_acc = acc;
                if let Some(t) = opts.target_acc {
                    if acc >= t {
                        break;
                    }
                }
            }
        }
        Ok(TrainResult {
            losses,
            final_acc,
            steps: steps_done,
            acc_curve,
        })
    }
}

/// Enumerate/sample a promising subspace of `n` configurations via random
/// sampling (paper: random sampling of the pruning space, close-to-uniform
/// size distribution), deduplicated, excluding the all-zero config.
pub fn sample_subspace(n_modules: usize, n: usize, seed: u64)
                       -> Vec<Config> {
    let mut rng = Rng::seed_from(seed);
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    let max_configs = 3usize.pow(n_modules as u32); // rates {30,50,70}
    while out.len() < n.min(max_configs) {
        let cfg: Config = (0..n_modules)
            .map(|_| 1 + rng.below(3) as u8)
            .collect();
        if seen.insert(cfg.clone()) {
            out.push(cfg);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_spec() -> ModelSpec {
        use crate::runtime::manifest::{DType, TensorSpec};
        ModelSpec {
            name: "fake".into(),
            input_shape: vec![16, 16, 3],
            classes: 16,
            params: vec![
                TensorSpec {
                    name: "m1.conv1.w".into(),
                    shape: vec![3, 3, 4, 8],
                    dtype: DType::F32,
                },
                TensorSpec {
                    name: "m1.conv1.b".into(),
                    shape: vec![8],
                    dtype: DType::F32,
                },
                TensorSpec {
                    name: "m1.conv2.w".into(),
                    shape: vec![3, 3, 8, 8],
                    dtype: DType::F32,
                },
            ],
            masks: vec![
                TensorSpec {
                    name: "m1.conv1.w".into(),
                    shape: vec![3, 3, 4, 8],
                    dtype: DType::F32,
                },
                TensorSpec {
                    name: "m1.conv2.w".into(),
                    shape: vec![3, 3, 8, 8],
                    dtype: DType::F32,
                },
            ],
            student_params: vec![],
            prunable_modules: vec!["m1".into()],
            flops: 1,
            param_count: 3 * 3 * 4 * 8 + 8 + 3 * 3 * 8 * 8,
            train_batch: 32,
            artifacts: Default::default(),
            modules: vec![],
        }
    }

    #[test]
    fn init_is_deterministic_and_shaped() {
        let spec = fake_spec();
        let a = ModelState::init(&spec, 7);
        let b = ModelState::init(&spec, 7);
        for (x, y) in a.params.iter().zip(&b.params) {
            assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
        }
        assert_eq!(a.params[0].shape(), &[3, 3, 4, 8]);
        // bias init to zero
        assert!(a.params[1].as_f32().unwrap().iter().all(|v| *v == 0.0));
    }

    #[test]
    fn config_masks_prune_first_conv_only() {
        let spec = fake_spec();
        let state = ModelState::init(&spec, 1);
        let masks = config_masks(&spec, &state, &vec![3]); // 70%
        let m1 = masks[0].as_f32().unwrap();
        let m2 = masks[1].as_f32().unwrap();
        // second conv keeps its weights except the input slices of the
        // dropped filters (consumer pruning — function-preserving)
        let cout2 = 8;
        let alive_rows = m2
            .chunks(cout2)
            .filter(|row| row.iter().all(|v| *v == 1.0))
            .count();
        assert_eq!(alive_rows, 3 * 3 * 3); // kh*kw*(8-5 surviving cin)
        // first conv: 70% of 8 filters -> 5 dropped
        let cout = 8;
        let mut dead = vec![true; cout];
        for (i, v) in m1.iter().enumerate() {
            if *v != 0.0 {
                dead[i % cout] = false;
            }
        }
        assert_eq!(dead.iter().filter(|d| **d).count(), 5);
    }

    #[test]
    fn model_size_accounts_for_dropped() {
        let spec = fake_spec();
        let state = ModelState::init(&spec, 1);
        let masks_full = config_masks(&spec, &state, &vec![0]);
        assert_eq!(config_model_size(&spec, &masks_full), spec.param_count);
        let masks = config_masks(&spec, &state, &vec![2]); // 50% -> 4 filters
        // conv1 loses kh*kw*cin*4 weights; conv2 loses its 4 dead input
        // slices kh*kw*4*cout2 (function-preserving consumer pruning).
        let dropped = 3 * 3 * 4 * 4 + 3 * 3 * 4 * 8;
        assert_eq!(
            config_model_size(&spec, &masks),
            spec.param_count - dropped as u64
        );
    }

    #[test]
    fn subspace_sampling_unique_and_nonzero() {
        let s = sample_subspace(6, 100, 3);
        assert_eq!(s.len(), 100);
        let set: std::collections::HashSet<_> = s.iter().cloned().collect();
        assert_eq!(set.len(), 100);
        assert!(s.iter().all(|c| c.iter().all(|r| (1..=3).contains(r))));
    }

    #[test]
    fn subspace_caps_at_space_size() {
        let s = sample_subspace(2, 100, 3);
        assert_eq!(s.len(), 9);
    }
}
