//! Hierarchical grammar inference for tuning-block discovery
//! (paper §2.2.2, Fig. 9).
//!
//! CoCo-Tune runs a hierarchical compression algorithm over the
//! concatenated layer sequences of all networks in the promising subspace;
//! repeated pruned-layer subsequences become grammar rules, and the rule
//! DAG drives tuning-block selection. The paper uses Sequitur
//! (Nevill-Manning & Witten 1997); we implement the Re-Pair variant
//! (Larsson & Moffat 1999) — it produces the same kind of CFG with the
//! same two invariants, with simpler bookkeeping:
//!
//!   * digram uniqueness — at termination no digram appears twice;
//!   * rule utility — every rule is used at least twice (rules are only
//!     created for digrams with >= 2 non-overlapping occurrences, and a
//!     use can only ever move into another rule body, never vanish).
//!
//! Block selection consumes only the CFG/DAG structure, so the choice of
//! grammar inferencer is interchangeable (documented in DESIGN.md).

use std::collections::HashMap;

/// Terminal symbols are user values (>= 0); rule references are negative.
pub type Symbol = i64;

/// A context-free grammar: rules[0] is the start rule S; the symbol
/// `-(i as i64)` references `rules[i]` (i >= 1).
#[derive(Debug, Clone)]
pub struct Grammar {
    pub rules: Vec<Vec<Symbol>>,
}

pub fn rule_index(sym: Symbol) -> Option<usize> {
    if sym < 0 {
        Some((-sym) as usize)
    } else {
        None
    }
}

impl Grammar {
    /// Expand a rule to its terminal string.
    pub fn expand(&self, rule: usize) -> Vec<Symbol> {
        let mut out = Vec::new();
        self.expand_into(rule, &mut out);
        out
    }
    fn expand_into(&self, rule: usize, out: &mut Vec<Symbol>) {
        for &s in &self.rules[rule] {
            match rule_index(s) {
                Some(r) => self.expand_into(r, out),
                None => out.push(s),
            }
        }
    }

    /// Direct reference count of every rule.
    pub fn direct_uses(&self) -> Vec<usize> {
        let mut uses = vec![0usize; self.rules.len()];
        for body in &self.rules {
            for &s in body {
                if let Some(r) = rule_index(s) {
                    uses[r] += 1;
                }
            }
        }
        uses
    }

    /// How many times each rule's yield occurs in the full expansion
    /// (via that rule). counts[0] == 1.
    pub fn expansion_counts(&self) -> Vec<usize> {
        // Rules only reference rules with smaller ids (Re-Pair creates
        // them bottom-up), but a fixpoint sweep is robust regardless.
        let mut counts = vec![0usize; self.rules.len()];
        counts[0] = 1;
        for _ in 0..self.rules.len().max(1) {
            let mut next = vec![0usize; self.rules.len()];
            next[0] = 1;
            for (r, body) in self.rules.iter().enumerate() {
                for &s in body {
                    if let Some(child) = rule_index(s) {
                        next[child] += counts[r];
                    }
                }
            }
            if next == counts {
                break;
            }
            counts = next;
        }
        counts
    }

    /// Rule ids directly referenced by `rule`.
    pub fn children(&self, rule: usize) -> Vec<usize> {
        self.rules[rule]
            .iter()
            .filter_map(|&s| rule_index(s))
            .collect()
    }

    /// Terminal length of each rule's yield.
    pub fn yield_lengths(&self) -> Vec<usize> {
        let mut lens = vec![0usize; self.rules.len()];
        // bottom-up: rule ids increase as they are created, and bodies only
        // reference earlier rules; compute in id order.
        for r in (0..self.rules.len()).rev() {
            let _ = r;
        }
        for r in 1..self.rules.len() {
            lens[r] = self.yield_len_rec(r, &mut vec![None; self.rules.len()]);
        }
        lens[0] = self.yield_len_rec(0, &mut vec![None; self.rules.len()]);
        lens
    }

    fn yield_len_rec(&self, r: usize, memo: &mut Vec<Option<usize>>)
                     -> usize {
        if let Some(v) = memo[r] {
            return v;
        }
        let mut n = 0;
        for &s in &self.rules[r] {
            n += match rule_index(s) {
                Some(c) => self.yield_len_rec(c, memo),
                None => 1,
            };
        }
        memo[r] = Some(n);
        n
    }
}

/// Count non-overlapping occurrences of each digram in `seq`.
fn digram_counts(seq: &[Symbol]) -> HashMap<(Symbol, Symbol), usize> {
    let mut counts = HashMap::new();
    let mut i = 0;
    // Non-overlapping greedy count per digram requires per-digram walk;
    // approximate with adjacent-pair counting, fixing the aaa case:
    let mut prev_same_run = 0usize;
    while i + 1 < seq.len() {
        let d = (seq[i], seq[i + 1]);
        if d.0 == d.1 {
            prev_same_run += 1;
            // count floor(run/2) occurrences for runs of equal symbols —
            // handled by only counting every other position.
            if prev_same_run % 2 == 1 {
                *counts.entry(d).or_insert(0) += 1;
            }
        } else {
            prev_same_run = 0;
            *counts.entry(d).or_insert(0) += 1;
        }
        i += 1;
    }
    counts
}

/// Replace all non-overlapping occurrences of digram `d` in `seq` with
/// `sym` (left-to-right greedy).
fn replace_digram(seq: &[Symbol], d: (Symbol, Symbol), sym: Symbol)
                  -> Vec<Symbol> {
    let mut out = Vec::with_capacity(seq.len());
    let mut i = 0;
    while i < seq.len() {
        if i + 1 < seq.len() && seq[i] == d.0 && seq[i + 1] == d.1 {
            out.push(sym);
            i += 2;
        } else {
            out.push(seq[i]);
            i += 1;
        }
    }
    out
}

/// Infer a hierarchical grammar over `input` (all symbols >= 0).
pub fn sequitur(input: &[Symbol]) -> Grammar {
    for &s in input {
        assert!(s >= 0, "input symbols must be non-negative");
    }
    let mut rules: Vec<Vec<Symbol>> = vec![input.to_vec()];
    loop {
        let counts = digram_counts(&rules[0]);
        let best = counts
            .into_iter()
            .filter(|(_, c)| *c >= 2)
            .max_by_key(|(d, c)| (*c, std::cmp::Reverse(*d)));
        match best {
            None => break,
            Some((d, _)) => {
                let rid = rules.len() as i64;
                rules.push(vec![d.0, d.1]);
                rules[0] = replace_digram(&rules[0], d, -rid);
            }
        }
    }
    enforce_utility(&mut rules);
    Grammar { rules }
}

/// Sequitur's rule-utility invariant: a rule referenced exactly once is
/// inlined at its single use site and removed (Re-Pair can strand such
/// rules when all occurrences of a rule get absorbed into a parent rule).
fn enforce_utility(rules: &mut Vec<Vec<Symbol>>) {
    loop {
        let mut uses = vec![0usize; rules.len()];
        for body in rules.iter() {
            for &s in body {
                if let Some(r) = rule_index(s) {
                    uses[r] += 1;
                }
            }
        }
        let single = (1..rules.len()).find(|&r| uses[r] == 1);
        let Some(victim) = single else { break };
        let body = rules[victim].clone();
        for parent in rules.iter_mut() {
            if let Some(pos) = parent
                .iter()
                .position(|&s| rule_index(s) == Some(victim))
            {
                parent.splice(pos..pos + 1, body.iter().copied());
                break;
            }
        }
        // Remove the victim and renumber references above it.
        rules.remove(victim);
        for body in rules.iter_mut() {
            for s in body.iter_mut() {
                if let Some(r) = rule_index(*s) {
                    if r > victim {
                        *s = -((r - 1) as i64);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn round_trips_input() {
        prop::check("sequitur-round-trip", 60, |g| {
            let n = g.usize(1, 200);
            let alphabet = g.usize(2, 6);
            let input: Vec<Symbol> =
                (0..n).map(|_| g.usize(0, alphabet - 1) as i64).collect();
            let gram = sequitur(&input);
            if gram.expand(0) != input {
                return Err("expansion != input".into());
            }
            Ok(())
        });
    }

    #[test]
    fn digram_uniqueness_at_termination() {
        prop::check("sequitur-digram-unique", 40, |g| {
            let n = g.usize(4, 150);
            let input: Vec<Symbol> =
                (0..n).map(|_| g.usize(0, 3) as i64).collect();
            let gram = sequitur(&input);
            let counts = digram_counts(&gram.rules[0]);
            for (d, c) in counts {
                if c >= 2 {
                    return Err(format!("digram {d:?} appears {c} times"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rule_utility_holds() {
        prop::check("sequitur-utility", 40, |gg| {
            let n = gg.usize(4, 150);
            let input: Vec<Symbol> =
                (0..n).map(|_| gg.usize(0, 3) as i64).collect();
            let g = sequitur(&input);
            let uses = g.direct_uses();
            for (r, u) in uses.iter().enumerate().skip(1) {
                if *u < 2 {
                    return Err(format!(
                        "rule {r} used {u} times: {:?}",
                        g.rules
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn finds_repeats_in_abcabc() {
        let input: Vec<Symbol> = vec![0, 1, 2, 0, 1, 2];
        let g = sequitur(&input);
        assert_eq!(g.expand(0), input);
        assert!(g.rules.len() > 1, "no rules inferred: {:?}", g.rules);
        let total: usize = g.rules.iter().map(|r| r.len()).sum();
        assert!(total < input.len(), "{:?}", g.rules);
    }

    #[test]
    fn expansion_counts_are_sound() {
        let input: Vec<Symbol> = vec![0, 1, 0, 1, 0, 1, 0, 1];
        let g = sequitur(&input);
        let counts = g.expansion_counts();
        let expanded = g.expand(0);
        for r in 1..g.rules.len() {
            let y = g.expand(r);
            let occur = count_subseq(&expanded, &y);
            assert!(counts[r] <= occur);
            assert!(counts[r] >= 2, "rule {r}: {:?}", g.rules);
        }
    }

    fn count_subseq(hay: &[Symbol], needle: &[Symbol]) -> usize {
        if needle.is_empty() || hay.len() < needle.len() {
            return 0;
        }
        (0..=hay.len() - needle.len())
            .filter(|&i| &hay[i..i + needle.len()] == needle)
            .count()
    }

    #[test]
    fn long_repetitive_input_compresses_well() {
        let unit: Vec<Symbol> = vec![3, 1, 4, 1, 5];
        let mut input = Vec::new();
        for _ in 0..20 {
            input.extend_from_slice(&unit);
        }
        let g = sequitur(&input);
        assert_eq!(g.expand(0), input);
        let total: usize = g.rules.iter().map(|r| r.len()).sum();
        assert!(total < input.len() / 2, "poor compression: {total}");
    }

    #[test]
    fn yield_lengths() {
        let input: Vec<Symbol> = vec![7, 8, 7, 8, 7, 8];
        let g = sequitur(&input);
        let lens = g.yield_lengths();
        assert_eq!(lens[0], 6);
        for r in 1..g.rules.len() {
            assert!(lens[r] >= 2);
        }
    }

    #[test]
    fn run_of_equal_symbols() {
        let input: Vec<Symbol> = vec![5; 9];
        let g = sequitur(&input);
        assert_eq!(g.expand(0), input);
    }
}
