//! Teacher-Student tuning-block pre-training (paper §2.2.2, Fig. 10).
//!
//! The AOT `block_pretrain` artifact runs the full (teacher) model forward
//! once per batch and trains pruned copies of ALL prunable modules
//! concurrently against the teacher's activation maps — the paper's
//! Fig. 10(b) structure, where teacher activations are shared across the
//! students for free.
//!
//! One pre-training run covers every module at one pruning rate; running
//! once per rate in Γ builds the full bank of
//! (module, rate) -> pre-trained weights used by assembly.

use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

use super::trainer::{config_masks, Config, ModelState, Trainer, RATES};
use crate::data;
use crate::runtime::manifest::DatasetSpec;
use crate::runtime::{Executable, HostTensor};

/// Bank of pre-trained tuning blocks: (module index, rate index) -> the
/// module's parameter tensors (by student-param order).
pub struct BlockBank {
    /// bank[(module_idx, rate_idx)] -> Vec<(param name, tensor)>
    pub blocks: HashMap<(usize, u8), Vec<(String, HostTensor)>>,
    /// Pre-training cost in train-equivalent steps (for overhead
    /// accounting in Table 3/4).
    pub pretrain_steps: usize,
    /// Reconstruction-loss curves per rate (step, total loss).
    pub loss_curves: HashMap<u8, Vec<(usize, f32)>>,
}

/// Pre-train all prunable modules at every rate in Γ\{0}.
pub fn pretrain_bank(trainer: &Trainer, teacher: &ModelState,
                     ds: &DatasetSpec, steps_per_rate: usize, lr: f32,
                     seed: u64) -> Result<BlockBank> {
    let rt = trainer.rt;
    let spec = &trainer.spec;
    let exe: Arc<Executable> =
        rt.load_model_artifact(&spec.name, "block_pretrain")?;
    let size = rt.manifest.image_size;
    let student_names = spec.student_params.clone();
    // student params start as copies of the teacher's module params
    let student_init: Vec<HostTensor> = student_names
        .iter()
        .map(|n| teacher.param(spec, n).expect("student param").clone())
        .collect();

    let mut bank = HashMap::new();
    let mut loss_curves = HashMap::new();
    let mut total_steps = 0usize;
    for rate_idx in 1..RATES.len() as u8 {
        if rate_idx > 3 {
            break;
        }
        // uniform-rate config for mask construction
        let cfg: Config = vec![rate_idx; spec.prunable_modules.len()];
        let masks = config_masks(spec, teacher, &cfg);
        let mut sparams = student_init.clone();
        let mut svels: Vec<HostTensor> = sparams
            .iter()
            .map(|t| HostTensor::zeros(t.shape()))
            .collect();
        let mut curve = Vec::new();
        for s in 0..steps_per_rate {
            let batch = data::make_batch(
                ds,
                size,
                spec.train_batch,
                seed ^ (rate_idx as u64) << 32 ^ (s as u64 * 104729),
            );
            let mut inputs = Vec::new();
            inputs.extend(teacher.params.iter().cloned());
            inputs.extend(sparams.iter().cloned());
            inputs.extend(svels.iter().cloned());
            inputs.extend(masks.iter().cloned());
            inputs.push(HostTensor::f32(
                &[batch.n, batch.size, batch.size, 3],
                batch.x.clone(),
            ));
            inputs.push(HostTensor::scalar_f32(lr));
            let mut out = exe.run(&inputs)?;
            let losses = out.pop().unwrap();
            let total: f32 =
                losses.as_f32()?.iter().sum();
            curve.push((s, total));
            let nv = out.split_off(sparams.len());
            sparams = out;
            svels = nv;
            total_steps += 1;
        }
        loss_curves.insert(rate_idx, curve);
        // Split the flat student params into per-module banks.
        for (mi, module) in spec.prunable_modules.iter().enumerate() {
            let prefix = format!("{module}.");
            let entry: Vec<(String, HostTensor)> = student_names
                .iter()
                .zip(&sparams)
                .filter(|(n, _)| n.starts_with(&prefix))
                .map(|(n, t)| (n.clone(), t.clone()))
                .collect();
            bank.insert((mi, rate_idx), entry);
        }
    }
    Ok(BlockBank {
        blocks: bank,
        pretrain_steps: total_steps,
        loss_curves,
    })
}

/// Assemble a block-trained network for `config`: start from the teacher
/// weights, overwrite each prunable module's params with its pre-trained
/// block at the module's rate (paper's "assembly step": initialize with
/// the tuning-block weights).
pub fn assemble(spec: &crate::runtime::ModelSpec, teacher: &ModelState,
                bank: &BlockBank, config: &Config) -> ModelState {
    let mut state = teacher.clone();
    state.zero_vels();
    for (mi, &rate_idx) in config.iter().enumerate() {
        if rate_idx == 0 {
            continue;
        }
        let Some(block) = bank.blocks.get(&(mi, rate_idx)) else {
            continue;
        };
        for (name, tensor) in block {
            if let Some(pi) =
                spec.params.iter().position(|t| &t.name == name)
            {
                state.params[pi] = tensor.clone();
            }
        }
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{DType, TensorSpec};
    use crate::runtime::ModelSpec;

    fn spec2() -> ModelSpec {
        let t = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.into(),
            shape,
            dtype: DType::F32,
        };
        ModelSpec {
            name: "fake".into(),
            input_shape: vec![16, 16, 3],
            classes: 16,
            params: vec![
                t("m1.c.w", vec![3, 3, 4, 4]),
                t("m2.c.w", vec![3, 3, 4, 4]),
            ],
            masks: vec![
                t("m1.c.w", vec![3, 3, 4, 4]),
                t("m2.c.w", vec![3, 3, 4, 4]),
            ],
            student_params: vec!["m1.c.w".into(), "m2.c.w".into()],
            prunable_modules: vec!["m1".into(), "m2".into()],
            flops: 1,
            param_count: 288,
            train_batch: 32,
            artifacts: Default::default(),
            modules: vec![],
        }
    }

    #[test]
    fn assemble_overwrites_only_configured_modules() {
        let spec = spec2();
        let teacher = ModelState::init(&spec, 3);
        let mut bank = BlockBank {
            blocks: HashMap::new(),
            pretrain_steps: 0,
            loss_curves: HashMap::new(),
        };
        let marked = HostTensor::f32(&[3, 3, 4, 4], vec![9.0; 144]);
        bank.blocks
            .insert((0, 2), vec![("m1.c.w".into(), marked.clone())]);
        let st = assemble(&spec, &teacher, &bank, &vec![2, 0]);
        assert_eq!(st.params[0].as_f32().unwrap()[0], 9.0);
        // module 2 untouched
        assert_eq!(
            st.params[1].as_f32().unwrap(),
            teacher.params[1].as_f32().unwrap()
        );
        // missing bank entry -> teacher weights kept
        let st2 = assemble(&spec, &teacher, &bank, &vec![3, 3]);
        assert_eq!(
            st2.params[0].as_f32().unwrap(),
            teacher.params[0].as_f32().unwrap()
        );
    }
}
