//! Hierarchical tuning-block identification (paper §2.2.2, Fig. 9).
//!
//! Encodes the promising subspace as symbol sequences (one per network,
//! symbol = (module, rate)), runs the hierarchical grammar inference
//! (sequitur.rs) on the concatenation, and selects the rules worth
//! pre-training with the paper's two heuristics:
//!   1. a rule used in only one network is never selected;
//!   2. a rule is preferred over its children only if it appears as often
//!      as its most frequently appearing descendant.
//! Any (module, rate) pair left uncovered becomes a singleton block.

use std::collections::{BTreeSet, HashMap};

use super::sequitur::{self, Grammar, Symbol};
use super::trainer::Config;

/// A tuning block: a run of consecutive prunable modules, each at a rate.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TuningBlock {
    pub start_module: usize,
    /// rate index per module in the run (len >= 1).
    pub rates: Vec<u8>,
}

impl TuningBlock {
    pub fn len(&self) -> usize {
        self.rates.len()
    }
    pub fn is_empty(&self) -> bool {
        self.rates.is_empty()
    }
    /// The (module, rate) pairs this block covers.
    pub fn pairs(&self) -> Vec<(usize, u8)> {
        self.rates
            .iter()
            .enumerate()
            .map(|(i, &r)| (self.start_module + i, r))
            .collect()
    }
}

const NRATES: i64 = 4;

fn encode(module: usize, rate: u8) -> Symbol {
    module as i64 * NRATES + rate as i64
}

fn decode(sym: Symbol) -> (usize, u8) {
    ((sym / NRATES) as usize, (sym % NRATES) as u8)
}

/// Result of block identification.
#[derive(Debug, Clone)]
pub struct BlockSelection {
    pub blocks: Vec<TuningBlock>,
    /// Frequency (number of networks) per selected block.
    pub frequencies: Vec<usize>,
    pub grammar_rules: usize,
}

impl BlockSelection {
    /// Total pre-training cost in module-units: the number of DISTINCT
    /// (module, rate) pairs across the selection. A multi-module block
    /// trains its modules jointly in one Teacher-Student run, so each
    /// pair costs one unit whether it is trained inside a run or as a
    /// singleton; overlapping selections don't pay twice.
    pub fn pretrain_module_units(&self) -> usize {
        let mut pairs = BTreeSet::new();
        for b in &self.blocks {
            pairs.extend(b.pairs());
        }
        pairs.len()
    }
    pub fn multi_module_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.len() > 1).count()
    }
}

/// Identify tuning blocks for a promising subspace.
pub fn identify_blocks(configs: &[Config], n_modules: usize)
                       -> BlockSelection {
    // Concatenate network sequences with unique separators so no rule can
    // span a network boundary (separator symbols never repeat).
    let sep_base = encode(n_modules, 0);
    let mut input: Vec<Symbol> = Vec::new();
    for (ni, cfg) in configs.iter().enumerate() {
        assert_eq!(cfg.len(), n_modules);
        for (mi, &r) in cfg.iter().enumerate() {
            input.push(encode(mi, r));
        }
        input.push(sep_base + ni as i64);
    }
    let grammar = sequitur::sequitur(&input);
    let counts = grammar.expansion_counts();

    // Validity: a rule's yield must decode to consecutive modules with no
    // separators.
    let valid_yield = |rule: usize| -> Option<TuningBlock> {
        let y = grammar.expand(rule);
        let mut rates = Vec::with_capacity(y.len());
        let mut start = None;
        for (i, &s) in y.iter().enumerate() {
            if s >= sep_base {
                return None;
            }
            let (m, r) = decode(s);
            match start {
                None => start = Some(m),
                Some(st) if m != st + i => return None,
                _ => {}
            }
            rates.push(r);
        }
        start.map(|s| TuningBlock {
            start_module: s,
            rates,
        })
    };

    // Max expansion count over all descendants of a rule.
    fn max_desc(g: &Grammar, counts: &[usize], rule: usize,
                memo: &mut HashMap<usize, usize>) -> usize {
        if let Some(&v) = memo.get(&rule) {
            return v;
        }
        let mut m = 0;
        for c in g.children(rule) {
            m = m.max(counts[c]).max(max_desc(g, counts, c, memo));
        }
        memo.insert(rule, m);
        m
    }

    // Top-down selection from the start rule's children.
    let mut memo = HashMap::new();
    let mut selected: BTreeSet<TuningBlock> = BTreeSet::new();
    let mut freqs: HashMap<TuningBlock, usize> = HashMap::new();
    let mut stack: Vec<usize> = grammar.children(0);
    let mut visited = vec![false; grammar.rules.len()];
    while let Some(r) = stack.pop() {
        if visited[r] {
            continue;
        }
        visited[r] = true;
        let take = counts[r] >= 2
            && counts[r] >= max_desc(&grammar, &counts, r, &mut memo);
        if take {
            if let Some(block) = valid_yield(r) {
                freqs.entry(block.clone())
                    .and_modify(|f| *f = (*f).max(counts[r]))
                    .or_insert(counts[r]);
                selected.insert(block);
                continue; // prefer this rule over its children
            }
        }
        stack.extend(grammar.children(r));
    }

    // Coverage: every (module, rate) pair in the subspace must be covered.
    let mut covered: BTreeSet<(usize, u8)> = BTreeSet::new();
    for b in &selected {
        covered.extend(b.pairs());
    }
    let mut pair_freq: HashMap<(usize, u8), usize> = HashMap::new();
    for cfg in configs {
        for (mi, &r) in cfg.iter().enumerate() {
            *pair_freq.entry((mi, r)).or_insert(0) += 1;
        }
    }
    for (&(mi, r), &f) in &pair_freq {
        if r != 0 && !covered.contains(&(mi, r)) {
            let b = TuningBlock {
                start_module: mi,
                rates: vec![r],
            };
            freqs.insert(b.clone(), f);
            selected.insert(b);
        }
    }

    let blocks: Vec<TuningBlock> = selected.into_iter().collect();
    let frequencies = blocks.iter().map(|b| freqs[b]).collect();
    BlockSelection {
        blocks,
        frequencies,
        grammar_rules: grammar.rules.len() - 1,
    }
}

/// Baseline block definition: every (module, rate) pair that occurs in
/// the subspace is its own tuning block ("every convolution module as a
/// tuning block", the paper's default before the identifier is applied).
pub fn per_module_blocks(configs: &[Config], n_modules: usize)
                         -> BlockSelection {
    let mut pair_freq: HashMap<(usize, u8), usize> = HashMap::new();
    for cfg in configs {
        for (mi, &r) in cfg.iter().enumerate() {
            if r != 0 {
                *pair_freq.entry((mi, r)).or_insert(0) += 1;
            }
        }
    }
    let _ = n_modules;
    let mut pairs: Vec<((usize, u8), usize)> =
        pair_freq.into_iter().collect();
    pairs.sort();
    let blocks: Vec<TuningBlock> = pairs
        .iter()
        .map(|((m, r), _)| TuningBlock {
            start_module: *m,
            rates: vec![*r],
        })
        .collect();
    let frequencies = pairs.iter().map(|(_, f)| *f).collect();
    BlockSelection {
        blocks,
        frequencies,
        grammar_rules: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_round_trip() {
        for m in 0..10 {
            for r in 0..4u8 {
                assert_eq!(decode(encode(m, r)), (m, r));
            }
        }
    }

    #[test]
    fn identical_configs_yield_whole_network_block() {
        // 4 identical networks -> the full sequence is one repeated block.
        let cfg: Config = vec![1, 2, 3, 1];
        let configs = vec![cfg.clone(); 4];
        let sel = identify_blocks(&configs, 4);
        // Must contain a multi-module block covering consecutive modules.
        assert!(
            sel.multi_module_blocks() >= 1,
            "blocks: {:?}",
            sel.blocks
        );
        // All pairs covered.
        let mut covered = BTreeSet::new();
        for b in &sel.blocks {
            covered.extend(b.pairs());
        }
        for (mi, &r) in cfg.iter().enumerate() {
            assert!(covered.contains(&(mi, r)));
        }
    }

    #[test]
    fn independent_configs_fall_back_to_singletons() {
        // Configs designed to share no common subsequences of length 2:
        let configs: Vec<Config> = vec![
            vec![1, 1, 2, 3],
            vec![2, 3, 1, 2],
            vec![3, 2, 3, 1],
        ];
        let sel = identify_blocks(&configs, 4);
        // every pair covered
        let mut covered = BTreeSet::new();
        for b in &sel.blocks {
            covered.extend(b.pairs());
        }
        for cfg in &configs {
            for (mi, &r) in cfg.iter().enumerate() {
                assert!(covered.contains(&(mi, r)), "({mi},{r}) uncovered");
            }
        }
    }

    #[test]
    fn collection2_style_runs_are_found() {
        // "collection-2": one rate per stretch of modules -> long runs
        // shared by multiple networks.
        let configs: Vec<Config> = vec![
            vec![2, 2, 2, 3, 3, 3],
            vec![2, 2, 2, 1, 1, 1],
            vec![1, 1, 1, 3, 3, 3],
            vec![2, 2, 2, 3, 3, 3],
        ];
        let sel = identify_blocks(&configs, 6);
        assert!(
            sel.multi_module_blocks() >= 1,
            "expected multi-module blocks, got {:?}",
            sel.blocks
        );
        // Fewer module-units than 4 networks x 6 modules of naive work.
        assert!(sel.pretrain_module_units() <= 24);
    }

    #[test]
    fn per_module_baseline_counts_pairs() {
        let configs: Vec<Config> = vec![vec![1, 2], vec![1, 3]];
        let sel = per_module_blocks(&configs, 2);
        assert_eq!(sel.blocks.len(), 3); // (0,1), (1,2), (1,3)
        assert!(sel.blocks.iter().all(|b| b.len() == 1));
        assert_eq!(sel.frequencies.iter().sum::<usize>(), 4);
    }

    #[test]
    fn selected_blocks_used_in_multiple_networks() {
        let configs: Vec<Config> = vec![
            vec![1, 2, 3],
            vec![1, 2, 1],
            vec![3, 2, 3],
            vec![1, 2, 2],
        ];
        let sel = identify_blocks(&configs, 3);
        for (b, f) in sel.blocks.iter().zip(&sel.frequencies) {
            if b.len() > 1 {
                assert!(*f >= 2, "multi-block {b:?} freq {f}");
            }
        }
    }
}
