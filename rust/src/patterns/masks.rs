//! Elementwise binary masks over HWIO conv weights for each pruning
//! scheme (mirrors python/compile/patterns.py). Used to drive the masked
//! PJRT training graphs from Rust (Table 1's accuracy axis).

use super::connectivity::{prune_connectivity, prune_unstructured};
use super::{assign_pattern, PATTERN_SET_4};

/// HWIO shape helper: (kh, kw, cin, cout) from a 4-d shape.
fn dims(shape: &[usize]) -> (usize, usize, usize, usize) {
    assert_eq!(shape.len(), 4, "expected HWIO conv shape");
    (shape[0], shape[1], shape[2], shape[3])
}

/// Non-structured magnitude mask keeping `keep` fraction.
pub fn mask_unstructured(w: &[f32], keep: f64) -> Vec<f32> {
    prune_unstructured(w, keep)
        .into_iter()
        .map(|b| if b { 1.0 } else { 0.0 })
        .collect()
}

/// Whole-filter (output channel) mask keeping `keep` fraction (HWIO).
pub fn mask_filters(w: &[f32], shape: &[usize], keep: f64) -> Vec<f32> {
    let (kh, kw, cin, cout) = dims(shape);
    let survivors = super::connectivity::prune_filters(w, kh, kw, cin,
                                                       cout, keep);
    let alive: std::collections::HashSet<usize> =
        survivors.into_iter().collect();
    let mut m = vec![0f32; w.len()];
    for (i, v) in m.iter_mut().enumerate() {
        if alive.contains(&(i % cout)) {
            *v = 1.0;
        }
    }
    m
}

/// Kernel-pattern mask: each 3x3 kernel keeps its best 4-entry pattern.
/// Non-3x3 shapes get an all-ones mask.
pub fn mask_patterns(w: &[f32], shape: &[usize]) -> Vec<f32> {
    let (kh, kw, cin, cout) = dims(shape);
    if (kh, kw) != (3, 3) {
        return vec![1f32; w.len()];
    }
    let mut m = vec![0f32; w.len()];
    for ci in 0..cin {
        for co in 0..cout {
            let mut k = [0f32; 9];
            for (t, kv) in k.iter_mut().enumerate() {
                *kv = w[t * cin * cout + ci * cout + co];
            }
            let pid = assign_pattern(&k);
            for &(dy, dx) in &PATTERN_SET_4[pid as usize] {
                m[(dy * 3 + dx) * cin * cout + ci * cout + co] = 1.0;
            }
        }
    }
    m
}

/// Connectivity mask: whole (cin,cout) kernels kept at `keep` fraction.
pub fn mask_connectivity(w: &[f32], shape: &[usize], keep: f64)
                         -> Vec<f32> {
    let (kh, kw, cin, cout) = dims(shape);
    let conn = prune_connectivity(w, kh, kw, cin, cout, keep);
    let mut m = vec![0f32; w.len()];
    for (i, v) in m.iter_mut().enumerate() {
        let rem = i % (cin * cout);
        let ci = rem / cout;
        let co = rem % cout;
        if conn.is_alive(ci, co) {
            *v = 1.0;
        }
    }
    m
}

/// Pattern + connectivity combined (the CoCo-Gen deployment scheme).
pub fn mask_pattern_connectivity(w: &[f32], shape: &[usize],
                                 conn_keep: f64) -> Vec<f32> {
    let p = mask_patterns(w, shape);
    let c = mask_connectivity(w, shape, conn_keep);
    p.iter().zip(&c).map(|(a, b)| a * b).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn w(shape: &[usize], seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        (0..shape.iter().product())
            .map(|_| rng.normal_f32())
            .collect()
    }

    #[test]
    fn keep_ratios() {
        let shape = [3, 3, 8, 8];
        let wt = w(&shape, 1);
        let keep = 4.0 / 9.0;
        let mu = mask_unstructured(&wt, keep);
        let frac = mu.iter().sum::<f32>() as f64 / mu.len() as f64;
        assert!((frac - keep).abs() < 0.01, "{frac}");
        let mp = mask_patterns(&wt, &shape);
        let frac = mp.iter().sum::<f32>() as f64 / mp.len() as f64;
        assert!((frac - keep).abs() < 1e-9);
        let mf = mask_filters(&wt, &shape, keep);
        let frac = mf.iter().sum::<f32>() as f64 / mf.len() as f64;
        // filter keep rounds up to whole filters: 4/8 = 0.5
        assert!((frac - 0.5).abs() < 1e-6, "{frac}");
        let mc = mask_connectivity(&wt, &shape, keep);
        let frac = mc.iter().sum::<f32>() as f64 / mc.len() as f64;
        assert!((frac - 29.0 / 64.0).abs() < 1e-6, "{frac}");
    }

    #[test]
    fn pattern_mask_keeps_centre() {
        let shape = [3, 3, 4, 4];
        let wt = w(&shape, 2);
        let m = mask_patterns(&wt, &shape);
        let (cin, cout) = (4, 4);
        for ci in 0..cin {
            for co in 0..cout {
                // centre tap (1,1) always survives
                assert_eq!(m[(1 * 3 + 1) * cin * cout + ci * cout + co],
                           1.0);
            }
        }
    }

    #[test]
    fn non_3x3_pattern_is_identity() {
        let shape = [1, 1, 4, 4];
        let wt = w(&shape, 3);
        assert!(mask_patterns(&wt, &shape).iter().all(|v| *v == 1.0));
    }

    #[test]
    fn combined_mask_is_intersection() {
        let shape = [3, 3, 6, 6];
        let wt = w(&shape, 4);
        let pc = mask_pattern_connectivity(&wt, &shape, 0.5);
        let p = mask_patterns(&wt, &shape);
        let c = mask_connectivity(&wt, &shape, 0.5);
        for i in 0..pc.len() {
            assert_eq!(pc[i], p[i] * c[i]);
        }
    }
}
