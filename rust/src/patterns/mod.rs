//! Kernel pattern library + pattern assignment + connectivity pruning
//! (paper §2.1.2). Mirrors python/compile/patterns.py exactly — the unit
//! tests pin the same tap lists on both sides.

pub mod connectivity;
pub mod masks;

/// A (dy, dx) tap inside a 3x3 kernel.
pub type Tap = (usize, usize);

/// The curated 4-entry pattern set over 3x3 kernels (centre always kept),
/// following PatDNN. MUST stay in sync with
/// python/compile/patterns.py::PATTERN_SET_4.
pub const PATTERN_SET_4: [[Tap; 4]; 8] = [
    [(0, 0), (0, 1), (1, 1), (1, 0)], // top-left block
    [(0, 1), (0, 2), (1, 1), (1, 2)], // top-right block
    [(1, 0), (1, 1), (2, 0), (2, 1)], // bottom-left block
    [(1, 1), (1, 2), (2, 1), (2, 2)], // bottom-right block
    [(0, 1), (1, 0), (1, 1), (1, 2)], // T up
    [(1, 0), (1, 1), (1, 2), (2, 1)], // T down
    [(0, 1), (1, 0), (1, 1), (2, 1)], // T left
    [(0, 1), (1, 1), (1, 2), (2, 1)], // cross (+) minus one
];

/// Pattern id type (index into PATTERN_SET_4).
pub type PatternId = u8;

/// Assign the best pattern (max preserved L2 energy) to one 3x3 kernel
/// given as 9 weights in row-major (ky*3+kx) order.
pub fn assign_pattern(kernel: &[f32; 9]) -> PatternId {
    let mut best = 0u8;
    let mut best_energy = f64::NEG_INFINITY;
    for (pid, taps) in PATTERN_SET_4.iter().enumerate() {
        let e: f64 = taps
            .iter()
            .map(|&(dy, dx)| {
                let w = kernel[dy * 3 + dx] as f64;
                w * w
            })
            .sum();
        if e > best_energy {
            best_energy = e;
            best = pid as u8;
        }
    }
    best
}

/// Energy preserved by pattern `pid` on `kernel`.
pub fn pattern_energy(kernel: &[f32; 9], pid: PatternId) -> f64 {
    PATTERN_SET_4[pid as usize]
        .iter()
        .map(|&(dy, dx)| {
            let w = kernel[dy * 3 + dx] as f64;
            w * w
        })
        .sum()
}

/// Per-layer pattern assignment for a dense HWIO weight tensor
/// (kh=kw=3): returns pattern ids [cin * cout] indexed `ci * cout + co`.
pub fn assign_layer_patterns(w_hwio: &[f32], cin: usize, cout: usize)
                             -> Vec<PatternId> {
    assert_eq!(w_hwio.len(), 9 * cin * cout);
    let mut ids = vec![0u8; cin * cout];
    for ci in 0..cin {
        for co in 0..cout {
            let mut k = [0f32; 9];
            for (t, kv) in k.iter_mut().enumerate() {
                // HWIO layout: w[ky][kx][ci][co]
                *kv = w_hwio[t * cin * cout + ci * cout + co];
            }
            ids[ci * cout + co] = assign_pattern(&k);
        }
    }
    ids
}

/// Project a dense 3x3 kernel onto its assigned pattern: zero the
/// non-pattern taps (the ADMM Z-update for kernel pattern pruning).
pub fn project_kernel(kernel: &[f32; 9]) -> ([f32; 9], PatternId) {
    let pid = assign_pattern(kernel);
    let mut out = [0f32; 9];
    for &(dy, dx) in &PATTERN_SET_4[pid as usize] {
        out[dy * 3 + dx] = kernel[dy * 3 + dx];
    }
    (out, pid)
}

/// Pattern-pruning statistics for a layer.
#[derive(Debug, Clone, Default)]
pub struct PatternStats {
    pub kernels: usize,
    pub histogram: [usize; 8],
    pub energy_kept: f64,
    pub energy_total: f64,
}

impl PatternStats {
    pub fn energy_ratio(&self) -> f64 {
        if self.energy_total == 0.0 {
            1.0
        } else {
            self.energy_kept / self.energy_total
        }
    }
}

/// Compute assignment statistics over a dense HWIO tensor.
pub fn layer_pattern_stats(w_hwio: &[f32], cin: usize, cout: usize)
                           -> PatternStats {
    let mut st = PatternStats {
        kernels: cin * cout,
        ..Default::default()
    };
    for ci in 0..cin {
        for co in 0..cout {
            let mut k = [0f32; 9];
            for (t, kv) in k.iter_mut().enumerate() {
                *kv = w_hwio[t * cin * cout + ci * cout + co];
            }
            let pid = assign_pattern(&k);
            st.histogram[pid as usize] += 1;
            st.energy_kept += pattern_energy(&k, pid);
            st.energy_total += k.iter().map(|w| (*w as f64) * (*w as f64))
                .sum::<f64>();
        }
    }
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn pattern_set_matches_python() {
        // Pinned tap lists — keep in sync with test_patterns.py.
        assert_eq!(PATTERN_SET_4[0], [(0, 0), (0, 1), (1, 1), (1, 0)]);
        assert_eq!(PATTERN_SET_4[7], [(0, 1), (1, 1), (1, 2), (2, 1)]);
        for taps in &PATTERN_SET_4 {
            assert!(taps.contains(&(1, 1)), "centre tap always kept");
            let mut s = taps.to_vec();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 4);
        }
    }

    #[test]
    fn assignment_maximizes_energy() {
        prop::check("pattern-assign-max-energy", 200, |g| {
            let mut k = [0f32; 9];
            for v in &mut k {
                *v = g.f32(-2.0, 2.0);
            }
            let pid = assign_pattern(&k);
            let e = pattern_energy(&k, pid);
            for other in 0..8u8 {
                if pattern_energy(&k, other) > e + 1e-9 {
                    return Err(format!(
                        "pattern {other} beats chosen {pid}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn projection_keeps_exactly_pattern_taps() {
        prop::check("projection-taps", 100, |g| {
            let mut k = [0f32; 9];
            for v in &mut k {
                *v = g.f32(-1.0, 1.0);
            }
            let (proj, pid) = project_kernel(&k);
            let taps = &PATTERN_SET_4[pid as usize];
            for dy in 0..3 {
                for dx in 0..3 {
                    let kept = taps.contains(&(dy, dx));
                    let v = proj[dy * 3 + dx];
                    if kept && (v - k[dy * 3 + dx]).abs() > 0.0 {
                        return Err("kept tap modified".into());
                    }
                    if !kept && v != 0.0 {
                        return Err("pruned tap nonzero".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn layer_stats_sane() {
        let cin = 4;
        let cout = 6;
        let mut w = vec![0f32; 9 * cin * cout];
        for (i, v) in w.iter_mut().enumerate() {
            *v = ((i * 31 % 17) as f32 - 8.0) * 0.1;
        }
        let st = layer_pattern_stats(&w, cin, cout);
        assert_eq!(st.kernels, 24);
        assert_eq!(st.histogram.iter().sum::<usize>(), 24);
        assert!(st.energy_ratio() > 0.4 && st.energy_ratio() <= 1.0);
    }

    #[test]
    fn obvious_corner_kernel_picks_corner_pattern() {
        let mut k = [0f32; 9];
        k[0] = 1.0; // (0,0)
        k[1] = 1.0; // (0,1)
        k[3] = 1.0; // (1,0)
        k[4] = 1.0; // (1,1)
        assert_eq!(assign_pattern(&k), 0);
    }
}
