//! Connectivity pruning (paper §2.1.2, Fig. 3): cut connections between
//! input and output channels — i.e. remove whole (cin, cout) kernels — by
//! L2-norm ranking. More flexible than filter/channel pruning, and
//! composes with kernel pattern pruning for higher total pruning rates.

/// Decision for one conv layer: which (ci, co) kernels survive.
#[derive(Debug, Clone)]
pub struct ConnectivityMask {
    pub cin: usize,
    pub cout: usize,
    /// alive[ci * cout + co]
    pub alive: Vec<bool>,
}

impl ConnectivityMask {
    pub fn all_alive(cin: usize, cout: usize) -> Self {
        ConnectivityMask {
            cin,
            cout,
            alive: vec![true; cin * cout],
        }
    }

    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    pub fn keep_fraction(&self) -> f64 {
        self.alive_count() as f64 / self.alive.len() as f64
    }

    /// Alive input channels for filter `co`.
    pub fn alive_inputs(&self, co: usize) -> Vec<usize> {
        (0..self.cin)
            .filter(|ci| self.alive[ci * self.cout + co])
            .collect()
    }

    pub fn is_alive(&self, ci: usize, co: usize) -> bool {
        self.alive[ci * self.cout + co]
    }
}

/// Rank kernels of a dense HWIO tensor by L2 norm and keep the top
/// `keep_frac` fraction (at least one kernel per output filter so no
/// filter goes fully dead — the paper keeps layer connectivity intact).
pub fn prune_connectivity(w_hwio: &[f32], kh: usize, kw: usize, cin: usize,
                          cout: usize, keep_frac: f64) -> ConnectivityMask {
    assert_eq!(w_hwio.len(), kh * kw * cin * cout);
    let n = cin * cout;
    let mut norms = vec![0f64; n];
    for t in 0..kh * kw {
        for ci in 0..cin {
            for co in 0..cout {
                let v = w_hwio[t * cin * cout + ci * cout + co] as f64;
                norms[ci * cout + co] += v * v;
            }
        }
    }
    let n_keep = ((keep_frac * n as f64).ceil() as usize).clamp(1, n);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());
    let mut alive = vec![false; n];
    for &i in order.iter().take(n_keep) {
        alive[i] = true;
    }
    let mut mask = ConnectivityMask { cin, cout, alive };
    // Guarantee every filter keeps its strongest input connection.
    for co in 0..cout {
        if mask.alive_inputs(co).is_empty() {
            let best = (0..cin)
                .max_by(|&a, &b| {
                    norms[a * cout + co]
                        .partial_cmp(&norms[b * cout + co])
                        .unwrap()
                })
                .unwrap();
            mask.alive[best * cout + co] = true;
        }
    }
    mask
}

/// Structured filter pruning baseline (Li et al.): drop whole output
/// filters by L1 norm; returns surviving filter indices.
pub fn prune_filters(w_hwio: &[f32], kh: usize, kw: usize, cin: usize,
                     cout: usize, keep_frac: f64) -> Vec<usize> {
    let mut norms = vec![0f64; cout];
    for t in 0..kh * kw {
        for ci in 0..cin {
            for co in 0..cout {
                norms[co] += w_hwio[t * cin * cout + ci * cout + co].abs()
                    as f64;
            }
        }
    }
    let n_keep = ((keep_frac * cout as f64).ceil() as usize).clamp(1, cout);
    let mut order: Vec<usize> = (0..cout).collect();
    order.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());
    let mut keep: Vec<usize> = order.into_iter().take(n_keep).collect();
    keep.sort_unstable();
    keep
}

/// Non-structured magnitude pruning baseline (Han et al.): returns a
/// binary mask over the full dense tensor keeping the top `keep_frac`.
pub fn prune_unstructured(w: &[f32], keep_frac: f64) -> Vec<bool> {
    let n = w.len();
    let n_keep = ((keep_frac * n as f64).ceil() as usize).clamp(1, n);
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        w[b].abs().partial_cmp(&w[a].abs()).unwrap()
    });
    let mut mask = vec![false; n];
    for &i in order.iter().take(n_keep) {
        mask[i] = true;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn keeps_exact_fraction() {
        prop::check("connectivity-fraction", 50, |g| {
            let cin = g.usize(1, 8);
            let cout = g.usize(1, 8);
            let keep = g.f64(0.1, 1.0);
            let w = g.normal_vec(9 * cin * cout);
            let m = prune_connectivity(&w, 3, 3, cin, cout, keep);
            let want = ((keep * (cin * cout) as f64).ceil() as usize)
                .clamp(1, cin * cout);
            // may exceed by the per-filter guarantee
            if m.alive_count() < want {
                return Err(format!(
                    "kept {} < {want}",
                    m.alive_count()
                ));
            }
            // every filter has at least one alive input
            for co in 0..cout {
                if m.alive_inputs(co).is_empty() {
                    return Err(format!("filter {co} fully dead"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn keeps_strongest_kernels() {
        let cin = 2;
        let cout = 2;
        let mut w = vec![0f32; 9 * cin * cout];
        // kernel (0,0) large, (1,1) large; others tiny
        for t in 0..9 {
            w[t * 4] = 10.0; // ci=0, co=0
            w[t * 4 + 3] = 8.0; // ci=1, co=1
            w[t * 4 + 1] = 0.1;
            w[t * 4 + 2] = 0.1;
        }
        let m = prune_connectivity(&w, 3, 3, cin, cout, 0.5);
        assert!(m.is_alive(0, 0));
        assert!(m.is_alive(1, 1));
        assert_eq!(m.alive_count(), 2);
    }

    #[test]
    fn filter_pruning_ranks_by_l1() {
        let cin = 1;
        let cout = 4;
        let mut w = vec![0f32; 9 * cout];
        for t in 0..9 {
            w[t * cout] = 0.1; // filter 0 weak
            w[t * cout + 1] = 5.0;
            w[t * cout + 2] = 3.0;
            w[t * cout + 3] = 0.2;
        }
        let keep = prune_filters(&w, 3, 3, cin, cout, 0.5);
        assert_eq!(keep, vec![1, 2]);
    }

    #[test]
    fn unstructured_keeps_topk() {
        let w = vec![0.1f32, -5.0, 0.3, 2.0, -0.05];
        let m = prune_unstructured(&w, 0.4);
        assert_eq!(m, vec![false, true, false, true, false]);
    }
}
