//! cocopie CLI — leader entrypoint.
//!
//! Subcommands (hand-rolled parser; clap is not in the offline registry):
//!   info                      — artifacts + manifest summary
//!   serve  [--model M] [--batch B] [--requests N] [--backend pjrt|native]
//!          [--scheme cocogen|cocogen-quant|coco-auto|dense]
//!          [--batch-mode auto|fused|fanout]
//!                             — run the serving coordinator on synthetic
//!                               traffic and print latency metrics;
//!                               `--backend native` serves a zoo timing
//!                               model on the executor pool (no PJRT or
//!                               artifacts needed); `--scheme
//!                               cocogen-quant` serves the weight-only
//!                               int8 plan; `--scheme coco-auto` runs
//!                               per-layer engine auto-tuning (at the
//!                               serving batch size) before serving;
//!                               `--batch-mode` picks fused batched
//!                               execution vs per-image pool fan-out
//!                               (auto = fused for batches of 2+)
//!   train  [--model M] [--dataset D] [--steps N]
//!                             — train a model via the AOT train_step
//!   compress [--model NAME]   — pattern-compress a timing model, print
//!                               storage + FLOP report
//!   explore [--configs N]     — real-tier CoCo-Tune exploration demo

use std::collections::HashMap;

use anyhow::Result;

use cocopie::codegen::{build_plan, PruneConfig, Scheme};
use cocopie::cocotune::trainer::{
    config_masks, sample_subspace, ModelState, TrainOpts, Trainer,
};
use cocopie::coordinator::{BatchPolicy, Coordinator};
use cocopie::ir::zoo;
use cocopie::runtime::Runtime;
use cocopie::util::rng::Rng;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args
                .get(i + 1)
                .filter(|v| !v.starts_with("--"))
                .cloned()
                .unwrap_or_else(|| "true".to_string());
            if val != "true" {
                i += 1;
            }
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    match cmd {
        "info" => info(),
        "serve" => serve(&flags),
        "train" => train(&flags),
        "compress" => compress(&flags),
        "explore" => explore(&flags),
        _ => {
            println!("cocopie {} — compression-compilation co-design",
                     cocopie::version());
            println!(
                "usage: cocopie <info|serve|train|compress|explore> [flags]"
            );
            Ok(())
        }
    }
}

fn info() -> Result<()> {
    let rt = Runtime::new(&Runtime::default_dir())?;
    println!("platform: {}", rt.platform());
    println!("models:");
    for (name, m) in &rt.manifest.models {
        println!(
            "  {name}: {} params, {} masks, {} artifacts, {} MFLOPs",
            m.param_count,
            m.masks.len(),
            m.artifacts.len(),
            m.flops / 1_000_000
        );
    }
    println!("micro artifacts: {:?}",
             rt.manifest.micro.keys().collect::<Vec<_>>());
    println!("datasets: {:?}",
             rt.manifest.datasets.keys().collect::<Vec<_>>());
    Ok(())
}

fn serve(flags: &HashMap<String, String>) -> Result<()> {
    let backend = flags.get("backend").map(String::as_str).unwrap_or("pjrt");
    let batch: usize =
        flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(8);
    let n: usize = flags
        .get("requests")
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let policy = BatchPolicy {
        max_batch: batch,
        max_wait: std::time::Duration::from_millis(3),
    };
    let (coord, elems) = match backend {
        "pjrt" => {
            anyhow::ensure!(
                flags.get("scheme").is_none(),
                "--scheme applies to --backend native only (the PJRT \
                 path serves the compiled AOT artifact as-is)"
            );
            let model = flags.get("model").map(String::as_str)
                .unwrap_or("resnet_mini");
            let rt = Runtime::new(&Runtime::default_dir())?;
            let spec = rt.manifest.model(model)?.clone();
            let elems: usize = spec.input_shape.iter().product();
            let mut cfg = cocopie::coordinator::ServeConfig::new(model);
            cfg.policy = policy;
            (Coordinator::start(cfg)?, elems)
        }
        "native" => {
            let model = flags.get("model").map(String::as_str)
                .unwrap_or("mobilenet_v2");
            let ir = match model {
                "vgg16" => zoo::vgg16(zoo::CIFAR_HW, 10),
                "resnet50" => zoo::resnet50(zoo::CIFAR_HW, 10),
                "mobilenet_v2" => zoo::mobilenet_v2(zoo::CIFAR_HW, 10),
                other => anyhow::bail!("unknown timing model {other}"),
            };
            let scheme_flag = flags.get("scheme").map(String::as_str)
                .unwrap_or("cocogen");
            let (scheme, name) = match scheme_flag {
                "cocogen" => (Scheme::CocoGen, "native-cocogen"),
                "cocogen-quant" | "quant" | "int8" => {
                    (Scheme::CocoGenQuant, "native-int8")
                }
                "coco-auto" | "cocoauto" | "auto" => {
                    (Scheme::CocoAuto, "native-auto")
                }
                "dense" => (Scheme::DenseIm2col, "native-dense"),
                other => anyhow::bail!(
                    "unknown scheme {other} \
                     (cocogen|cocogen-quant|coco-auto|dense)"
                ),
            };
            let mode = match flags
                .get("batch-mode")
                .map(String::as_str)
                .unwrap_or("auto")
            {
                "auto" => cocopie::coordinator::NativeBatchMode::Auto,
                "fused" => cocopie::coordinator::NativeBatchMode::Fused,
                "fanout" | "fan-out" => {
                    cocopie::coordinator::NativeBatchMode::FanOut
                }
                other => anyhow::bail!(
                    "unknown batch mode {other} (auto|fused|fanout)"
                ),
            };
            let elems = ir.input.c * ir.input.h * ir.input.w;
            let mut plan = build_plan(&ir, scheme, PruneConfig::default(),
                                      7);
            if scheme == Scheme::CocoAuto {
                println!(
                    "auto-tuning per-layer engines for {model} at \
                     batch {batch}..."
                );
                // Tune at threads = 1 and at the serving batch size:
                // per-layer winners must hold in the regime that
                // actually serves — fused batches of max_batch images
                // (the best kernel at n = 1 is often not the best at
                // n = 8).
                cocopie::codegen::autotune_plan_batched(&mut plan, 1,
                                                        batch);
            }
            let plan = plan.into_shared();
            println!(
                "serving {model} via {name}: {} KB resident weights, \
                 {} KB activation arena per executor",
                plan.weight_bytes() / 1024,
                plan.peak_activation_bytes() / 1024
            );
            let coord = Coordinator::start_with(
                vec![Box::new(
                    cocopie::coordinator::NativeBackend::new(name, plan)
                        .with_batch_mode(mode),
                )],
                policy,
                cocopie::coordinator::RouterPolicy::Failover,
            )?;
            (coord, elems)
        }
        other => anyhow::bail!("unknown backend {other} (pjrt|native)"),
    };
    let client = coord.client();
    let mut rng = Rng::seed_from(1);
    let mut pending = Vec::new();
    for _ in 0..n {
        let img: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
        pending.push(client.submit(img)?);
    }
    for p in pending {
        let _ = p.recv();
    }
    drop(client);
    let report = coord.shutdown_report();
    let s = &report.overall;
    println!(
        "served {} requests: p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
        s.completed, s.p50_ms, s.p99_ms, s.mean_batch
    );
    for (name, b) in &report.per_backend {
        println!("  {name}: {} requests, p50 {:.2} ms", b.completed,
                 b.p50_ms);
    }
    Ok(())
}

fn train(flags: &HashMap<String, String>) -> Result<()> {
    let model = flags.get("model").map(String::as_str)
        .unwrap_or("resnet_mini");
    let dataset = flags.get("dataset").map(String::as_str)
        .unwrap_or("synflowers");
    let steps: usize =
        flags.get("steps").and_then(|v| v.parse().ok()).unwrap_or(300);
    let rt = Runtime::new(&Runtime::default_dir())?;
    let trainer = Trainer::new(&rt, model)?;
    let ds = rt.manifest.datasets[dataset].clone();
    let mut state = ModelState::init(&trainer.spec, 42);
    let masks = config_masks(
        &trainer.spec,
        &state,
        &vec![0; trainer.spec.prunable_modules.len()],
    );
    let opts = TrainOpts {
        steps,
        eval_every: 50,
        ..Default::default()
    };
    let res = trainer.train(&mut state, &masks, &ds, &opts)?;
    println!("trained {model} on {dataset} for {} steps", res.steps);
    for (s, a) in &res.acc_curve {
        println!("  step {s:4}  acc {a:.3}");
    }
    Ok(())
}

fn compress(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("model").map(String::as_str).unwrap_or("vgg16");
    let ir = match name {
        "vgg16" => zoo::vgg16(zoo::IMAGENET_HW, 1000),
        "resnet50" => zoo::resnet50(zoo::IMAGENET_HW, 1000),
        "mobilenet_v2" => zoo::mobilenet_v2(zoo::IMAGENET_HW, 1000),
        other => anyhow::bail!("unknown timing model {other}"),
    };
    let dense = build_plan(&ir, Scheme::DenseNaive, PruneConfig::default(),
                           7);
    let coco = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(), 7);
    println!("{name}: dense {} MB -> cocogen {} MB ({:.2}x), \
              FLOP keep ratio {:.3}",
             dense.weight_bytes() / (1 << 20),
             coco.weight_bytes() / (1 << 20),
             dense.weight_bytes() as f64 / coco.weight_bytes() as f64,
             coco.flop_keep_ratio());
    Ok(())
}

fn explore(flags: &HashMap<String, String>) -> Result<()> {
    use cocopie::cocotune::explore::{explore, InitMode};
    use cocopie::cocotune::pretrain::pretrain_bank;
    let n_cfg: usize =
        flags.get("configs").and_then(|v| v.parse().ok()).unwrap_or(8);
    let rt = Runtime::new(&Runtime::default_dir())?;
    let trainer = Trainer::new(&rt, "resnet_mini")?;
    let ds = rt.manifest.datasets["synflowers"].clone();
    println!("training teacher...");
    let mut teacher = ModelState::init(&trainer.spec, 42);
    let masks = config_masks(&trainer.spec, &teacher, &vec![0; 6]);
    let res = trainer.train(
        &mut teacher,
        &masks,
        &ds,
        &TrainOpts {
            steps: 450,
            ..Default::default()
        },
    )?;
    println!("teacher acc {:.3}", res.final_acc);
    println!("pre-training tuning blocks...");
    let bank = pretrain_bank(&trainer, &teacher, &ds, 40, 0.02, 7)?;
    let configs = sample_subspace(6, n_cfg, 3);
    let thr = res.final_acc; // alpha = 0 (paper mid-range)
    let opts = TrainOpts {
        steps: 120,
        lr: 0.015,
        eval_every: 20,
        ..Default::default()
    };
    println!("exploring {} configs (thr {:.3})...", configs.len(), thr);
    let base = explore(&trainer, &teacher, &ds, &configs,
                       InitMode::Default, &opts, thr, true)?;
    let comp = explore(&trainer, &teacher, &ds, &configs,
                       InitMode::BlockTrained(&bank), &opts, thr, true)?;
    println!(
        "default:      {} configs, {} steps, found={:?}",
        base.results.len(),
        base.total_steps,
        base.found.map(|i| base.results[i].model_size)
    );
    println!(
        "block-trained: {} configs, {} steps (+{} pretrain), found={:?}",
        comp.results.len(),
        comp.total_steps,
        bank.pretrain_steps,
        comp.found.map(|i| comp.results[i].model_size)
    );
    Ok(())
}
