//! cocopie CLI — leader entrypoint.
//!
//! Subcommands (hand-rolled parser; clap is not in the offline registry):
//!   info                      — artifacts + manifest summary
//!   serve  [--model M] [--batch B] [--requests N] [--backend pjrt|native]
//!          [--variants dense,cocogen,coco-auto | --scheme S]
//!          [--sla mixed|realtime|standard|quality]
//!          [--batch-mode auto|fused|fanout]
//!          [--rate R] [--queue-cap C] [--no-simd]
//!                             — run the serving coordinator on synthetic
//!                               traffic and print per-deployment latency
//!                               metrics; `--backend native` registers
//!                               one named deployment per `--variants`
//!                               scheme (built by `Deployment::builder`,
//!                               `coco-auto` auto-tuned at the serving
//!                               batch size) and routes each request's
//!                               SLA class across them on the live path;
//!                               `--scheme S` is shorthand for
//!                               `--variants S`; `--batch-mode` picks
//!                               fused batched execution vs per-image
//!                               pool fan-out (auto = fused for 2+);
//!                               `--rate R` offers requests open-loop
//!                               at R req/s (default: one burst) and
//!                               `--queue-cap C` bounds the per-
//!                               deployment queue (native only) so
//!                               overload sheds typed `Overloaded`
//!                               instead of queueing without bound;
//!                               `--lifecycle` runs the hot-swap scene
//!                               instead: v1 serves open-loop Poisson
//!                               traffic while v2 registers on the
//!                               *running* coordinator, canaries
//!                               through staged weights
//!                               (5% → 25% → 100%), promotes on
//!                               windowed metrics, and v1 drains out
//!                               with zero dropped requests
//!   train  [--model M] [--dataset D] [--steps N]
//!                             — train a model via the AOT train_step
//!   compress [--model NAME]   — pattern-compress a timing model, print
//!                               storage + FLOP report
//!   verify [--model M|all] [--scheme S] [--batch B]
//!                             — run the static plan verifier
//!                               (`codegen::verify`) over compiled
//!                               pipelines without executing them:
//!                               every scheme (or one via `--scheme`)
//!                               against the conv zoo + text encoder
//!                               (or one via `--model`), at batch 1 and
//!                               `--batch` (default 8); prints one line
//!                               per combo and exits nonzero on the
//!                               first typed `VerifyError`
//!   explore [--configs N]    — real-tier CoCo-Tune exploration demo
//!
//! Unknown flags are rejected per subcommand: a typo'd `--scehme` is a
//! usage error, not a silently served default.

use std::collections::HashMap;

use anyhow::{bail, Result};

use cocopie::cocotune::trainer::{
    config_masks, sample_subspace, ModelState, TrainOpts, Trainer,
};
use cocopie::ir::zoo;
use cocopie::prelude::*;
use cocopie::runtime::Runtime;
use cocopie::util::rng::Rng;

/// Parse `--key value` / `--switch` pairs, rejecting any flag not in
/// `allowed` with a usage error naming the subcommand.
fn parse_flags(cmd: &str, args: &[String], allowed: &[&str])
               -> Result<HashMap<String, String>> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let Some(key) = args[i].strip_prefix("--") else {
            bail!(
                "unexpected argument '{}' for `{cmd}` (flags look like \
                 --key [value])",
                args[i]
            );
        };
        if !allowed.contains(&key) {
            bail!(
                "unknown flag --{key} for `{cmd}` (expected one of: {})",
                allowed
                    .iter()
                    .map(|f| format!("--{f}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }
        let val = args
            .get(i + 1)
            .filter(|v| !v.starts_with("--"))
            .cloned()
            .unwrap_or_else(|| "true".to_string());
        if val != "true" {
            i += 1;
        }
        out.insert(key.to_string(), val);
        i += 1;
    }
    Ok(out)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[args.len().min(1)..];
    match cmd {
        "info" => {
            parse_flags(cmd, rest, &[])?;
            info()
        }
        "serve" => {
            let flags = parse_flags(cmd, rest, &[
                "model", "batch", "requests", "backend", "scheme",
                "variants", "sla", "batch-mode", "rate", "queue-cap",
                "no-simd", "lifecycle",
            ])?;
            if flags.contains_key("lifecycle") {
                serve_lifecycle(&flags)
            } else {
                serve(&flags)
            }
        }
        "train" => {
            let flags =
                parse_flags(cmd, rest, &["model", "dataset", "steps"])?;
            train(&flags)
        }
        "compress" => {
            let flags = parse_flags(cmd, rest, &["model"])?;
            compress(&flags)
        }
        "verify" => {
            let flags =
                parse_flags(cmd, rest, &["model", "scheme", "batch"])?;
            verify_cmd(&flags)
        }
        "explore" => {
            let flags = parse_flags(cmd, rest, &["configs"])?;
            explore(&flags)
        }
        _ => {
            println!("cocopie {} — compression-compilation co-design",
                     cocopie::version());
            println!(
                "usage: cocopie \
                 <info|serve|train|compress|verify|explore> [flags]"
            );
            Ok(())
        }
    }
}

fn info() -> Result<()> {
    let rt = Runtime::new(&Runtime::default_dir())?;
    println!("platform: {}", rt.platform());
    println!("models:");
    for (name, m) in &rt.manifest.models {
        println!(
            "  {name}: {} params, {} masks, {} artifacts, {} MFLOPs",
            m.param_count,
            m.masks.len(),
            m.artifacts.len(),
            m.flops / 1_000_000
        );
    }
    println!("micro artifacts: {:?}",
             rt.manifest.micro.keys().collect::<Vec<_>>());
    println!("datasets: {:?}",
             rt.manifest.datasets.keys().collect::<Vec<_>>());
    Ok(())
}

fn serve(flags: &HashMap<String, String>) -> Result<()> {
    // Kernel-dispatch override: pin every engine to the portable
    // scalar tier before any pipeline compiles or autotunes.
    // `COCOPIE_FORCE_SCALAR=1` in the environment does the same.
    if flags.contains_key("no-simd") {
        cocopie::exec::micro::set_force_scalar(true);
    }
    let backend = flags.get("backend").map(String::as_str).unwrap_or("pjrt");
    let batch: usize =
        flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(8);
    let n: usize = flags
        .get("requests")
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let policy = BatchPolicy {
        max_batch: batch,
        max_wait: std::time::Duration::from_millis(3),
    };
    anyhow::ensure!(
        !(flags.contains_key("scheme") && flags.contains_key("variants")),
        "--scheme is shorthand for a single-entry --variants; pass one \
         or the other"
    );
    let rate: Option<f64> = match flags.get("rate") {
        None => None,
        Some(v) => {
            let r: f64 = v.parse().map_err(|_| {
                anyhow::anyhow!("--rate wants req/s, got '{v}'")
            })?;
            anyhow::ensure!(r > 0.0, "--rate must be positive");
            Some(r)
        }
    };
    let queue_cap: Option<usize> = match flags.get("queue-cap") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| {
            anyhow::anyhow!("--queue-cap wants a request count, got \
                             '{v}'")
        })?),
    };
    let sla_flag = flags.get("sla").map(String::as_str);
    let (coord, elems) = match backend {
        "pjrt" => {
            anyhow::ensure!(
                flags.get("scheme").is_none()
                    && flags.get("variants").is_none()
                    && flags.get("batch-mode").is_none()
                    && queue_cap.is_none(),
                "--scheme/--variants/--batch-mode/--queue-cap apply to \
                 --backend native only (the PJRT path serves the \
                 compiled AOT artifact as-is)"
            );
            let model = flags.get("model").map(String::as_str)
                .unwrap_or("resnet_mini");
            let rt = Runtime::new(&Runtime::default_dir())?;
            let spec = rt.manifest.model(model)?.clone();
            let elems: usize = spec.input_shape.iter().product();
            let mut cfg = ServeConfig::new(model);
            cfg.policy = policy;
            (Coordinator::start(cfg)?, elems)
        }
        "native" => {
            let model = flags.get("model").map(String::as_str)
                .unwrap_or("mobilenet_v2");
            let ir = match model {
                "vgg16" => zoo::vgg16(zoo::CIFAR_HW, 10),
                "resnet50" => zoo::resnet50(zoo::CIFAR_HW, 10),
                "mobilenet_v2" => zoo::mobilenet_v2(zoo::CIFAR_HW, 10),
                "text" => zoo::tiny_text_encoder(),
                other => bail!(
                    "unknown timing model {other} \
                     (vgg16|resnet50|mobilenet_v2|text)"
                ),
            };
            let variants_flag = flags
                .get("variants")
                .or_else(|| flags.get("scheme"))
                .map(String::as_str)
                .unwrap_or("cocogen");
            let mut schemes = Vec::new();
            for name in variants_flag.split(',') {
                let Some(scheme) = Scheme::parse(name.trim()) else {
                    bail!(
                        "unknown scheme '{}' in --variants (try one of: \
                         dense, cocogen, cocogen-quant, coco-auto)",
                        name.trim()
                    );
                };
                schemes.push(scheme);
            }
            let mode = match flags
                .get("batch-mode")
                .map(String::as_str)
                .unwrap_or("auto")
            {
                "auto" => NativeBatchMode::Auto,
                "fused" => NativeBatchMode::Fused,
                "fanout" | "fan-out" => NativeBatchMode::FanOut,
                other => bail!(
                    "unknown batch mode {other} (auto|fused|fanout)"
                ),
            };
            let elems = ir.input.elements();
            let mut builder = Coordinator::builder().policy(policy);
            if let Some(cap) = queue_cap {
                builder = builder.queue_cap(cap);
            }
            for scheme in schemes {
                if scheme == Scheme::CocoAuto {
                    println!(
                        "auto-tuning per-layer engines for {model} at \
                         batch {batch}..."
                    );
                }
                // Tune CocoAuto at threads = 1 and at the serving
                // batch size: per-layer winners must hold in the
                // regime that actually serves — fused batches of
                // max_batch images (the best kernel at n = 1 is often
                // not the best at n = 8).
                let mut db = Deployment::builder(scheme.label(), &ir)
                    .scheme(scheme)
                    .seed(7)
                    .batch_mode(mode);
                if scheme == Scheme::CocoAuto {
                    db = db.autotune_at(batch);
                }
                let dep = db.build()?;
                let plan = dep.plan().expect("native deployment");
                println!(
                    "deployment '{}': {} KB resident weights, {} KB \
                     activation arena per executor",
                    dep.name(),
                    plan.weight_bytes() / 1024,
                    plan.peak_activation_bytes() / 1024
                );
                builder = builder.register(dep);
            }
            (builder.start()?, elems)
        }
        other => bail!("unknown backend {other} (pjrt|native)"),
    };
    let client = coord.client();
    let multi = client.deployments().len() > 1;
    let fixed_sla = match sla_flag {
        None => None,
        Some("mixed") => None,
        Some(s) => Some(Sla::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown SLA class '{s}' (mixed|realtime|standard|quality)"
            )
        })?),
    };
    let mut rng = Rng::seed_from(1);
    let mut pending = Vec::new();
    let mut shed = 0usize;
    // With --rate, arrivals follow a fixed-seed open-loop Poisson
    // schedule — requests fire at their offsets whether or not earlier
    // ones completed, so a rate past capacity genuinely overloads the
    // coordinator and the overflow comes back as typed `Overloaded`
    // sheds (counted, not fatal). Without it, one closed burst.
    let schedule = rate
        .map(|r| cocopie::util::bench::arrival_schedule(r, n, 1));
    let t0 = std::time::Instant::now();
    for i in 0..n {
        if let Some(s) = &schedule {
            let elapsed = t0.elapsed();
            if s[i] > elapsed {
                std::thread::sleep(s[i] - elapsed);
            }
        }
        let img: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
        let sla = fixed_sla.unwrap_or_else(|| {
            if multi { Sla::mixed(i) } else { Sla::Standard }
        });
        match client.infer(InferRequest {
            image: img,
            sla,
            deployment: None,
        }) {
            Ok(rx) => pending.push((sla, rx)),
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => return Err(e.into()),
        }
    }
    let mut routed: HashMap<(Sla, std::sync::Arc<str>), usize> =
        HashMap::new();
    for (sla, p) in pending {
        match p.recv() {
            Ok(Ok(pred)) => {
                *routed.entry((sla, pred.deployment)).or_insert(0) += 1;
            }
            Ok(Err(ServeError::Overloaded { .. })) => shed += 1,
            _ => {}
        }
    }
    drop(client);
    let report = coord.shutdown_report();
    let s = &report.overall;
    println!(
        "served {} requests: p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
        s.completed, s.p50_ms, s.p99_ms, s.mean_batch
    );
    if rate.is_some() || shed > 0 {
        println!(
            "overload: {shed} shed (typed Overloaded), queue depth \
             high-water {}",
            s.queue_depth_max
        );
    }
    for dep in &report.deployments {
        println!(
            "  {:16} {:5} reqs  p50 {:7.2} ms  p99 {:7.2} ms",
            dep.name, dep.summary.completed, dep.summary.p50_ms,
            dep.summary.p99_ms
        );
    }
    if multi {
        let mut rows: Vec<_> = routed.into_iter().collect();
        rows.sort_by_key(|((sla, name), _)| {
            (sla.label(), name.clone())
        });
        println!("SLA routing (live, metrics-fed):");
        for ((sla, name), count) in rows {
            println!("  {:8} -> {:16} {count:5} reqs", sla.label(), name);
        }
    }
    Ok(())
}

/// `serve --lifecycle`: the hot-swap scene. v1 serves an open-loop
/// Poisson stream while v2 registers on the *running* coordinator,
/// canaries through staged traffic weights, promotes (or rolls back)
/// on windowed metric deltas, and the loser drains out — every
/// in-flight request answered, zero dropped.
fn serve_lifecycle(flags: &HashMap<String, String>) -> Result<()> {
    anyhow::ensure!(
        flags.get("backend").map(String::as_str).unwrap_or("native")
            == "native",
        "--lifecycle drives the native path (hot-swap needs \
         builder-made versions)"
    );
    for banned in
        ["variants", "scheme", "sla", "batch-mode", "queue-cap"]
    {
        anyhow::ensure!(
            !flags.contains_key(banned),
            "--{banned} does not combine with --lifecycle (the scene \
             builds its own v1/v2 schemes)"
        );
    }
    if flags.contains_key("no-simd") {
        cocopie::exec::micro::set_force_scalar(true);
    }
    let model = flags.get("model").map(String::as_str)
        .unwrap_or("mobilenet_v2");
    let ir = match model {
        "vgg16" => zoo::vgg16(zoo::CIFAR_HW, 10),
        "resnet50" => zoo::resnet50(zoo::CIFAR_HW, 10),
        "mobilenet_v2" => zoo::mobilenet_v2(zoo::CIFAR_HW, 10),
        "text" => zoo::tiny_text_encoder(),
        other => bail!(
            "unknown timing model {other} \
             (vgg16|resnet50|mobilenet_v2|text)"
        ),
    };
    let batch: usize =
        flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(8);
    let rate: f64 = flags
        .get("rate")
        .and_then(|v| v.parse().ok())
        .unwrap_or(200.0);
    anyhow::ensure!(rate > 0.0, "--rate must be positive");
    // The stream must outlast the canary's stage windows, or the
    // starved windows read as insufficient evidence and roll back.
    let n: usize = flags
        .get("requests")
        .and_then(|v| v.parse().ok())
        .unwrap_or((rate * 12.0) as usize);
    let elems = ir.input.elements();
    let v1 = format!("{model}@1");
    let v2 = format!("{model}@2");
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: batch,
            max_wait: std::time::Duration::from_millis(3),
        })
        .register(
            Deployment::builder(&v1, &ir)
                .scheme(Scheme::CocoGen)
                .seed(7)
                .build()?,
        )
        .start()?;
    let lc = coord.lifecycle();
    let client = coord.client();
    let schedule =
        cocopie::util::bench::arrival_schedule(rate, n, 11);
    println!(
        "lifecycle: {v1} serving {n} open-loop arrivals at \
         {rate:.0} req/s; hot-swapping to {v2} mid-stream"
    );
    let driver = std::thread::spawn(move || {
        cocopie::util::bench::open_loop_drive(
            &client,
            elems,
            &schedule,
            |_| Sla::Standard,
            std::time::Duration::from_secs(30),
        )
    });
    // Let v1 accumulate a little history before the swap starts.
    std::thread::sleep(std::time::Duration::from_millis(500));
    let dep2 = Deployment::builder(&v2, &ir)
        .scheme(Scheme::CocoGenQuant)
        .seed(7)
        .build()?;
    let cfg = CanaryConfig {
        stages: vec![0.05, 0.25, 1.0],
        stage_window: std::time::Duration::from_secs(3),
        min_requests: 16,
        max_p99_ratio: 2.0,
        p99_floor_ms: 5.0,
        max_shed_excess: 0.25,
        max_failovers: 0,
        poll: std::time::Duration::from_millis(10),
    };
    let t_swap = std::time::Instant::now();
    match lc.canary(dep2, &v1, &cfg)? {
        CanaryOutcome::Promoted => println!(
            "canary promoted in {:.1}s: {v2} live, {v1} drained and \
             retired",
            t_swap.elapsed().as_secs_f64()
        ),
        CanaryOutcome::RolledBack { stage, weight, reason } => {
            println!(
                "canary rolled back at stage {stage} (weight \
                 {weight:.2}): {reason}"
            )
        }
    }
    for (name, state) in lc.status() {
        println!("  {name:16} {state:?}");
    }
    let report = driver.join().unwrap();
    println!(
        "open loop: {} offered, {} completed, {} shed, {} failed, \
         {} hung, goodput {:.0} req/s",
        report.offered, report.completed, report.shed,
        report.failed, report.hung, report.goodput_rps()
    );
    anyhow::ensure!(
        report.hung == 0 && report.failed == 0,
        "requests lost across the hot-swap"
    );
    let report = coord.shutdown_report();
    for dep in &report.deployments {
        println!(
            "  {:16} {:5} reqs  p50 {:7.2} ms  p99 {:7.2} ms",
            dep.name, dep.summary.completed, dep.summary.p50_ms,
            dep.summary.p99_ms
        );
    }
    Ok(())
}

fn train(flags: &HashMap<String, String>) -> Result<()> {
    let model = flags.get("model").map(String::as_str)
        .unwrap_or("resnet_mini");
    let dataset = flags.get("dataset").map(String::as_str)
        .unwrap_or("synflowers");
    let steps: usize =
        flags.get("steps").and_then(|v| v.parse().ok()).unwrap_or(300);
    let rt = Runtime::new(&Runtime::default_dir())?;
    let trainer = Trainer::new(&rt, model)?;
    let ds = rt.manifest.datasets[dataset].clone();
    let mut state = ModelState::init(&trainer.spec, 42);
    let masks = config_masks(
        &trainer.spec,
        &state,
        &vec![0; trainer.spec.prunable_modules.len()],
    );
    let opts = TrainOpts {
        steps,
        eval_every: 50,
        ..Default::default()
    };
    let res = trainer.train(&mut state, &masks, &ds, &opts)?;
    println!("trained {model} on {dataset} for {} steps", res.steps);
    for (s, a) in &res.acc_curve {
        println!("  step {s:4}  acc {a:.3}");
    }
    Ok(())
}

fn compress(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("model").map(String::as_str).unwrap_or("vgg16");
    let ir = match name {
        "vgg16" => zoo::vgg16(zoo::IMAGENET_HW, 1000),
        "resnet50" => zoo::resnet50(zoo::IMAGENET_HW, 1000),
        "mobilenet_v2" => zoo::mobilenet_v2(zoo::IMAGENET_HW, 1000),
        // Sequence tier: CSR-pruned projections instead of pattern
        // kernels, same storage/FLOP report.
        "text" => zoo::text_encoder(128, 256, 4, 4, 10),
        other => anyhow::bail!(
            "unknown timing model {other} \
             (vgg16|resnet50|mobilenet_v2|text)"
        ),
    };
    let dense = build_plan(&ir, Scheme::DenseNaive, PruneConfig::default(),
                           7);
    let coco = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(), 7);
    println!("{name}: dense {} MB -> cocogen {} MB ({:.2}x), \
              FLOP keep ratio {:.3}",
             dense.weight_bytes() / (1 << 20),
             coco.weight_bytes() / (1 << 20),
             dense.weight_bytes() as f64 / coco.weight_bytes() as f64,
             coco.flop_keep_ratio());
    Ok(())
}

/// `verify`: compile scheme×model combos and run only the static
/// verifier over each — dataflow, arena non-aliasing, compressed-
/// metadata bounds, and scheme legality — never executing a kernel.
/// This is the CLI face of the same gate `Deployment::builder`
/// applies at registration time.
fn verify_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let model = flags.get("model").map(String::as_str).unwrap_or("all");
    let batch: usize =
        flags.get("batch").and_then(|v| v.parse().ok()).unwrap_or(8);
    let schemes: Vec<Scheme> = match flags.get("scheme") {
        None => Scheme::ALL.to_vec(),
        Some(s) => {
            let Some(scheme) = Scheme::parse(s) else {
                bail!("unknown scheme '{s}' (try one of: dense, \
                       cocogen, cocogen-quant, coco-auto)");
            };
            vec![scheme]
        }
    };
    let names: Vec<&str> = match model {
        "all" => vec!["vgg16", "resnet50", "mobilenet_v2", "text"],
        m => vec![m],
    };
    let mut combos = 0usize;
    for name in &names {
        let ir = match *name {
            "vgg16" => zoo::vgg16(zoo::CIFAR_HW, 10),
            "resnet50" => zoo::resnet50(zoo::CIFAR_HW, 10),
            "mobilenet_v2" => zoo::mobilenet_v2(zoo::CIFAR_HW, 10),
            "text" => zoo::tiny_text_encoder(),
            other => bail!(
                "unknown timing model {other} \
                 (all|vgg16|resnet50|mobilenet_v2|text)"
            ),
        };
        for &scheme in &schemes {
            let plan =
                build_plan(&ir, scheme, PruneConfig::default(), 7);
            for b in [1, batch.max(1)] {
                let pipe = match plan.verify_batched(b) {
                    Ok(p) => p,
                    Err(e) => bail!(
                        "{name} x {} at batch {b}: REJECTED: {e}",
                        scheme.label()
                    ),
                };
                println!(
                    "{name:14} {:14} batch {b:3}  ok: {:3} ops, {} KB \
                     arena",
                    scheme.label(),
                    pipe.ops.len(),
                    pipe.mem.peak_bytes() / 1024
                );
                combos += 1;
                if b == 1 && batch <= 1 {
                    break;
                }
            }
        }
    }
    println!("verified {combos} scheme x model x batch combos; all \
              proofs hold");
    Ok(())
}

fn explore(flags: &HashMap<String, String>) -> Result<()> {
    use cocopie::cocotune::explore::{explore, InitMode};
    use cocopie::cocotune::pretrain::pretrain_bank;
    let n_cfg: usize =
        flags.get("configs").and_then(|v| v.parse().ok()).unwrap_or(8);
    let rt = Runtime::new(&Runtime::default_dir())?;
    let trainer = Trainer::new(&rt, "resnet_mini")?;
    let ds = rt.manifest.datasets["synflowers"].clone();
    println!("training teacher...");
    let mut teacher = ModelState::init(&trainer.spec, 42);
    let masks = config_masks(&trainer.spec, &teacher, &vec![0; 6]);
    let res = trainer.train(
        &mut teacher,
        &masks,
        &ds,
        &TrainOpts {
            steps: 450,
            ..Default::default()
        },
    )?;
    println!("teacher acc {:.3}", res.final_acc);
    println!("pre-training tuning blocks...");
    let bank = pretrain_bank(&trainer, &teacher, &ds, 40, 0.02, 7)?;
    let configs = sample_subspace(6, n_cfg, 3);
    let thr = res.final_acc; // alpha = 0 (paper mid-range)
    let opts = TrainOpts {
        steps: 120,
        lr: 0.015,
        eval_every: 20,
        ..Default::default()
    };
    println!("exploring {} configs (thr {:.3})...", configs.len(), thr);
    let base = explore(&trainer, &teacher, &ds, &configs,
                       InitMode::Default, &opts, thr, true)?;
    let comp = explore(&trainer, &teacher, &ds, &configs,
                       InitMode::BlockTrained(&bank), &opts, thr, true)?;
    println!(
        "default:      {} configs, {} steps, found={:?}",
        base.results.len(),
        base.total_steps,
        base.found.map(|i| base.results[i].model_size)
    );
    println!(
        "block-trained: {} configs, {} steps (+{} pretrain), found={:?}",
        comp.results.len(),
        comp.total_steps,
        bank.pretrain_steps,
        comp.found.map(|i| comp.results[i].model_size)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags_accepts_known_pairs_and_switches() {
        let f = parse_flags(
            "serve",
            &args(&["--model", "vgg16", "--batch", "4", "--sla"]),
            &["model", "batch", "sla"],
        )
        .unwrap();
        assert_eq!(f.get("model").unwrap(), "vgg16");
        assert_eq!(f.get("batch").unwrap(), "4");
        // A trailing value-less flag parses as a switch.
        assert_eq!(f.get("sla").unwrap(), "true");
    }

    #[test]
    fn parse_flags_rejects_typos_with_usage_error() {
        // The motivating bug: `--scehme` must be an error, not a
        // silently served default scheme.
        let err = parse_flags(
            "serve",
            &args(&["--scehme", "cocogen"]),
            &["scheme", "model"],
        )
        .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--scehme") && msg.contains("serve"),
                "unhelpful error: {msg}");
        assert!(msg.contains("--scheme"),
                "error must list the accepted flags: {msg}");
    }

    #[test]
    fn parse_flags_rejects_bare_positional_arguments() {
        assert!(parse_flags("info", &args(&["extra"]), &[]).is_err());
        assert!(parse_flags("info", &args(&[]), &[]).is_ok());
    }
}
