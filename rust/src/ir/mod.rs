//! Layerwise representation (LR) — the paper's §2.1.3 "fine-grained DNN
//! layerwise representation": a high-level IR that carries, per layer,
//! everything the compression and code-generation passes need (shapes,
//! kernel geometry, pattern/tuning annotations attach in codegen::Plan).
//!
//! The LR is richer than a plain op list: every layer records its resolved
//! input/output shapes, so downstream passes (reorder, tuner, weight
//! compression, the executors, the hardware model) never re-derive
//! geometry.
//!
//! The IR spans two model families behind one [`Shape`] type: spatial
//! `[C, H, W]` conv nets and sequence `[T, D]` models (token count x
//! model width). Sequence shapes reuse the planar layout as
//! `{c: 1, h: T, w: D}`, so every family-agnostic pass (liveness, arena
//! planning, batching, the serving signature) works on both without a
//! dispatch; family-specific passes ask [`Shape::family`].

pub mod liveness;
pub mod zoo;

use anyhow::{bail, Result};

/// Which model family a shape belongs to. The extents live in the same
/// three fields either way; the family records how passes should read
/// them (and lets the builder reject e.g. attention over an image).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// `[C, H, W]` image activations (planar NCHW — see exec::Tensor).
    Spatial,
    /// `[T, D]` token sequences, stored as `{c: 1, h: T, w: D}`.
    Sequence,
}

/// Tensor shape for both model families. Spatial shapes are channels x
/// height x width; sequence shapes are tokens x width stored in the same
/// fields as `{c: 1, h: T, w: D}` so executors, liveness, and the serving
/// signature treat both identically. Equality compares extents only (a
/// `[T, D]` activation and a `[1, T, D]` image of the same numbers are
/// the same buffer), which keeps `exec::Tensor::shape()` — always
/// spatial — comparable against sequence pipeline shapes.
#[derive(Debug, Clone, Copy)]
pub struct Shape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    family: Family,
}

/// Historical name for [`Shape`] (the type predates the sequence tier);
/// every spatial call-site keeps compiling unchanged.
pub type Chw = Shape;

impl PartialEq for Shape {
    fn eq(&self, other: &Self) -> bool {
        (self.c, self.h, self.w) == (other.c, other.h, other.w)
    }
}
impl Eq for Shape {}

impl Shape {
    /// Spatial `[C, H, W]` shape.
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Shape {
            c,
            h,
            w,
            family: Family::Spatial,
        }
    }

    /// Sequence `[T, D]` shape (`t` tokens of width `d`).
    pub fn seq(t: usize, d: usize) -> Self {
        Shape {
            c: 1,
            h: t,
            w: d,
            family: Family::Sequence,
        }
    }

    pub fn elements(&self) -> usize {
        self.c * self.h * self.w
    }

    pub fn family(&self) -> Family {
        self.family
    }

    pub fn is_seq(&self) -> bool {
        self.family == Family::Sequence
    }

    /// Sequence length (tokens). Meaningful for sequence shapes.
    pub fn t(&self) -> usize {
        self.h
    }

    /// Sequence width (model dimension). Meaningful for sequence shapes.
    pub fn d(&self) -> usize {
        self.w
    }
}

/// Layer kinds supported by the native executors.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// Dense 2-D convolution, SAME padding.
    Conv {
        kh: usize,
        kw: usize,
        cout: usize,
        stride: usize,
        relu: bool,
    },
    /// Depthwise 3x3 convolution, SAME padding.
    DwConv { stride: usize, relu: bool },
    /// 2x2 max-pool, stride 2.
    MaxPool2,
    /// Global average pool -> [C, 1, 1].
    GlobalAvgPool,
    /// Fully connected over flattened input.
    Dense { cout: usize, relu: bool },
    /// Elementwise residual add with the *output* of an earlier layer
    /// (index into the model's layer list), then optional ReLU. Works
    /// for both families (a transformer residual is the same flat add).
    Add { from: usize, relu: bool },
    /// Per-token linear projection `[T, D_in] -> [T, d_out]` (weights
    /// `[d_out, D_in]` + bias) — the FC of the sequence family.
    MatMul { d_out: usize, relu: bool },
    /// Per-token layer normalization over the width `D` with learned
    /// scale/shift (gamma/beta).
    LayerNorm,
    /// Multi-head self-attention: fused per-head Q/K/V projections,
    /// `softmax(Q K^T / sqrt(D/heads)) V`, then the output projection.
    /// Shape-preserving: `[T, D] -> [T, D]`.
    SelfAttention { heads: usize },
    /// Mean-pool over the sequence positions: `[T, D] -> [D, 1, 1]`
    /// (spatial), so the existing `Dense` classifier head and the
    /// serving signature's `h == w == 1` logits check apply unchanged.
    SeqPool,
}

/// One layer of the LR.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub input: Shape,
    pub output: Shape,
}

impl Layer {
    /// Dense FLOPs (2*MACs) of this layer.
    pub fn flops(&self) -> u64 {
        match &self.kind {
            LayerKind::Conv { kh, kw, cout, .. } => {
                2 * (self.output.h * self.output.w * kh * kw * self.input.c
                    * cout) as u64
            }
            LayerKind::DwConv { .. } => {
                2 * (self.output.h * self.output.w * 9 * self.input.c) as u64
            }
            LayerKind::Dense { cout, .. } => {
                2 * (self.input.elements() * cout) as u64
            }
            LayerKind::Add { .. } => self.output.elements() as u64,
            LayerKind::MatMul { d_out, .. } => {
                2 * (self.input.t() * self.input.d() * d_out) as u64
            }
            LayerKind::LayerNorm => {
                // mean + variance + normalize + scale/shift ~ 8 ops/elem
                8 * self.input.elements() as u64
            }
            LayerKind::SelfAttention { heads } => {
                let (t, d) = (self.input.t(), self.input.d());
                // 4 projections (QKV + output) + QK^T + scores*V + softmax
                (8 * t * d * d + 4 * t * t * d + 5 * heads * t * t) as u64
            }
            LayerKind::SeqPool => self.input.elements() as u64,
            _ => 0,
        }
    }

    /// Dense weight-parameter count (biases excluded, like the conv arms).
    pub fn weight_count(&self) -> usize {
        match &self.kind {
            LayerKind::Conv { kh, kw, cout, .. } => {
                kh * kw * self.input.c * cout
            }
            LayerKind::DwConv { .. } => 9 * self.input.c,
            LayerKind::Dense { cout, .. } => self.input.elements() * cout,
            LayerKind::MatMul { d_out, .. } => self.input.d() * d_out,
            LayerKind::LayerNorm => 2 * self.input.d(),
            LayerKind::SelfAttention { .. } => {
                4 * self.input.d() * self.input.d()
            }
            _ => 0,
        }
    }

    /// Engine scratch (elements) this layer needs beyond its output slot.
    /// Only self-attention uses it: Q/K/V/context rows plus the
    /// `[heads, T, T]` score buffer — the sequence-length-dependent
    /// allocation the arena must plan for. NOT scaled by the batch
    /// dimension: the batched kernel loops per image over one scratch.
    pub fn scratch_elems(&self) -> usize {
        match &self.kind {
            LayerKind::SelfAttention { heads } => {
                let (t, d) = (self.input.t(), self.input.d());
                4 * t * d + heads * t * t
            }
            _ => 0,
        }
    }

    pub fn is_conv3x3(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { kh: 3, kw: 3, .. })
    }
}

/// A whole model in LR form.
#[derive(Debug, Clone)]
pub struct ModelIR {
    pub name: String,
    pub input: Shape,
    pub layers: Vec<Layer>,
}

/// Builder that tracks shapes as layers are appended.
pub struct IrBuilder {
    name: String,
    input: Shape,
    cur: Shape,
    layers: Vec<Layer>,
}

fn out_dim(size: usize, stride: usize) -> usize {
    size.div_ceil(stride)
}

impl IrBuilder {
    pub fn new(name: &str, input: Shape) -> Self {
        IrBuilder {
            name: name.to_string(),
            input,
            cur: input,
            layers: Vec::new(),
        }
    }

    /// Output index of the most recently added layer (the value `add`
    /// takes as its skip source). Panics with a clear message on an
    /// empty builder instead of underflowing.
    pub fn last(&self) -> usize {
        assert!(
            !self.layers.is_empty(),
            "IrBuilder::last() called on an empty builder: add a layer \
             before requesting a skip-link index"
        );
        self.layers.len() - 1
    }

    pub fn cur_shape(&self) -> Shape {
        self.cur
    }

    fn push(&mut self, name: &str, kind: LayerKind, out: Shape)
            -> &mut Self {
        self.layers.push(Layer {
            name: name.to_string(),
            kind,
            input: self.cur,
            output: out,
        });
        self.cur = out;
        self
    }

    pub fn conv(&mut self, name: &str, k: usize, cout: usize, stride: usize,
                relu: bool) -> &mut Self {
        let out = Shape::new(cout, out_dim(self.cur.h, stride),
                             out_dim(self.cur.w, stride));
        self.push(
            name,
            LayerKind::Conv {
                kh: k,
                kw: k,
                cout,
                stride,
                relu,
            },
            out,
        )
    }

    pub fn dwconv(&mut self, name: &str, stride: usize, relu: bool)
                  -> &mut Self {
        let out = Shape::new(self.cur.c, out_dim(self.cur.h, stride),
                             out_dim(self.cur.w, stride));
        self.push(name, LayerKind::DwConv { stride, relu }, out)
    }

    pub fn maxpool(&mut self, name: &str) -> &mut Self {
        let out = Shape::new(self.cur.c, out_dim(self.cur.h, 2),
                             out_dim(self.cur.w, 2));
        self.push(name, LayerKind::MaxPool2, out)
    }

    pub fn gap(&mut self, name: &str) -> &mut Self {
        let out = Shape::new(self.cur.c, 1, 1);
        self.push(name, LayerKind::GlobalAvgPool, out)
    }

    pub fn dense(&mut self, name: &str, cout: usize, relu: bool) -> &mut Self {
        let out = Shape::new(cout, 1, 1);
        self.push(name, LayerKind::Dense { cout, relu }, out)
    }

    /// Residual add with the output of layer index `from`. Both families.
    pub fn add(&mut self, name: &str, from: usize, relu: bool) -> &mut Self {
        let out = self.cur;
        self.push(name, LayerKind::Add { from, relu }, out)
    }

    fn assert_seq(&self, op: &str, name: &str) {
        assert!(
            self.cur.is_seq(),
            "{op} '{name}' requires a sequence shape, but the current \
             shape is {:?}",
            self.cur
        );
    }

    /// Per-token linear projection `[T, D] -> [T, d_out]`.
    pub fn matmul(&mut self, name: &str, d_out: usize, relu: bool)
                  -> &mut Self {
        self.assert_seq("matmul", name);
        let out = Shape::seq(self.cur.t(), d_out);
        self.push(name, LayerKind::MatMul { d_out, relu }, out)
    }

    /// Per-token layer normalization over the width `D`.
    pub fn layernorm(&mut self, name: &str) -> &mut Self {
        self.assert_seq("layernorm", name);
        let out = self.cur;
        self.push(name, LayerKind::LayerNorm, out)
    }

    /// Multi-head self-attention; `D` must divide evenly into `heads`.
    pub fn attention(&mut self, name: &str, heads: usize) -> &mut Self {
        self.assert_seq("attention", name);
        assert!(
            heads > 0 && self.cur.d() % heads == 0,
            "attention '{name}': width {} does not divide into {heads} \
             heads",
            self.cur.d()
        );
        let out = self.cur;
        self.push(name, LayerKind::SelfAttention { heads }, out)
    }

    /// Mean-pool over tokens: `[T, D] -> [D, 1, 1]` (spatial), feeding
    /// the standard `dense` classifier head.
    pub fn seqpool(&mut self, name: &str) -> &mut Self {
        self.assert_seq("seqpool", name);
        let out = Shape::new(self.cur.d(), 1, 1);
        self.push(name, LayerKind::SeqPool, out)
    }

    pub fn build(self) -> Result<ModelIR> {
        // Validate Add references and shape agreement, naming the
        // offending layers (not bare indices) so a bad skip-link in a
        // 50-layer model is findable from the message alone.
        for (i, l) in self.layers.iter().enumerate() {
            if let LayerKind::Add { from, .. } = l.kind {
                if from >= i {
                    bail!(
                        "{}: Add skip-link references layer index {from}, \
                         but only {i} earlier layer(s) exist",
                        l.name
                    );
                }
                let src = &self.layers[from];
                if src.output != l.input {
                    bail!(
                        "{}: Add from {} has mismatched shapes: {:?} vs \
                         {:?}",
                        l.name,
                        src.name,
                        src.output,
                        l.input
                    );
                }
            }
        }
        Ok(ModelIR {
            name: self.name,
            input: self.input,
            layers: self.layers,
        })
    }
}

impl ModelIR {
    pub fn flops(&self) -> u64 {
        self.layers.iter().map(Layer::flops).sum()
    }
    pub fn weight_count(&self) -> usize {
        self.layers.iter().map(Layer::weight_count).sum()
    }
    /// Indices of 3x3 conv layers (the pattern-prunable set).
    pub fn conv3x3_layers(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.is_conv3x3())
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_tracks_shapes() {
        let mut b = IrBuilder::new("t", Chw::new(3, 32, 32));
        b.conv("c1", 3, 16, 1, true)
            .maxpool("p1")
            .conv("c2", 3, 32, 2, true)
            .gap("g")
            .dense("fc", 10, false);
        let m = b.build().unwrap();
        assert_eq!(m.layers[0].output, Chw::new(16, 32, 32));
        assert_eq!(m.layers[1].output, Chw::new(16, 16, 16));
        assert_eq!(m.layers[2].output, Chw::new(32, 8, 8));
        assert_eq!(m.layers[4].output, Chw::new(10, 1, 1));
        assert!(m.flops() > 0);
    }

    #[test]
    fn add_validates_shapes() {
        let mut b = IrBuilder::new("t", Chw::new(8, 8, 8));
        b.conv("c1", 3, 8, 1, true);
        let skip = b.last();
        b.conv("c2", 3, 8, 1, false).add("a", skip, true);
        assert!(b.build().is_ok());

        let mut b = IrBuilder::new("t", Chw::new(8, 8, 8));
        b.conv("c1", 3, 8, 1, true);
        let skip = b.last();
        b.conv("c2", 3, 16, 1, false).add("a", skip, true);
        assert!(b.build().is_err()); // channel mismatch
    }

    #[test]
    fn build_errors_name_the_offending_layers() {
        // Shape mismatch: the message carries both layer names.
        let mut b = IrBuilder::new("t", Chw::new(8, 8, 8));
        b.conv("conv1", 3, 8, 1, true);
        let skip = b.last();
        b.conv("conv2", 3, 16, 1, false).add("add3", skip, true);
        let err = b.build().unwrap_err().to_string();
        assert!(err.contains("add3: Add from conv1"), "got: {err}");
        assert!(err.contains("mismatched shapes"), "got: {err}");

        // Bad skip-link index: the message names the Add layer.
        let mut b = IrBuilder::new("t", Chw::new(8, 8, 8));
        b.conv("c1", 3, 8, 1, true).add("bad_add", 7, false);
        let err = b.build().unwrap_err().to_string();
        assert!(err.contains("bad_add: Add skip-link references layer \
                              index 7"),
                "got: {err}");
    }

    #[test]
    #[should_panic(expected = "empty builder")]
    fn last_on_empty_builder_panics_clearly() {
        let b = IrBuilder::new("t", Chw::new(1, 4, 4));
        let _ = b.last();
    }

    #[test]
    fn flops_and_weights_scale() {
        let mut b = IrBuilder::new("t", Chw::new(4, 16, 16));
        b.conv("c", 3, 8, 1, false);
        let m = b.build().unwrap();
        assert_eq!(m.layers[0].weight_count(), 3 * 3 * 4 * 8);
        assert_eq!(m.layers[0].flops(), 2 * 16 * 16 * 9 * 4 * 8);
    }

    #[test]
    fn seq_shapes_compare_by_extents_but_keep_family() {
        let s = Shape::seq(16, 32);
        assert_eq!((s.c, s.h, s.w), (1, 16, 32));
        assert_eq!((s.t(), s.d()), (16, 32));
        assert!(s.is_seq());
        assert_eq!(s.family(), Family::Sequence);
        // Equality ignores family: a [1, T, D] spatial tensor is the
        // same buffer as a [T, D] sequence activation.
        assert_eq!(s, Shape::new(1, 16, 32));
        assert!(!Shape::new(1, 16, 32).is_seq());
    }

    #[test]
    fn seq_builder_tracks_shapes_and_counts() {
        let mut b = IrBuilder::new("seq", Shape::seq(16, 32));
        b.matmul("embed", 32, false);
        let skip = b.last();
        b.attention("attn", 4)
            .add("res", skip, false)
            .layernorm("ln")
            .matmul("ff1", 64, true)
            .matmul("ff2", 32, false)
            .seqpool("pool")
            .dense("cls", 5, false);
        let m = b.build().unwrap();
        assert_eq!(m.layers[1].output, Shape::seq(16, 32));
        assert_eq!(m.layers[4].output, Shape::seq(16, 64));
        assert_eq!(m.layers[6].output, Shape::new(32, 1, 1));
        assert_eq!(m.layers[7].output, Shape::new(5, 1, 1));
        // MatMul params: d_in * d_out, attention 4*D^2, layernorm 2*D.
        assert_eq!(m.layers[0].weight_count(), 32 * 32);
        assert_eq!(m.layers[1].weight_count(), 4 * 32 * 32);
        assert_eq!(m.layers[3].weight_count(), 2 * 32);
        assert_eq!(m.layers[4].weight_count(), 32 * 64);
        assert_eq!(m.layers[4].flops(), 2 * 16 * 32 * 64);
        // Attention scratch: Q/K/V/ctx rows + [heads, T, T] scores.
        assert_eq!(m.layers[1].scratch_elems(),
                   4 * 16 * 32 + 4 * 16 * 16);
        assert_eq!(m.layers[0].scratch_elems(), 0);
    }

    #[test]
    #[should_panic(expected = "requires a sequence shape")]
    fn seq_ops_reject_spatial_shapes() {
        let mut b = IrBuilder::new("t", Chw::new(3, 8, 8));
        b.conv("c1", 3, 8, 1, true).attention("attn", 2);
    }
}
