//! Layerwise representation (LR) — the paper's §2.1.3 "fine-grained DNN
//! layerwise representation": a high-level IR that carries, per layer,
//! everything the compression and code-generation passes need (shapes,
//! kernel geometry, pattern/tuning annotations attach in codegen::Plan).
//!
//! The LR is richer than a plain op list: every layer records its resolved
//! input/output spatial shapes, so downstream passes (reorder, tuner,
//! weight compression, the executors, the hardware model) never re-derive
//! geometry.

pub mod liveness;
pub mod zoo;

use anyhow::{bail, Result};

/// Spatial tensor shape: channels, height, width (executors use planar
/// NCHW layout — see exec::Tensor).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chw {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Chw {
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Chw { c, h, w }
    }
    pub fn elements(&self) -> usize {
        self.c * self.h * self.w
    }
}

/// Layer kinds supported by the native executors.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// Dense 2-D convolution, SAME padding.
    Conv {
        kh: usize,
        kw: usize,
        cout: usize,
        stride: usize,
        relu: bool,
    },
    /// Depthwise 3x3 convolution, SAME padding.
    DwConv { stride: usize, relu: bool },
    /// 2x2 max-pool, stride 2.
    MaxPool2,
    /// Global average pool -> [C, 1, 1].
    GlobalAvgPool,
    /// Fully connected over flattened input.
    Dense { cout: usize, relu: bool },
    /// Elementwise residual add with the *output* of an earlier layer
    /// (index into the model's layer list), then optional ReLU.
    Add { from: usize, relu: bool },
}

/// One layer of the LR.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub input: Chw,
    pub output: Chw,
}

impl Layer {
    /// Dense FLOPs (2*MACs) of this layer.
    pub fn flops(&self) -> u64 {
        match &self.kind {
            LayerKind::Conv { kh, kw, cout, .. } => {
                2 * (self.output.h * self.output.w * kh * kw * self.input.c
                    * cout) as u64
            }
            LayerKind::DwConv { .. } => {
                2 * (self.output.h * self.output.w * 9 * self.input.c) as u64
            }
            LayerKind::Dense { cout, .. } => {
                2 * (self.input.elements() * cout) as u64
            }
            LayerKind::Add { .. } => self.output.elements() as u64,
            _ => 0,
        }
    }

    /// Dense weight-parameter count.
    pub fn weight_count(&self) -> usize {
        match &self.kind {
            LayerKind::Conv { kh, kw, cout, .. } => {
                kh * kw * self.input.c * cout
            }
            LayerKind::DwConv { .. } => 9 * self.input.c,
            LayerKind::Dense { cout, .. } => self.input.elements() * cout,
            _ => 0,
        }
    }

    pub fn is_conv3x3(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { kh: 3, kw: 3, .. })
    }
}

/// A whole model in LR form.
#[derive(Debug, Clone)]
pub struct ModelIR {
    pub name: String,
    pub input: Chw,
    pub layers: Vec<Layer>,
}

/// Builder that tracks shapes as layers are appended.
pub struct IrBuilder {
    name: String,
    input: Chw,
    cur: Chw,
    layers: Vec<Layer>,
}

fn out_dim(size: usize, stride: usize) -> usize {
    size.div_ceil(stride)
}

impl IrBuilder {
    pub fn new(name: &str, input: Chw) -> Self {
        IrBuilder {
            name: name.to_string(),
            input,
            cur: input,
            layers: Vec::new(),
        }
    }

    /// Output index of the most recently added layer (the value `add`
    /// takes as its skip source). Panics with a clear message on an
    /// empty builder instead of underflowing.
    pub fn last(&self) -> usize {
        assert!(
            !self.layers.is_empty(),
            "IrBuilder::last() called on an empty builder: add a layer \
             before requesting a skip-link index"
        );
        self.layers.len() - 1
    }

    pub fn cur_shape(&self) -> Chw {
        self.cur
    }

    pub fn conv(&mut self, name: &str, k: usize, cout: usize, stride: usize,
                relu: bool) -> &mut Self {
        let out = Chw::new(cout, out_dim(self.cur.h, stride),
                           out_dim(self.cur.w, stride));
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Conv {
                kh: k,
                kw: k,
                cout,
                stride,
                relu,
            },
            input: self.cur,
            output: out,
        });
        self.cur = out;
        self
    }

    pub fn dwconv(&mut self, name: &str, stride: usize, relu: bool)
                  -> &mut Self {
        let out = Chw::new(self.cur.c, out_dim(self.cur.h, stride),
                           out_dim(self.cur.w, stride));
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::DwConv { stride, relu },
            input: self.cur,
            output: out,
        });
        self.cur = out;
        self
    }

    pub fn maxpool(&mut self, name: &str) -> &mut Self {
        let out = Chw::new(self.cur.c, out_dim(self.cur.h, 2),
                           out_dim(self.cur.w, 2));
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::MaxPool2,
            input: self.cur,
            output: out,
        });
        self.cur = out;
        self
    }

    pub fn gap(&mut self, name: &str) -> &mut Self {
        let out = Chw::new(self.cur.c, 1, 1);
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::GlobalAvgPool,
            input: self.cur,
            output: out,
        });
        self.cur = out;
        self
    }

    pub fn dense(&mut self, name: &str, cout: usize, relu: bool) -> &mut Self {
        let out = Chw::new(cout, 1, 1);
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Dense { cout, relu },
            input: self.cur,
            output: out,
        });
        self.cur = out;
        self
    }

    /// Residual add with the output of layer index `from`.
    pub fn add(&mut self, name: &str, from: usize, relu: bool) -> &mut Self {
        let out = self.cur;
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Add { from, relu },
            input: self.cur,
            output: out,
        });
        self
    }

    pub fn build(self) -> Result<ModelIR> {
        // Validate Add references and shape agreement.
        for (i, l) in self.layers.iter().enumerate() {
            if let LayerKind::Add { from, .. } = l.kind {
                if from >= i {
                    bail!("layer {i} Add references later layer {from}");
                }
                if self.layers[from].output != l.input {
                    bail!(
                        "Add shape mismatch at layer {i}: {:?} vs {:?}",
                        self.layers[from].output,
                        l.input
                    );
                }
            }
        }
        Ok(ModelIR {
            name: self.name,
            input: self.input,
            layers: self.layers,
        })
    }
}

impl ModelIR {
    pub fn flops(&self) -> u64 {
        self.layers.iter().map(Layer::flops).sum()
    }
    pub fn weight_count(&self) -> usize {
        self.layers.iter().map(Layer::weight_count).sum()
    }
    /// Indices of 3x3 conv layers (the pattern-prunable set).
    pub fn conv3x3_layers(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.is_conv3x3())
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_tracks_shapes() {
        let mut b = IrBuilder::new("t", Chw::new(3, 32, 32));
        b.conv("c1", 3, 16, 1, true)
            .maxpool("p1")
            .conv("c2", 3, 32, 2, true)
            .gap("g")
            .dense("fc", 10, false);
        let m = b.build().unwrap();
        assert_eq!(m.layers[0].output, Chw::new(16, 32, 32));
        assert_eq!(m.layers[1].output, Chw::new(16, 16, 16));
        assert_eq!(m.layers[2].output, Chw::new(32, 8, 8));
        assert_eq!(m.layers[4].output, Chw::new(10, 1, 1));
        assert!(m.flops() > 0);
    }

    #[test]
    fn add_validates_shapes() {
        let mut b = IrBuilder::new("t", Chw::new(8, 8, 8));
        b.conv("c1", 3, 8, 1, true);
        let skip = b.last();
        b.conv("c2", 3, 8, 1, false).add("a", skip, true);
        assert!(b.build().is_ok());

        let mut b = IrBuilder::new("t", Chw::new(8, 8, 8));
        b.conv("c1", 3, 8, 1, true);
        let skip = b.last();
        b.conv("c2", 3, 16, 1, false).add("a", skip, true);
        assert!(b.build().is_err()); // channel mismatch
    }

    #[test]
    #[should_panic(expected = "empty builder")]
    fn last_on_empty_builder_panics_clearly() {
        let b = IrBuilder::new("t", Chw::new(1, 4, 4));
        let _ = b.last();
    }

    #[test]
    fn flops_and_weights_scale() {
        let mut b = IrBuilder::new("t", Chw::new(4, 16, 16));
        b.conv("c", 3, 8, 1, false);
        let m = b.build().unwrap();
        assert_eq!(m.layers[0].weight_count(), 3 * 3 * 4 * 8);
        assert_eq!(m.layers[0].flops(), 2 * 16 * 16 * 9 * 4 * 8);
    }
}
