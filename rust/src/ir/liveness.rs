//! Liveness analysis and static activation-memory planning over the LR.
//!
//! The executor used to allocate a fresh output tensor per layer per
//! inference and keep clones of every `Add` source alive. This pass
//! computes, ahead of time, how long each layer's output actually lives
//! (its last reader — the next layer, or a later `Add` skip-link) and
//! assigns every output to a slot in a small arena of reusable buffers.
//! A chain model needs 2 slots; a residual model needs 3 — independent
//! of depth — so steady-state inference performs no activation
//! allocation at all.
//!
//! `codegen::lower` consumes the [`MemoryPlan`] when compiling an
//! `ExecPlan` into its op pipeline;
//! `codegen::ExecPlan::peak_activation_bytes` reports its footprint next
//! to `weight_bytes()`.

use super::{LayerKind, ModelIR};

/// For each layer output, the index of its last reader.
///
/// Layer `i`'s output is read by layer `i + 1` (the linear chain) and by
/// any later `Add { from: i, .. }` layer. The final layer's output is
/// the model result and gets the sentinel `n` (alive past the end).
pub fn last_uses(ir: &ModelIR) -> Vec<usize> {
    let n = ir.layers.len();
    let mut last: Vec<usize> = (0..n).map(|i| (i + 1).min(n)).collect();
    if n > 0 {
        last[n - 1] = n;
    }
    for (j, l) in ir.layers.iter().enumerate() {
        if let LayerKind::Add { from, .. } = l.kind {
            last[from] = last[from].max(j);
        }
    }
    last
}

/// Static assignment of layer outputs to reusable arena slots.
///
/// The plan can carry a leading batch dimension: `build_batched(ir, n)`
/// sizes every slot for `n` images stored contiguously `[N][C][H][W]`
/// (`batch` records the factor), so a batch-compiled pipeline serves
/// fused batches out of the same fixed arena — weights and slot
/// assignment identical to the single-image plan, capacities scaled.
///
/// This greedy assignment is a *claim*, not a proof: the static
/// verifier (`codegen::verify`) independently re-derives liveness from
/// the lowered ops at compile/register time and rejects any plan where
/// two simultaneously-live values would share a slot, a write lands
/// in-place, a capacity falls short, or [`MemoryPlan::peak_bytes`]
/// disagrees with the verified footprint.
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    /// Arena slot holding each layer's output.
    pub slot_of: Vec<usize>,
    /// Element capacity of each slot (max over its tenants, times
    /// `batch`).
    pub slot_elems: Vec<usize>,
    /// Leading batch dimension the capacities were scaled for (1 for
    /// single-image plans).
    pub batch: usize,
    /// Shared engine-scratch capacity (elements): the max of
    /// `Layer::scratch_elems()` over the model — for self-attention the
    /// `[heads, T, T]` score buffer plus Q/K/V/context rows, so the
    /// footprint depends on sequence length, not just channel counts.
    /// NOT scaled by `batch`: the batched attention kernel loops per
    /// image over the one scratch region. Zero for conv-only models.
    pub scratch_elems: usize,
}

impl MemoryPlan {
    /// Greedy linear scan: walk layers in execution order, reusing any
    /// slot whose current tenant was last read strictly before this op
    /// (a tenant with `last_use == i` is still being read *by* op `i`,
    /// so its slot can never double as op `i`'s output — that rule is
    /// what makes every op safely out-of-place). Among free slots the
    /// best fit wins: smallest one that already holds the output, else
    /// the one needing the least growth.
    pub fn build(ir: &ModelIR) -> MemoryPlan {
        Self::build_batched(ir, 1)
    }

    /// [`MemoryPlan::build`] with every slot sized for `batch` images
    /// stored contiguously (the fused-batch arena). Slot *assignment* is
    /// identical to the single-image plan — liveness does not depend on
    /// the batch size — only capacities scale.
    pub fn build_batched(ir: &ModelIR, batch: usize) -> MemoryPlan {
        let batch = batch.max(1);
        let n = ir.layers.len();
        let last = last_uses(ir);
        let mut slot_of = vec![0usize; n];
        let mut slot_elems: Vec<usize> = Vec::new();
        // Last-use index of each slot's current tenant.
        let mut expiry: Vec<usize> = Vec::new();
        for i in 0..n {
            let need = ir.layers[i].output.elements();
            let fit = |s: usize| {
                let sz = slot_elems[s];
                // (must grow?, wasted or missing elements)
                if sz >= need {
                    (false, sz - need)
                } else {
                    (true, need - sz)
                }
            };
            let mut best: Option<usize> = None;
            for (s, &e) in expiry.iter().enumerate() {
                if e >= i {
                    continue; // tenant still live (or read by op i)
                }
                best = match best {
                    None => Some(s),
                    Some(b) if fit(s) < fit(b) => Some(s),
                    keep => keep,
                };
            }
            let s = match best {
                Some(s) => {
                    slot_elems[s] = slot_elems[s].max(need);
                    s
                }
                None => {
                    slot_elems.push(need);
                    expiry.push(0);
                    slot_elems.len() - 1
                }
            };
            expiry[s] = last[i];
            slot_of[i] = s;
        }
        for e in slot_elems.iter_mut() {
            *e *= batch;
        }
        let scratch_elems = ir
            .layers
            .iter()
            .map(super::Layer::scratch_elems)
            .max()
            .unwrap_or(0);
        MemoryPlan {
            slot_of,
            slot_elems,
            batch,
            scratch_elems,
        }
    }

    /// Total arena footprint in bytes (f32 activations), engine scratch
    /// included — what `exec::Arena::for_pipeline` allocates and what the
    /// no-growth regression guard compares against.
    pub fn peak_bytes(&self) -> usize {
        (self.slot_elems.iter().sum::<usize>() + self.scratch_elems) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Chw, IrBuilder};

    fn chain_ir() -> ModelIR {
        let mut b = IrBuilder::new("chain", Chw::new(3, 16, 16));
        b.conv("c1", 3, 8, 1, true)
            .conv("c2", 3, 8, 1, true)
            .conv("c3", 3, 16, 2, true)
            .gap("g")
            .dense("fc", 10, false);
        b.build().unwrap()
    }

    fn residual_ir() -> ModelIR {
        let mut b = IrBuilder::new("res", Chw::new(3, 12, 12));
        b.conv("c1", 3, 8, 1, true);
        let skip = b.last();
        b.conv("c2", 3, 8, 1, false)
            .conv("c3", 3, 8, 1, false)
            .add("a", skip, true)
            .gap("g")
            .dense("fc", 5, false);
        b.build().unwrap()
    }

    #[test]
    fn chain_uses_two_slots() {
        let ir = chain_ir();
        let mp = MemoryPlan::build(&ir);
        assert_eq!(mp.slot_elems.len(), 2, "{:?}", mp);
        // consecutive layers never share a slot (out-of-place ops)
        for w in mp.slot_of.windows(2) {
            assert_ne!(w[0], w[1]);
        }
    }

    #[test]
    fn residual_keeps_skip_source_alive() {
        let ir = residual_ir();
        let last = last_uses(&ir);
        // c1 (index 0) is read by c2 (1) and by the Add at index 3.
        assert_eq!(last[0], 3);
        let mp = MemoryPlan::build(&ir);
        // three live values at the Add: skip, cur, and the Add's output
        assert_eq!(mp.slot_elems.len(), 3, "{:?}", mp);
        // the Add's inputs (c1 and c3 outputs) and output all differ
        assert_ne!(mp.slot_of[3], mp.slot_of[0]);
        assert_ne!(mp.slot_of[3], mp.slot_of[2]);
    }

    #[test]
    fn peak_is_bounded_by_total_and_covers_largest() {
        for ir in [chain_ir(), residual_ir()] {
            let mp = MemoryPlan::build(&ir);
            let total: usize = ir
                .layers
                .iter()
                .map(|l| l.output.elements() * 4)
                .sum();
            let largest = ir
                .layers
                .iter()
                .map(|l| l.output.elements() * 4)
                .max()
                .unwrap();
            assert!(mp.peak_bytes() <= total);
            assert!(mp.peak_bytes() >= largest);
        }
    }

    #[test]
    fn batched_plan_scales_capacities_only() {
        for ir in [chain_ir(), residual_ir()] {
            let single = MemoryPlan::build(&ir);
            let batched = MemoryPlan::build_batched(&ir, 8);
            assert_eq!(single.batch, 1);
            assert_eq!(batched.batch, 8);
            // same slot assignment, 8x the capacity per slot
            assert_eq!(single.slot_of, batched.slot_of);
            assert_eq!(single.slot_elems.len(), batched.slot_elems.len());
            for (s, b) in single.slot_elems.iter()
                .zip(&batched.slot_elems)
            {
                assert_eq!(s * 8, *b);
            }
            assert_eq!(single.peak_bytes() * 8, batched.peak_bytes());
        }
    }

    fn seq_ir(t: usize, d: usize, heads: usize) -> ModelIR {
        let mut b = IrBuilder::new("seq", crate::ir::Shape::seq(t, d));
        b.matmul("embed", d, false);
        let skip = b.last();
        b.attention("attn", heads)
            .add("res", skip, false)
            .layernorm("ln")
            .seqpool("pool")
            .dense("cls", 4, false);
        b.build().unwrap()
    }

    #[test]
    fn attention_scratch_scales_with_sequence_length() {
        let short = MemoryPlan::build(&seq_ir(8, 16, 2));
        let long = MemoryPlan::build(&seq_ir(32, 16, 2));
        // [heads, T, T] + Q/K/V/ctx rows, per the layer's contract.
        assert_eq!(short.scratch_elems, 4 * 8 * 16 + 2 * 8 * 8);
        assert_eq!(long.scratch_elems, 4 * 32 * 16 + 2 * 32 * 32);
        assert!(long.peak_bytes() > short.peak_bytes());
        // scratch is part of the reported peak
        assert!(short.peak_bytes()
                >= (short.slot_elems.iter().sum::<usize>()
                    + short.scratch_elems) * 4);
    }

    #[test]
    fn batched_seq_plan_scales_slots_not_scratch() {
        let ir = seq_ir(16, 32, 4);
        let single = MemoryPlan::build(&ir);
        let batched = MemoryPlan::build_batched(&ir, 8);
        assert_eq!(single.slot_of, batched.slot_of);
        for (s, b) in single.slot_elems.iter().zip(&batched.slot_elems) {
            assert_eq!(s * 8, *b);
        }
        // The batched attention kernel loops per image over one scratch
        // region, so scratch does not carry the batch factor.
        assert_eq!(single.scratch_elems, batched.scratch_elems);
        assert!(single.scratch_elems > 0);
    }

    #[test]
    fn conv_models_need_no_scratch() {
        for ir in [chain_ir(), residual_ir()] {
            assert_eq!(MemoryPlan::build(&ir).scratch_elems, 0);
        }
    }

    #[test]
    fn empty_model_has_empty_plan() {
        let ir = ModelIR {
            name: "empty".into(),
            input: Chw::new(1, 1, 1),
            layers: Vec::new(),
        };
        let mp = MemoryPlan::build(&ir);
        assert!(mp.slot_of.is_empty());
        assert_eq!(mp.peak_bytes(), 0);
    }
}
