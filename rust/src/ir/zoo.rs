//! Full-shape timing models (paper Fig. 5: VGG-16, ResNet-50,
//! MobileNet-V2 on ImageNet- and CIFAR-shaped inputs) plus the mini
//! generative nets for the Fig. 6 application demos.
//!
//! These drive the *native executor* latency comparisons; weights are
//! random (latency is weight-value independent). The "ImageNet" spatial
//! resolution is reduced 224 -> 64 so the dense naive baseline finishes in
//! bench-able time on this CPU (documented substitution, DESIGN.md §2);
//! channel counts — which determine the arithmetic-intensity regime — are
//! the real ones.

use super::{Chw, IrBuilder, ModelIR, Shape};

/// Input resolutions for the two dataset shapes of Fig. 5.
pub const IMAGENET_HW: usize = 64; // paper: 224 (see DESIGN.md §2)
pub const CIFAR_HW: usize = 32;

/// VGG-16 conv backbone (channel plan 64..512) + small head.
pub fn vgg16(hw: usize, classes: usize) -> ModelIR {
    let mut b = IrBuilder::new(
        &format!("vgg16_{hw}"),
        Chw::new(3, hw, hw),
    );
    let plan: &[(usize, usize)] =
        &[(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    let mut li = 0;
    for (bi, (ch, n)) in plan.iter().enumerate() {
        for ci in 0..*n {
            b.conv(&format!("conv{}_{}", bi + 1, ci + 1), 3, *ch, 1, true);
            li += 1;
        }
        // stop pooling once the spatial dims hit 2x2
        if b.cur_shape().h > 2 {
            b.maxpool(&format!("pool{}", bi + 1));
        }
        let _ = li;
    }
    b.gap("gap").dense("fc", classes, false);
    b.build().expect("vgg16 IR")
}

/// ResNet-50: bottleneck stacks [3,4,6,3], channels 256/512/1024/2048.
pub fn resnet50(hw: usize, classes: usize) -> ModelIR {
    let mut b = IrBuilder::new(
        &format!("resnet50_{hw}"),
        Chw::new(3, hw, hw),
    );
    b.conv("stem", 3, 64, if hw >= 64 { 2 } else { 1 }, true);
    let stacks: &[(usize, usize, usize)] = &[
        (64, 256, 3),
        (128, 512, 4),
        (256, 1024, 6),
        (512, 2048, 3),
    ];
    for (si, (mid, out, n)) in stacks.iter().enumerate() {
        for bi in 0..*n {
            let stride = if si > 0 && bi == 0 && b.cur_shape().h > 2 {
                2
            } else {
                1
            };
            let skip_ok = bi > 0; // first block of a stack changes shape
            let pre = b.last();
            let tag = format!("s{si}b{bi}");
            b.conv(&format!("{tag}_c1"), 1, *mid, stride, true)
                .conv(&format!("{tag}_c2"), 3, *mid, 1, true)
                .conv(&format!("{tag}_c3"), 1, *out, 1, false);
            if skip_ok {
                b.add(&format!("{tag}_add"), pre, true);
            }
        }
    }
    b.gap("gap").dense("fc", classes, false);
    b.build().expect("resnet50 IR")
}

/// MobileNet-V2-ish: stem + depthwise-separable chain with expansion.
pub fn mobilenet_v2(hw: usize, classes: usize) -> ModelIR {
    let mut b = IrBuilder::new(
        &format!("mbntv2_{hw}"),
        Chw::new(3, hw, hw),
    );
    b.conv("stem", 3, 32, if hw >= 64 { 2 } else { 1 }, true);
    // (expansion cout, stride) plan, channels from the paper's MBv2 table
    let plan: &[(usize, usize)] = &[
        (16, 1),
        (24, 2),
        (24, 1),
        (32, 2),
        (32, 1),
        (64, 2),
        (64, 1),
        (96, 1),
        (160, if hw >= 64 { 2 } else { 1 }),
        (320, 1),
    ];
    for (i, (cout, stride)) in plan.iter().enumerate() {
        let stride = if b.cur_shape().h <= 2 { 1 } else { *stride };
        let cin = b.cur_shape().c;
        let expand = (cin * 6).min(960);
        b.conv(&format!("b{i}_expand"), 1, expand, 1, true)
            .dwconv(&format!("b{i}_dw"), stride, true)
            .conv(&format!("b{i}_project"), 1, *cout, 1, false);
    }
    b.conv("head_conv", 1, 1280, 1, true)
        .gap("gap")
        .dense("fc", classes, false);
    b.build().expect("mbntv2 IR")
}

/// The six Fig. 5 model/dataset pairs: (label, ModelIR).
pub fn fig5_models() -> Vec<(String, ModelIR)> {
    let mut out = Vec::new();
    for (tag, hw, classes) in
        [("imagenet", IMAGENET_HW, 1000), ("cifar", CIFAR_HW, 10)]
    {
        out.push((format!("VGG-{tag}"), vgg16(hw, classes)));
        out.push((format!("RNT-{tag}"), resnet50(hw, classes)));
        out.push((format!("MBNT-{tag}"), mobilenet_v2(hw, classes)));
    }
    out
}

/// Fig. 6 app-demo generative nets (encoder-decoder without upsampling:
/// conv stacks at full resolution dominate, as in the real demos).
pub fn style_transfer_net(hw: usize) -> ModelIR {
    let mut b = IrBuilder::new("style_transfer", Chw::new(3, hw, hw));
    b.conv("enc1", 3, 32, 1, true).conv("enc2", 3, 64, 2, true);
    for i in 0..4 {
        let pre = b.last();
        b.conv(&format!("res{i}_c1"), 3, 64, 1, true)
            .conv(&format!("res{i}_c2"), 3, 64, 1, false)
            .add(&format!("res{i}_add"), pre, true);
    }
    b.conv("dec1", 3, 32, 1, true).conv("dec2", 3, 3, 1, false);
    b.build().expect("style IR")
}

pub fn coloring_net(hw: usize) -> ModelIR {
    let mut b = IrBuilder::new("coloring", Chw::new(1, hw, hw));
    b.conv("low1", 3, 32, 2, true)
        .conv("low2", 3, 64, 1, true)
        .conv("mid1", 3, 64, 1, true)
        .conv("mid2", 3, 64, 1, true)
        .conv("fuse", 1, 64, 1, true)
        .conv("col1", 3, 32, 1, true)
        .conv("col2", 3, 2, 1, false);
    b.build().expect("coloring IR")
}

pub fn super_resolution_net(hw: usize) -> ModelIR {
    // WDSR-like: wide-activation residual blocks + linear low-rank tail.
    let mut b = IrBuilder::new("super_res", Chw::new(3, hw, hw));
    b.conv("head", 3, 32, 1, true);
    for i in 0..3 {
        let pre = b.last();
        b.conv(&format!("wide{i}_a"), 3, 96, 1, true)
            .conv(&format!("wide{i}_b"), 1, 32, 1, false)
            .add(&format!("wide{i}_add"), pre, true);
    }
    b.conv("tail", 3, 12, 1, false); // 2x pixel-shuffle payload (4*3)
    b.build().expect("super_res IR")
}

/// Transformer-encoder text classifier over `[T, D]` token embeddings
/// (the sequence-tier counterpart of the Fig. 5 conv zoo): an input
/// projection, `blocks` post-norm encoder blocks (self-attention +
/// 2-layer feed-forward, both residual), then mean-pool + linear head.
/// Weights are random, as everywhere in the zoo — the serving and
/// compression comparisons are value-independent.
pub fn text_encoder(t: usize, d: usize, heads: usize, blocks: usize,
                    classes: usize) -> ModelIR {
    let mut b = IrBuilder::new(
        &format!("text_encoder_{t}x{d}"),
        Shape::seq(t, d),
    );
    // Input projection, so the first block's residual references a
    // real layer output rather than the model input.
    b.matmul("embed", d, false);
    for i in 0..blocks {
        let skip = b.last();
        b.attention(&format!("blk{i}_attn"), heads)
            .add(&format!("blk{i}_res1"), skip, false)
            .layernorm(&format!("blk{i}_ln1"));
        let skip2 = b.last();
        b.matmul(&format!("blk{i}_ff1"), 2 * d, true)
            .matmul(&format!("blk{i}_ff2"), d, false)
            .add(&format!("blk{i}_res2"), skip2, false)
            .layernorm(&format!("blk{i}_ln2"));
    }
    b.seqpool("pool").dense("cls", classes, false);
    b.build().expect("text_encoder IR")
}

/// Default smoke-sized text classifier served next to the conv zoo
/// (`seq-dense` / `seq-cocogen-quant` deployments).
pub fn tiny_text_encoder() -> ModelIR {
    text_encoder(16, 32, 4, 2, 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_encoder_builds_and_is_sequence_shaped() {
        let m = tiny_text_encoder();
        assert!(m.input.is_seq());
        assert_eq!(m.input.t(), 16);
        assert_eq!(m.input.d(), 32);
        // head output is spatial [classes, 1, 1] so the conv serving
        // path (argmax over c) applies unchanged
        let out = m.layers.last().unwrap().output;
        assert!(!out.is_seq());
        assert_eq!((out.c, out.h, out.w), (4, 1, 1));
        assert!(m.flops() > 0);
        assert!(m.weight_count() > 0);
        // residuals: two per encoder block
        let adds = m
            .layers
            .iter()
            .filter(|l| {
                matches!(l.kind, super::super::LayerKind::Add { .. })
            })
            .count();
        assert_eq!(adds, 4);
    }

    #[test]
    fn text_encoder_scales_with_depth_and_length() {
        let small = text_encoder(16, 32, 4, 1, 4);
        let deep = text_encoder(16, 32, 4, 3, 4);
        let long = text_encoder(64, 32, 4, 1, 4);
        assert!(deep.flops() > small.flops());
        assert!(deep.weight_count() > small.weight_count());
        // sequence length scales FLOPs but not weights
        assert!(long.flops() > small.flops());
        assert_eq!(long.weight_count(), small.weight_count());
    }

    #[test]
    fn fig5_zoo_builds() {
        let models = fig5_models();
        assert_eq!(models.len(), 6);
        for (name, m) in &models {
            assert!(m.flops() > 0, "{name}");
            assert!(!m.conv3x3_layers().is_empty(), "{name}");
        }
    }

    #[test]
    fn vgg_heavier_than_mbnt() {
        let v = vgg16(64, 1000);
        let m = mobilenet_v2(64, 1000);
        assert!(v.flops() > 5 * m.flops());
    }

    #[test]
    fn resnet_has_residuals() {
        let r = resnet50(64, 1000);
        let adds = r
            .layers
            .iter()
            .filter(|l| matches!(l.kind, super::super::LayerKind::Add { .. }))
            .count();
        // one residual add per block except the first of each stack
        assert_eq!(adds, (3 - 1) + (4 - 1) + (6 - 1) + (3 - 1));
    }

    #[test]
    fn app_nets_build() {
        for m in [
            style_transfer_net(128),
            coloring_net(128),
            super_resolution_net(64),
        ] {
            assert!(m.flops() > 0);
        }
    }
}
