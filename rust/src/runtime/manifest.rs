//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the L2→L3 contract: every AOT artifact's input/output
//! signature (names, shapes, dtypes, feed order), the model specs (modules,
//! parameter order, prunable set), the synthetic-dataset parameters, and
//! the pattern set — all read from one JSON document so Python and Rust
//! can never drift apart silently.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// Tensor dtype (only what the artifacts use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// One tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("tensor missing name"))?
                .to_string(),
            shape: j.get("shape").usize_vec(),
            dtype: DType::parse(j.get("dtype").as_str().unwrap_or("f32"))?,
        })
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(ArtifactSpec {
            file: j
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("artifact missing file"))?
                .to_string(),
            inputs: parse_list("inputs")?,
            outputs: parse_list("outputs")?,
        })
    }
    /// Index of the input named `name`.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }
}

/// Model spec mirrored from python/compile/model.py::ModelDef.spec_json().
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub input_shape: Vec<usize>, // (H, W, C)
    pub classes: usize,
    pub params: Vec<TensorSpec>,
    pub masks: Vec<TensorSpec>,
    pub student_params: Vec<String>,
    pub prunable_modules: Vec<String>,
    pub flops: u64,
    pub param_count: u64,
    pub train_batch: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Raw module list (kind-specific fields stay JSON).
    pub modules: Vec<Json>,
}

impl ModelSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let tensors = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        let strings = |key: &str| -> Vec<String> {
            j.get(key)
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_str().map(str::to_string))
                .collect()
        };
        let mut artifacts = BTreeMap::new();
        if let Some(obj) = j.get("artifacts").as_obj() {
            for (k, v) in obj {
                artifacts.insert(k.clone(), ArtifactSpec::from_json(v)?);
            }
        }
        Ok(ModelSpec {
            name: j
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("model missing name"))?
                .to_string(),
            input_shape: j.get("input_shape").usize_vec(),
            classes: j.get("classes").as_usize().unwrap_or(0),
            params: tensors("params")?,
            masks: tensors("masks")?,
            student_params: strings("student_params"),
            prunable_modules: strings("prunable_modules"),
            flops: j.get("flops").as_f64().unwrap_or(0.0) as u64,
            param_count: j.get("param_count").as_f64().unwrap_or(0.0) as u64,
            train_batch: j.get("train_batch").as_usize().unwrap_or(32),
            artifacts,
            modules: j.get("modules").as_arr().unwrap_or(&[]).to_vec(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("model {} has no artifact {name}", self.name))
    }

    pub fn param_shape(&self, name: &str) -> Option<&[usize]> {
        self.params
            .iter()
            .find(|t| t.name == name)
            .map(|t| t.shape.as_slice())
    }

    /// Mask names that belong to a given module.
    pub fn module_masks(&self, module: &str) -> Vec<&TensorSpec> {
        let prefix = format!("{module}.");
        self.masks
            .iter()
            .filter(|t| t.name.starts_with(&prefix))
            .collect()
    }
}

/// Synthetic dataset parameters (mirrors python/compile/data.py).
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: String,
    pub classes: usize,
    pub noise: f64,
    pub freq_base: f64,
    pub angle_jitter: f64,
    pub train: usize,
    pub test: usize,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelSpec>,
    pub micro: BTreeMap<String, ArtifactSpec>,
    pub datasets: BTreeMap<String, DatasetSpec>,
    pub image_size: usize,
    /// Pattern set: 8 patterns x 4 (dy,dx) taps.
    pub pattern_set: Vec<Vec<(usize, usize)>>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let mut models = BTreeMap::new();
        if let Some(obj) = j.get("models").as_obj() {
            for (k, v) in obj {
                models.insert(k.clone(), ModelSpec::from_json(v)?);
            }
        }
        let mut micro = BTreeMap::new();
        if let Some(obj) = j.get("micro").as_obj() {
            for (k, v) in obj {
                micro.insert(k.clone(), ArtifactSpec::from_json(v)?);
            }
        }
        let mut datasets = BTreeMap::new();
        let data = j.get("data");
        let image_size = data.get("size").as_usize().unwrap_or(16);
        if let Some(obj) = data.get("datasets").as_obj() {
            for (k, v) in obj {
                datasets.insert(
                    k.clone(),
                    DatasetSpec {
                        name: k.clone(),
                        classes: v.get("classes").as_usize().unwrap_or(16),
                        noise: v.get("noise").as_f64().unwrap_or(0.1),
                        freq_base: v.get("freq_base").as_f64().unwrap_or(1.5),
                        angle_jitter: v
                            .get("angle_jitter")
                            .as_f64()
                            .unwrap_or(0.1),
                        train: v.get("train").as_usize().unwrap_or(2048),
                        test: v.get("test").as_usize().unwrap_or(512),
                    },
                );
            }
        }
        let pattern_set = j
            .get("pattern_set")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|p| {
                p.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|t| {
                        let v = t.usize_vec();
                        (v.first().copied().unwrap_or(0),
                         v.get(1).copied().unwrap_or(0))
                    })
                    .collect()
            })
            .collect();
        Ok(Manifest {
            models,
            micro,
            datasets,
            image_size,
            pattern_set,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no model {name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest() -> Json {
        json::parse(
            r#"{
          "format": 1,
          "models": {
            "m": {
              "name": "m", "input_shape": [16,16,3], "classes": 16,
              "params": [{"name":"a.w","shape":[3,3,3,8],"dtype":"f32"}],
              "masks": [{"name":"a.w","shape":[3,3,3,8],"dtype":"f32"}],
              "student_params": ["a.w"], "prunable_modules": ["a"],
              "flops": 123, "param_count": 216, "train_batch": 8,
              "modules": [{"name":"a","kind":"stem","cout":8,"prunable":true}],
              "artifacts": {
                "infer_b1": {"file": "m.infer_b1.hlo.txt",
                  "inputs": [{"name":"p:a.w","shape":[3,3,3,8],"dtype":"f32"},
                             {"name":"x","shape":[1,16,16,3],"dtype":"f32"}],
                  "outputs": [{"name":"logits","shape":[1,16],"dtype":"f32"}]}
              }
            }
          },
          "micro": {},
          "data": {"size": 16, "datasets": {"synflowers":
            {"classes":16,"noise":0.1,"freq_base":1.5,"angle_jitter":0.05,
             "train":2048,"test":512}}},
          "pattern_set": [[[0,0],[0,1],[1,1],[1,0]]]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_model_spec() {
        let man = Manifest::from_json(&tiny_manifest()).unwrap();
        let m = man.model("m").unwrap();
        assert_eq!(m.classes, 16);
        assert_eq!(m.params[0].shape, vec![3, 3, 3, 8]);
        assert_eq!(m.params[0].elements(), 216);
        let art = m.artifact("infer_b1").unwrap();
        assert_eq!(art.inputs.len(), 2);
        assert_eq!(art.input_index("x"), Some(1));
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn parses_datasets_and_patterns() {
        let man = Manifest::from_json(&tiny_manifest()).unwrap();
        assert_eq!(man.datasets["synflowers"].classes, 16);
        assert_eq!(man.pattern_set[0][2], (1, 1));
        assert_eq!(man.image_size, 16);
    }

    #[test]
    fn module_masks_by_prefix() {
        let man = Manifest::from_json(&tiny_manifest()).unwrap();
        let m = man.model("m").unwrap();
        assert_eq!(m.module_masks("a").len(), 1);
        assert_eq!(m.module_masks("b").len(), 0);
    }

    #[test]
    fn real_manifest_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if dir.join("manifest.json").exists() {
            let man = Manifest::load(&dir).unwrap();
            assert!(man.models.contains_key("resnet_mini"));
            let rm = &man.models["resnet_mini"];
            assert_eq!(rm.prunable_modules.len(), 6);
            assert!(rm.artifacts.contains_key("train_step"));
            assert_eq!(man.pattern_set.len(), 8);
        }
    }
}
