//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the request path. Python never runs here.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reassigns
//! instruction ids, avoiding the 64-bit-id proto incompatibility between
//! jax >= 0.5 and xla_extension 0.5.1.
//!
//! Offline builds link the vendored `xla` stub (rust/vendor/xla), so
//! everything here compiles everywhere but [`Runtime::new`] returns an
//! error at runtime until the real bindings are swapped in. Callers —
//! the serving coordinator's `PjrtBackend`, the trainer, the PJRT
//! integration tests — already treat that error like a missing artifacts
//! directory: fail over to the native backend, or skip.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{ArtifactSpec, DType, Manifest, ModelSpec, TensorSpec};

/// Host-side tensor: shape + typed data.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 {
            shape: shape.to_vec(),
            data,
        }
    }
    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 {
            shape: shape.to_vec(),
            data,
        }
    }
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 {
            shape: vec![],
            data: vec![v],
        }
    }
    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor::F32 {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }
    pub fn ones(shape: &[usize]) -> Self {
        HostTensor::F32 {
            shape: shape.to_vec(),
            data: vec![1.0; shape.iter().product()],
        }
    }
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => {
                shape
            }
        }
    }
    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("tensor has {} elements, expected scalar", d.len());
        }
        Ok(d[0])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32 { shape, data } => {
                let l = xla::Literal::vec1(data.as_slice());
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                l.reshape(&dims)?
            }
            HostTensor::I32 { shape, data } => {
                let l = xla::Literal::vec1(data.as_slice());
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                l.reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        match spec.dtype {
            DType::F32 => Ok(HostTensor::F32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<f32>()?,
            }),
            DType::I32 => Ok(HostTensor::I32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<i32>()?,
            }),
        }
    }
}

/// A compiled artifact bound to its manifest signature.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Device-resident input set for repeated execution: a prefix of the
/// artifact's inputs (typically params + masks) uploaded once; only the
/// per-call suffix (e.g. the image batch) crosses the host boundary each
/// execution. This is the serving/training hot-path optimization — see
/// EXPERIMENTS.md §Perf.
pub struct DeviceInputs {
    buffers: Vec<xla::PjRtBuffer>,
    prefix_len: usize,
}

impl Executable {
    /// Execute with host tensors; validates the signature, returns outputs
    /// in manifest order.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact {} expects {} inputs, got {}",
                self.spec.file,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "input '{}' shape mismatch: fed {:?}, artifact wants {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let root = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let parts = root.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact {} returned {} outputs, manifest says {}",
                self.spec.file,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }

    /// Upload the first `prefix.len()` inputs to the device once. The
    /// tensors must match the artifact's input prefix (validated).
    pub fn upload_prefix(&self, client: &xla::PjRtClient,
                         prefix: &[HostTensor]) -> Result<DeviceInputs> {
        if prefix.len() > self.spec.inputs.len() {
            bail!("prefix longer than artifact inputs");
        }
        let mut buffers = Vec::with_capacity(prefix.len());
        for (t, spec) in prefix.iter().zip(&self.spec.inputs) {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "prefix input '{}' shape mismatch: {:?} vs {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
            let buf = match t {
                HostTensor::F32 { shape, data } => client
                    .buffer_from_host_buffer::<f32>(data, shape, None)?,
                HostTensor::I32 { shape, data } => client
                    .buffer_from_host_buffer::<i32>(data, shape, None)?,
            };
            buffers.push(buf);
        }
        Ok(DeviceInputs {
            buffers,
            prefix_len: prefix.len(),
        })
    }

    /// Execute with a device-resident prefix + host suffix. Only the
    /// suffix tensors are uploaded on this call.
    pub fn run_with_prefix(&self, client: &xla::PjRtClient,
                           prefix: &DeviceInputs, suffix: &[HostTensor])
                           -> Result<Vec<HostTensor>> {
        let total = prefix.prefix_len + suffix.len();
        if total != self.spec.inputs.len() {
            bail!(
                "artifact {} expects {} inputs, got {} (prefix {} + {})",
                self.spec.file,
                self.spec.inputs.len(),
                total,
                prefix.prefix_len,
                suffix.len()
            );
        }
        let mut suffix_bufs = Vec::with_capacity(suffix.len());
        for (t, spec) in suffix
            .iter()
            .zip(self.spec.inputs[prefix.prefix_len..].iter())
        {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "suffix input '{}' shape mismatch: {:?} vs {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
            let buf = match t {
                HostTensor::F32 { shape, data } => client
                    .buffer_from_host_buffer::<f32>(data, shape, None)?,
                HostTensor::I32 { shape, data } => client
                    .buffer_from_host_buffer::<i32>(data, shape, None)?,
            };
            suffix_bufs.push(buf);
        }
        let all: Vec<&xla::PjRtBuffer> = prefix
            .buffers
            .iter()
            .chain(suffix_bufs.iter())
            .collect();
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&all)?;
        let root = result[0][0].to_literal_sync()?;
        let parts = root.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact {} returned {} outputs, manifest says {}",
                self.spec.file,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }
}

/// The runtime: a PJRT CPU client + artifact cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Create from an artifacts directory (must contain manifest.json).
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts directory: `$COCOPIE_ARTIFACTS` or
    /// `<crate>/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("COCOPIE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Borrow the PJRT client (buffer uploads, prefix execution).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile (cached) a model artifact, e.g. ("resnet_mini",
    /// "train_step").
    pub fn load_model_artifact(
        &self,
        model: &str,
        artifact: &str,
    ) -> Result<Arc<Executable>> {
        let spec = self.manifest.model(model)?.artifact(artifact)?.clone();
        self.compile_cached(&spec)
    }

    /// Load + compile (cached) a micro artifact, e.g. "gemm".
    pub fn load_micro(&self, name: &str) -> Result<Arc<Executable>> {
        let spec = self
            .manifest
            .micro
            .get(name)
            .ok_or_else(|| anyhow!("no micro artifact {name}"))?
            .clone();
        self.compile_cached(&spec)
    }

    fn compile_cached(&self, spec: &ArtifactSpec) -> Result<Arc<Executable>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(&spec.file) {
                return Ok(exe.clone());
            }
        }
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {}", path.display()))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", spec.file))?;
        let exe = Arc::new(Executable {
            spec: spec.clone(),
            exe,
        });
        self.cache
            .lock()
            .unwrap()
            .insert(spec.file.clone(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.as_i32().is_err());
        let s = HostTensor::scalar_f32(4.0);
        assert_eq!(s.scalar().unwrap(), 4.0);
        assert!(t.scalar().is_err());
    }

    #[test]
    #[should_panic]
    fn host_tensor_rejects_bad_len() {
        let _ = HostTensor::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn ones_zeros() {
        let z = HostTensor::zeros(&[4]);
        assert_eq!(z.as_f32().unwrap(), &[0.0; 4]);
        let o = HostTensor::ones(&[2, 2]);
        assert_eq!(o.as_f32().unwrap(), &[1.0; 4]);
    }
}
