//! Hardware energy/latency model for the Fig. 7 comparisons.
//!
//! The paper compares a Samsung Galaxy S10 running CoCo-Gen against ASIC
//! and FPGA accelerators on *energy efficiency* (inferences per joule)
//! and latency. Those comparisons are arithmetic over device operating
//! points. We reproduce the arithmetic with:
//!
//! * accelerator operating points on a VGG-16-class workload, from the
//!   sources the paper cites (TPU [15], Eyeriss [8], ESE [18], vendor
//!   specs for Xavier/MLU-100/edge-TPU);
//! * the S10 + CoCo-Gen point from the paper's own measurement
//!   (18.9 ms VGG CONV on the Adreno 640 => 52.9 inf/s at a ~3 W mobile
//!   GPU envelope);
//! * OUR testbed measurement shown alongside for transparency — this x86
//!   box running the native cocogen executor is NOT a mobile SoC, so it
//!   carries its own power envelope and validates the *mechanism*
//!   (the pruned-vs-dense speedup factor), not the absolute mobile point.
//!   See DESIGN.md §2.

/// A device operating point for a VGG-16-class benchmark network.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Average board/device power, watts.
    pub power_w: f64,
    /// Throughput in inferences per second on the benchmark model.
    pub inf_per_s: f64,
    /// Process node, nm (the paper's technology-maturity argument).
    pub tech_nm: u32,
}

impl DeviceProfile {
    /// Energy efficiency: inferences per joule.
    pub fn inf_per_j(&self) -> f64 {
        self.inf_per_s / self.power_w
    }
    pub fn latency_ms(&self) -> f64 {
        1e3 / self.inf_per_s
    }
}

/// The paper's mobile operating point: VGG CONV layers in 18.9 ms on the
/// S10 (Adreno 640), ~3 W sustained GPU envelope.
pub fn s10_cocogen() -> DeviceProfile {
    DeviceProfile {
        name: "S10 + CoCo-Gen (paper)",
        power_w: 3.0,
        inf_per_s: 1000.0 / 18.9,
        tech_nm: 8,
    }
}

/// Accelerator operating points on a VGG-16-class CNN (batch-1 service
/// throughput; values from the cited sources / vendor specs — the paper's
/// Fig. 7 comparison set).
pub fn accelerator_profiles() -> Vec<DeviceProfile> {
    vec![
        DeviceProfile {
            // Cloud TPU-V2 board serving VGG-scale CNNs; high throughput
            // but a 280 W board envelope.
            name: "TPU-V2 (cloud)",
            power_w: 280.0,
            inf_per_s: 1000.0,
            tech_nm: 16,
        },
        DeviceProfile {
            // Edge TPU is optimized for small int8 models; VGG-16 blows
            // its on-chip memory, throughput collapses (paper §2.1.5:
            // "edge TPU is optimized for small-scale DNNs").
            name: "Edge TPU",
            power_w: 2.0,
            inf_per_s: 6.0,
            tech_nm: 28,
        },
        DeviceProfile {
            name: "Jetson AGX Xavier",
            power_w: 30.0,
            inf_per_s: 103.0,
            tech_nm: 12,
        },
        DeviceProfile {
            name: "Cambricon MLU-100",
            power_w: 75.0,
            inf_per_s: 150.0,
            tech_nm: 16,
        },
        DeviceProfile {
            // Eyeriss: 0.7 fps VGG-16 CONV at 278 mW (ISSCC'16).
            name: "Eyeriss (ASIC)",
            power_w: 0.278,
            inf_per_s: 0.7,
            tech_nm: 65,
        },
        DeviceProfile {
            // ESE (FPGA'17): sparse LSTM engine, 41 W board; the paper
            // compares efficiency on the same-scale workload.
            name: "ESE (FPGA)",
            power_w: 41.0,
            inf_per_s: 120.0,
            tech_nm: 28,
        },
    ]
}

/// Mobile power envelopes (S10-class SoC running a sustained CNN load).
pub const MOBILE_CPU_POWER_W: f64 = 3.5;
pub const MOBILE_GPU_POWER_W: f64 = 3.0;
/// This x86 testbed's package envelope under the bench load.
pub const TESTBED_POWER_W: f64 = 35.0;

/// FLOP-scale a measured latency to a different model size at equal
/// effective FLOP/s.
pub fn flop_scaled_inf_per_s(measured_latency_s: f64, flops_measured: u64,
                             flops_target: u64) -> f64 {
    let scale = flops_target as f64 / flops_measured.max(1) as f64;
    1.0 / (measured_latency_s * scale)
}

/// A Fig. 7 comparison row.
#[derive(Debug, Clone)]
pub struct EfficiencyRow {
    pub device: String,
    pub inf_per_s: f64,
    pub power_w: f64,
    pub inf_per_j: f64,
    pub vs_mobile: f64,
}

/// Build the Fig. 7 table: the S10+CoCo-Gen reference, our testbed point,
/// and the accelerators, all normalized to the S10 point.
pub fn fig7_table(testbed_inf_per_s: f64) -> Vec<EfficiencyRow> {
    let s10 = s10_cocogen();
    let mobile_eff = s10.inf_per_j();
    let mut rows = vec![
        EfficiencyRow {
            device: s10.name.into(),
            inf_per_s: s10.inf_per_s,
            power_w: s10.power_w,
            inf_per_j: mobile_eff,
            vs_mobile: 1.0,
        },
        EfficiencyRow {
            device: "this testbed + CoCo-Gen (measured)".into(),
            inf_per_s: testbed_inf_per_s,
            power_w: TESTBED_POWER_W,
            inf_per_j: testbed_inf_per_s / TESTBED_POWER_W,
            vs_mobile: (testbed_inf_per_s / TESTBED_POWER_W) / mobile_eff,
        },
    ];
    for p in accelerator_profiles() {
        rows.push(EfficiencyRow {
            device: p.name.into(),
            inf_per_s: p.inf_per_s,
            power_w: p.power_w,
            inf_per_j: p.inf_per_j(),
            vs_mobile: p.inf_per_j() / mobile_eff,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_have_positive_operating_points() {
        for p in accelerator_profiles() {
            assert!(p.power_w > 0.0 && p.inf_per_s > 0.0, "{}", p.name);
            assert!(p.inf_per_j() > 0.0);
            assert!(p.latency_ms() > 0.0);
        }
    }

    #[test]
    fn paper_shape_mobile_beats_accelerators() {
        // The paper's headline: S10 + CoCo-Gen outperforms the
        // accelerator set on inferences/joule.
        let s10 = s10_cocogen();
        for p in accelerator_profiles() {
            assert!(
                s10.inf_per_j() > p.inf_per_j(),
                "{} ({:.2} inf/J) beats mobile ({:.2})",
                p.name,
                p.inf_per_j(),
                s10.inf_per_j()
            );
        }
    }

    #[test]
    fn old_process_nodes_lag() {
        let profs = accelerator_profiles();
        let eyeriss = profs.iter().find(|p| p.tech_nm == 65).unwrap();
        let xavier =
            profs.iter().find(|p| p.name.contains("Xavier")).unwrap();
        assert!(eyeriss.inf_per_s < xavier.inf_per_s);
    }

    #[test]
    fn fig7_rows_and_reference() {
        let rows = fig7_table(10.0);
        assert_eq!(rows[0].vs_mobile, 1.0);
        assert_eq!(rows.len(), 8);
        let beaten = rows[2..].iter().filter(|r| r.vs_mobile < 1.0).count();
        assert_eq!(beaten, 6);
    }

    #[test]
    fn flop_scaling() {
        let f = flop_scaled_inf_per_s(0.010, 1_000, 2_000);
        assert!((f - 50.0).abs() < 1e-9);
    }
}
