//! Minimal JSON parser/serializer.
//!
//! The offline registry in this image carries no `serde`/`serde_json`, so
//! the artifact manifest (written by `python/compile/aot.py`) is parsed by
//! this hand-rolled implementation. It supports the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bools, null) — enough
//! for the manifest, configs and experiment reports.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Convenience: `[1,2,3]` -> Vec<usize>.
    pub fn usize_vec(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: src.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }
    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {} (found {:?})",
                        self.i, other
                    ))
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {} (found {:?})",
                        self.i, other
                    ))
                }
            }
        }
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u hex")?;
                            self.i += 4;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // copy a full UTF-8 sequence
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len()
                        && (self.b[self.i] & 0xC0) == 0x80
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }
    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {}", start))
    }
}

// ------------------------------------------------------------------------
// Serialization
// ------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Builder helpers for constructing reports.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").idx(1).as_f64(), Some(2.5));
        assert_eq!(v.get("a").idx(2).as_f64(), Some(-300.0));
        assert_eq!(v.get("b").as_str(), Some("x\ny"));
        assert_eq!(v.get("c").as_bool(), Some(true));
        assert_eq!(*v.get("d"), Json::Null);
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn nested_and_unicode() {
        let src = r#"{"k": {"inner": ["é", {"deep": [[]]}]}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("k").get("inner").idx(0).as_str(), Some("é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("[1] trailing").is_err());
    }

    #[test]
    fn missing_keys_are_null() {
        let v = parse("{}").unwrap();
        assert_eq!(*v.get("nope").get("deeper"), Json::Null);
    }

    #[test]
    fn usize_vec() {
        let v = parse("[3, 3, 16, 32]").unwrap();
        assert_eq!(v.usize_vec(), vec![3, 3, 16, 32]);
    }
}
