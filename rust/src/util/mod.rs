//! Substrate utilities built from scratch for the offline environment:
//! JSON, PRNG, thread pool, stats, bench harness, property-test harness.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
