//! Scoped data-parallel helpers (no `rayon` offline).
//!
//! `parallel_for_chunks` splits an index range across worker threads using
//! `std::thread::scope`; used by the conv executors' batch/filter loops and
//! by the exploration engine's node simulation.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (min(cores, cap)).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Run `f(i)` for every i in 0..n across `threads` workers.
/// Work-stealing via a shared atomic counter in blocks of `grain`.
pub fn parallel_for<F>(n: usize, grain: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let grain = grain.max(1);
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = counter.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + grain).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Split `data` into consecutive `chunk`-sized pieces and process them in
/// parallel: `f(chunk_index, chunk_slice)`. Used by the conv executors to
/// hand each worker its own set of output planes without locking.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk: usize,
                                 threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    let chunk = chunk.max(1);
    let n_chunks = data.len().div_ceil(chunk);
    let threads = threads.max(1).min(n_chunks);
    if threads == 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    // Pre-split into raw parts so each worker claims disjoint chunks.
    let parts: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> = data
        .chunks_mut(chunk)
        .enumerate()
        .map(|(i, c)| std::sync::Mutex::new(Some((i, c))))
        .collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let idx = counter.fetch_add(1, Ordering::Relaxed);
                if idx >= parts.len() {
                    break;
                }
                if let Some((i, c)) = parts[idx].lock().unwrap().take() {
                    f(i, c);
                }
            });
        }
    });
}

/// Map 0..n through `f` in parallel, preserving order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(n, 1, threads, |i| {
            let mut slot = slots[i].lock().unwrap();
            **slot = f(i);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, 7, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_and_empty() {
        parallel_for(0, 1, 4, |_| panic!("must not run"));
        let hits = AtomicU64::new(0);
        parallel_for(10, 1, 1, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn parallel_map_ordered() {
        let v = parallel_map(100, 8, |i| i * i);
        assert_eq!(v[7], 49);
        assert_eq!(v.len(), 100);
    }
}
