//! Small statistics helpers used by benches, metrics and the simulator.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Percentile via linear interpolation on the sorted copy (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice — lets callers that need
/// several percentiles (metrics summaries) sort once instead of once per
/// quantile.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Geometric mean (all inputs must be > 0).
pub fn geo_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn geo() {
        assert!((geo_mean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
