//! Deterministic PRNG (xoshiro256**) + distributions.
//!
//! No `rand` crate offline; everything stochastic in the library (dataset
//! synthesis, subspace sampling, the calibrated simulator, the property
//! harness) goes through this generator so experiments are reproducible
//! from a single seed.

/// xoshiro256** — fast, high-quality, tiny.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        // SplitMix64 expansion of the seed.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample one element.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }

    /// Fork a derived RNG (stable across call sites given the same label).
    pub fn fork(&self, label: u64) -> Rng {
        let mix = self.s[0]
            ^ self.s[3].rotate_left(13)
            ^ label.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::seed_from(mix)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::seed_from(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.below(7);
            assert!(n < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_is_stable_and_distinct() {
        let r = Rng::seed_from(7);
        let mut f1 = r.fork(1);
        let mut f1b = r.fork(1);
        let mut f2 = r.fork(2);
        assert_eq!(f1.next_u64(), f1b.next_u64());
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
