//! Mini property-testing harness (proptest is not in the offline registry).
//!
//! `check(name, cases, |g| { ... })` runs a closure over `cases` randomized
//! inputs drawn through a `Gen`; failures report the case seed so they can
//! be replayed with `Gen::replay(seed)`.

use super::rng::Rng;

/// Randomized-input generator handed to property closures.
pub struct Gen {
    rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn replay(seed: u64) -> Gen {
        Gen {
            rng: Rng::seed_from(seed),
            case_seed: seed,
        }
    }
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.f64() < 0.5
    }
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal_f32()).collect()
    }
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }
    pub fn pick<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        self.rng.choose(v)
    }
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` randomized generations. Panics with the failing
/// case seed on the first failure (property returns Err or panics).
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base = fxhash(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x2545F4914F6CDD1D);
        let mut g = Gen::replay(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed}): {msg}"
            );
        }
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert two f32 slices are elementwise close.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32)
                       -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!(
                "mismatch at {i}: {x} vs {y} (|d|={} tol={tol})",
                (x - y).abs()
            ));
        }
        if x.is_nan() != y.is_nan() {
            return Err(format!("nan mismatch at {i}: {x} vs {y}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_valid_property() {
        check("add-commutes", 50, |g| {
            let a = g.f64(-100.0, 100.0);
            let b = g.f64(-100.0, 100.0);
            if (a + b - (b + a)).abs() < 1e-12 {
                Ok(())
            } else {
                Err("non-commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn check_reports_failures() {
        check("always-fails", 3, |_| Err("boom".into()));
    }

    #[test]
    fn allclose() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6)
            .is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-5, 1e-6).is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-6).is_err());
    }
}
