//! Hand-rolled benchmark harness (criterion is not in the offline registry).
//!
//! Warmup + timed iterations with median/p10/p90 reporting. Every
//! `benches/*.rs` binary uses this; its output lines are the rows of the
//! paper's tables/figures.
//!
//! Also home of the **open-loop** load generator
//! ([`arrival_schedule`] + [`open_loop_drive`]): arrivals follow a
//! fixed-seed Poisson schedule and are fired without waiting for
//! completions, so a coordinator past its capacity sees genuine
//! overload. (A closed-loop driver self-throttles — its offered rate
//! collapses to the service rate, and overload behaviour is never
//! exercised.)

use std::time::{Duration, Instant};

use crate::coordinator::{Client, InferRequest, ServeError, Sla};
use super::rng::Rng;
use super::stats;

/// Result of a timed measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl Measurement {
    pub fn median_ms(&self) -> f64 {
        self.median_s * 1e3
    }
    pub fn median_us(&self) -> f64 {
        self.median_s * 1e6
    }
}

/// Time `f` with automatic iteration count targeting ~`budget_s` seconds
/// of measurement (min 5, max `max_iters` iterations).
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, max_iters: usize,
                         mut f: F) -> Measurement {
    // Warmup + calibration run.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / once) as usize).clamp(3, max_iters.max(3));
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Measurement {
        name: name.to_string(),
        iters,
        median_s: stats::median(&samples),
        mean_s: stats::mean(&samples),
        p10_s: stats::percentile(&samples, 10.0),
        p90_s: stats::percentile(&samples, 90.0),
    }
}

/// Fast-path bench for cheap closures: fixed iteration count.
pub fn bench_n<F: FnMut()>(name: &str, iters: usize, mut f: F) -> Measurement {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Measurement {
        name: name.to_string(),
        iters,
        median_s: stats::median(&samples),
        mean_s: stats::mean(&samples),
        p10_s: stats::percentile(&samples, 10.0),
        p90_s: stats::percentile(&samples, 90.0),
    }
}

/// Pretty table printer for bench rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }
    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }
    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let w = widths.get(i).copied().unwrap_or(c.len());
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// One SLA class's share of an open-loop run.
#[derive(Debug, Clone)]
pub struct ClassStats {
    pub sla: Sla,
    /// Requests the schedule offered in this class.
    pub offered: usize,
    pub completed: usize,
    /// Requests turned away with [`ServeError::Overloaded`] (either at
    /// submission or typed on the reply channel).
    pub shed: usize,
    /// p50 end-to-end latency over *completed* requests, ms.
    pub p50_ms: f64,
    /// p99 end-to-end latency over *completed* requests, ms.
    pub p99_ms: f64,
}

/// Outcome of one [`open_loop_drive`] run. Every offered request is
/// accounted for exactly once: `completed + shed + failed + hung ==
/// offered`. `hung` (a reply channel that neither answered nor closed
/// within the drain budget) is always a bug in the serving path — the
/// overload suite asserts it is zero.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    pub offered: usize,
    pub completed: usize,
    pub shed: usize,
    /// Typed non-shed errors (`Exhausted`, `Stopped`, ...).
    pub failed: usize,
    pub hung: usize,
    /// Wall time from the first arrival to the end of the drain.
    pub elapsed_s: f64,
    /// Per-SLA breakdown, `[Realtime, Standard, Quality]` order.
    pub classes: Vec<ClassStats>,
}

impl OpenLoopReport {
    /// Completed requests per second of wall time — the survival
    /// metric under overload (offered rate is meaningless once the
    /// coordinator sheds).
    pub fn goodput_rps(&self) -> f64 {
        self.completed as f64 / self.elapsed_s.max(1e-9)
    }

    pub fn class(&self, sla: Sla) -> &ClassStats {
        self.classes.iter().find(|c| c.sla == sla).unwrap()
    }
}

fn class_index(sla: Sla) -> usize {
    match sla {
        Sla::Realtime => 0,
        Sla::Standard => 1,
        Sla::Quality => 2,
    }
}

/// Deterministic open-loop arrival schedule: `n` offsets (from the
/// run's start) of a Poisson process at `rate_hz`, i.e. exponential
/// inter-arrival gaps, fixed entirely by `seed`. The same seed always
/// yields the same schedule, so overload tests replay bit-identical
/// arrival patterns.
pub fn arrival_schedule(rate_hz: f64, n: usize, seed: u64)
                       -> Vec<Duration> {
    assert!(rate_hz > 0.0, "arrival rate must be positive");
    let mut rng = Rng::seed_from(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            // Inverse-CDF exponential; 1-u in (0,1] keeps ln() finite.
            t += -(1.0 - rng.f64()).ln() / rate_hz;
            Duration::from_secs_f64(t)
        })
        .collect()
}

/// Drive `client` open-loop: fire one request per schedule entry at
/// its offset — *without* waiting for earlier completions — then drain
/// every reply channel under one shared `drain_timeout` deadline.
/// `sla_of(i)` assigns the i-th arrival's SLA class; every request
/// carries a `vec![0.5; image_elems]` image and leaves deployment
/// choice to the router.
pub fn open_loop_drive<F>(client: &Client, image_elems: usize,
                          schedule: &[Duration], sla_of: F,
                          drain_timeout: Duration) -> OpenLoopReport
where
    F: Fn(usize) -> Sla,
{
    let mut offered = [0usize; 3];
    let mut sheds = [0usize; 3];
    let mut failed = 0usize;
    let mut inflight = Vec::with_capacity(schedule.len());
    let t0 = Instant::now();
    for (i, off) in schedule.iter().enumerate() {
        let elapsed = t0.elapsed();
        if *off > elapsed {
            std::thread::sleep(*off - elapsed);
        }
        let sla = sla_of(i);
        offered[class_index(sla)] += 1;
        match client.infer(InferRequest {
            image: vec![0.5; image_elems],
            sla,
            deployment: None,
        }) {
            Ok(rx) => inflight.push((sla, rx)),
            Err(ServeError::Overloaded { .. }) => {
                sheds[class_index(sla)] += 1;
            }
            Err(_) => failed += 1,
        }
    }
    // Drain under one shared deadline: a healthy coordinator answers
    // every channel (prediction or typed error) long before it, so
    // `hung` only counts genuinely lost replies.
    let deadline = Instant::now() + drain_timeout;
    let mut lat: [Vec<f64>; 3] =
        [Vec::new(), Vec::new(), Vec::new()];
    let mut hung = 0usize;
    for (sla, rx) in inflight {
        let left = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(left) {
            Ok(Ok(pred)) => {
                lat[class_index(sla)].push(pred.latency_ms);
            }
            Ok(Err(ServeError::Overloaded { .. })) => {
                sheds[class_index(sla)] += 1;
            }
            Ok(Err(_)) => failed += 1,
            Err(_) => hung += 1,
        }
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    let classes = [Sla::Realtime, Sla::Standard, Sla::Quality]
        .into_iter()
        .map(|sla| {
            let k = class_index(sla);
            ClassStats {
                sla,
                offered: offered[k],
                completed: lat[k].len(),
                shed: sheds[k],
                p50_ms: stats::percentile(&lat[k], 50.0),
                p99_ms: stats::percentile(&lat[k], 99.0),
            }
        })
        .collect::<Vec<_>>();
    OpenLoopReport {
        offered: offered.iter().sum(),
        completed: lat.iter().map(Vec::len).sum(),
        shed: sheds.iter().sum(),
        failed,
        hung,
        elapsed_s,
        classes,
    }
}

/// Format helper: `12.3ms` / `45.6us`.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.2}s", s)
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench_n("noop-ish", 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(m.iters, 10);
        assert!(m.median_s >= 0.0);
        assert!(m.p90_s >= m.p10_s);
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_time(2.0), "2.00s");
        assert_eq!(fmt_time(0.0021), "2.10ms");
        assert_eq!(fmt_time(12e-6), "12.0us");
    }

    #[test]
    fn arrival_schedule_is_deterministic_and_monotone() {
        let a = arrival_schedule(500.0, 256, 7);
        let b = arrival_schedule(500.0, 256, 7);
        assert_eq!(a, b, "same seed must replay the same schedule");
        assert!(a.windows(2).all(|w| w[0] <= w[1]),
                "offsets must be non-decreasing");
        let c = arrival_schedule(500.0, 256, 8);
        assert_ne!(a, c, "a different seed must move the arrivals");
    }

    #[test]
    fn arrival_schedule_is_pinned_bit_for_bit() {
        // Regression pin, not just self-consistency: seed 7 at 250 Hz
        // must reproduce these exact nanosecond offsets on every host
        // and every run. If this test breaks, the RNG, the inverse-CDF
        // transform, or the Duration conversion changed — all of which
        // silently invalidate replayed overload experiments.
        let got: Vec<u128> = arrival_schedule(250.0, 6, 7)
            .iter()
            .map(Duration::as_nanos)
            .collect();
        assert_eq!(got, vec![
            1_921_964u128,
            4_460_443,
            14_882_864,
            16_905_768,
            20_020_317,
            23_577_939,
        ]);
    }

    #[test]
    fn arrival_schedule_tracks_the_offered_rate() {
        // 2000 arrivals at 1 kHz span ~2 s; the exponential gaps
        // average 1/rate, so the makespan concentrates tightly.
        let s = arrival_schedule(1000.0, 2000, 42);
        let span = s.last().unwrap().as_secs_f64();
        assert!((1.7..2.3).contains(&span),
                "2000 arrivals at 1kHz spanned {span:.3}s");
    }
}
