//! Hand-rolled benchmark harness (criterion is not in the offline registry).
//!
//! Warmup + timed iterations with median/p10/p90 reporting. Every
//! `benches/*.rs` binary uses this; its output lines are the rows of the
//! paper's tables/figures.

use std::time::Instant;

use super::stats;

/// Result of a timed measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl Measurement {
    pub fn median_ms(&self) -> f64 {
        self.median_s * 1e3
    }
    pub fn median_us(&self) -> f64 {
        self.median_s * 1e6
    }
}

/// Time `f` with automatic iteration count targeting ~`budget_s` seconds
/// of measurement (min 5, max `max_iters` iterations).
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, max_iters: usize,
                         mut f: F) -> Measurement {
    // Warmup + calibration run.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / once) as usize).clamp(3, max_iters.max(3));
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Measurement {
        name: name.to_string(),
        iters,
        median_s: stats::median(&samples),
        mean_s: stats::mean(&samples),
        p10_s: stats::percentile(&samples, 10.0),
        p90_s: stats::percentile(&samples, 90.0),
    }
}

/// Fast-path bench for cheap closures: fixed iteration count.
pub fn bench_n<F: FnMut()>(name: &str, iters: usize, mut f: F) -> Measurement {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Measurement {
        name: name.to_string(),
        iters,
        median_s: stats::median(&samples),
        mean_s: stats::mean(&samples),
        p10_s: stats::percentile(&samples, 10.0),
        p90_s: stats::percentile(&samples, 90.0),
    }
}

/// Pretty table printer for bench rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }
    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }
    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let w = widths.get(i).copied().unwrap_or(c.len());
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        println!("{sep}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Format helper: `12.3ms` / `45.6us`.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.2}s", s)
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench_n("noop-ish", 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(m.iters, 10);
        assert!(m.median_s >= 0.0);
        assert!(m.p90_s >= m.p10_s);
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_time(2.0), "2.00s");
        assert_eq!(fmt_time(0.0021), "2.10ms");
        assert_eq!(fmt_time(12e-6), "12.0us");
    }
}
