//! Compressed weight storage (paper §2.1.3).
//!
//! * `FkwLayer` — the paper's compact pattern format ("filter-kernel-
//!   weight"): per surviving kernel a (cin, pattern-id) pair plus exactly
//!   K=4 weights; filters are physically reordered by the codegen pass.
//!   Yields much better compression than CSR because tap positions are a
//!   1-byte pattern id instead of per-weight indices.
//! * `CsrLayer` — conventional compressed-sparse-row over the flattened
//!   `[cout][cin*kh*kw]` matrix; the baseline the paper compares against
//!   (and what non-structured pruning must use).
//! * `DenseLayer` — OIHW dense weights for the naive/im2col/xla engines.
//! * `ProjStore` / `AttnWeights` — the sequence tier's projection
//!   matrices (`[d_out, d_in]`): one enum spanning dense f32,
//!   unstructured-pruned CSR, and weight-only int8, so MatMul and
//!   attention layers ride the same compression menu as convs.

use std::sync::Arc;

use crate::patterns::connectivity::ConnectivityMask;
use crate::patterns::{self, PatternId, PATTERN_SET_4};
use crate::quant::QuantDense;

/// Dense conv weights, OIHW layout: `w[co][ci][ky][kx]`.
#[derive(Debug, Clone)]
pub struct DenseLayer {
    pub cout: usize,
    pub cin: usize,
    pub kh: usize,
    pub kw: usize,
    pub weights: Vec<f32>,
    pub bias: Vec<f32>,
}

impl DenseLayer {
    pub fn at(&self, co: usize, ci: usize, ky: usize, kx: usize) -> f32 {
        self.weights
            [((co * self.cin + ci) * self.kh + ky) * self.kw + kx]
    }
    pub fn size_bytes(&self) -> usize {
        self.weights.len() * 4 + self.bias.len() * 4
    }
}

/// Flat f32 weights + bias — the shape depthwise-conv and FC layers
/// share (`w[c][ky][kx]` resp. `w[cout][cin_flat]`), so plan accounting
/// (`LayerPlan::weight_bytes`) has one code path for both.
#[derive(Debug, Clone)]
pub struct FlatWeights {
    pub weights: Vec<f32>,
    pub bias: Vec<f32>,
}

impl FlatWeights {
    pub fn new(weights: Vec<f32>, bias: Vec<f32>) -> FlatWeights {
        FlatWeights { weights, bias }
    }

    pub fn size_bytes(&self) -> usize {
        (self.weights.len() + self.bias.len()) * 4
    }

    /// View a `[d_out, d_in]` projection as a 1x1 `DenseLayer`, the
    /// shape the pruning and quantization passes operate on (a per-token
    /// projection IS a 1x1 conv over a `[d_in, T, 1]` activation).
    pub fn to_proj_dense(&self, d_in: usize) -> DenseLayer {
        assert_eq!(self.weights.len() % d_in, 0,
                   "projection width does not divide the weight count");
        DenseLayer {
            cout: self.weights.len() / d_in,
            cin: d_in,
            kh: 1,
            kw: 1,
            weights: self.weights.clone(),
            bias: self.bias.clone(),
        }
    }
}

/// Weight store behind one sequence projection (`LayerKind::MatMul`, or
/// one of an attention layer's Q/K/V/output projections): dense f32,
/// unstructured-pruned CSR, or weight-only per-channel int8 — the conv
/// tier's compression menu carried over to `[d_out, d_in]` matrices.
/// (Pattern/FKW pruning is 3x3-kernel-specific and does not apply.)
/// Payloads are `Arc`-shared so plans and compiled pipelines bind them
/// without copying, same as the conv stores.
#[derive(Debug, Clone)]
pub enum ProjStore {
    Dense(Arc<FlatWeights>),
    /// CSR rows over `[d_out][d_in]` (a 1x1 [`CsrLayer`]).
    Csr(Arc<CsrLayer>),
    /// Per-output-channel symmetric int8 (a 1x1 [`QuantDense`]).
    Int8(Arc<QuantDense>),
}

impl ProjStore {
    /// Output width of the projection.
    pub fn d_out(&self) -> usize {
        match self {
            ProjStore::Dense(w) => w.bias.len(),
            ProjStore::Csr(c) => c.cout,
            ProjStore::Int8(q) => q.cout,
        }
    }

    /// Resident weight bytes of this store.
    pub fn size_bytes(&self) -> usize {
        match self {
            ProjStore::Dense(w) => w.size_bytes(),
            ProjStore::Csr(c) => c.size_bytes(),
            ProjStore::Int8(q) => q.size_bytes(),
        }
    }

    /// (surviving, dense) weight counts for pruned stores, `None` when
    /// every weight is resident — mirrors `LayerPlan::conv_nnz`.
    pub fn nnz(&self) -> Option<(usize, usize)> {
        match self {
            ProjStore::Csr(c) => {
                Some((c.nnz(), c.cout * c.cin * c.kh * c.kw))
            }
            _ => None,
        }
    }
}

/// The four projections of one self-attention layer (fused QKV + output),
/// each independently compressible.
#[derive(Debug, Clone)]
pub struct AttnWeights {
    pub q: ProjStore,
    pub k: ProjStore,
    pub v: ProjStore,
    pub o: ProjStore,
}

impl AttnWeights {
    pub fn stores(&self) -> [&ProjStore; 4] {
        [&self.q, &self.k, &self.v, &self.o]
    }

    pub fn size_bytes(&self) -> usize {
        self.stores().iter().map(|s| s.size_bytes()).sum()
    }

    /// Aggregate (surviving, dense) weight counts across the four
    /// projections; dense/int8 stores count fully resident.
    pub fn nnz(&self) -> Option<(usize, usize)> {
        if self.stores().iter().all(|s| s.nnz().is_none()) {
            return None;
        }
        let mut kept = 0;
        let mut total = 0;
        for s in self.stores() {
            match s.nnz() {
                Some((k, t)) => {
                    kept += k;
                    total += t;
                }
                None => {
                    let full = match s {
                        ProjStore::Dense(w) => w.weights.len(),
                        ProjStore::Int8(q) => q.weights.len(),
                        ProjStore::Csr(_) => unreachable!(),
                    };
                    kept += full;
                    total += full;
                }
            }
        }
        Some((kept, total))
    }
}

/// CSR over the flattened `[cout][cin*kh*kw]` weight matrix.
#[derive(Debug, Clone)]
pub struct CsrLayer {
    pub cout: usize,
    pub cin: usize,
    pub kh: usize,
    pub kw: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>, // ci*kh*kw + ky*kw + kx
    pub values: Vec<f32>,
    pub bias: Vec<f32>,
}

impl CsrLayer {
    /// Build from a dense layer, dropping zeros (or entries killed by an
    /// explicit element mask of the same OIHW layout).
    pub fn from_dense(d: &DenseLayer, mask: Option<&[bool]>) -> CsrLayer {
        let cols = d.cin * d.kh * d.kw;
        let mut row_ptr = Vec::with_capacity(d.cout + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for co in 0..d.cout {
            for ci in 0..d.cin {
                for ky in 0..d.kh {
                    for kx in 0..d.kw {
                        let oi = ((co * d.cin + ci) * d.kh + ky) * d.kw + kx;
                        let keep = mask.map(|m| m[oi]).unwrap_or(true)
                            && d.weights[oi] != 0.0;
                        if keep {
                            col_idx.push(
                                (ci * d.kh * d.kw + ky * d.kw + kx) as u32,
                            );
                            values.push(d.weights[oi]);
                        }
                    }
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        let _ = cols;
        CsrLayer {
            cout: d.cout,
            cin: d.cin,
            kh: d.kh,
            kw: d.kw,
            row_ptr,
            col_idx,
            values,
            bias: d.bias.clone(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.row_ptr.len() * 4
            + self.col_idx.len() * 4
            + self.values.len() * 4
            + self.bias.len() * 4
    }

    /// Expand back to dense OIHW (for round-trip tests).
    pub fn to_dense(&self) -> DenseLayer {
        let mut weights = vec![0f32; self.cout * self.cin * self.kh * self.kw];
        for co in 0..self.cout {
            for e in self.row_ptr[co] as usize..self.row_ptr[co + 1] as usize {
                let col = self.col_idx[e] as usize;
                let ci = col / (self.kh * self.kw);
                let rem = col % (self.kh * self.kw);
                let oi = ((co * self.cin + ci) * self.kh) * self.kw
                    + rem;
                weights[oi] = self.values[e];
            }
        }
        DenseLayer {
            cout: self.cout,
            cin: self.cin,
            kh: self.kh,
            kw: self.kw,
            weights,
            bias: self.bias.clone(),
        }
    }
}

/// One surviving kernel in FKW form.
#[derive(Debug, Clone, Copy)]
pub struct FkwKernel {
    pub ci: u16,
    pub pattern: PatternId,
}

/// The paper's compact pattern-format layer (3x3 kernels, K=4 patterns).
#[derive(Debug, Clone)]
pub struct FkwLayer {
    pub cout: usize,
    pub cin: usize,
    /// Physical filter order (after filter-kernel reorder); maps physical
    /// position -> original output-channel index.
    pub filter_order: Vec<u32>,
    /// Per physical filter: `[offsets[f], offsets[f+1])` indexes
    /// kernels/weights.
    pub offsets: Vec<u32>,
    /// Per surviving kernel: input channel + pattern id (sorted by pattern
    /// within each filter — the "kernel reorder" half of the pass).
    pub kernels: Vec<FkwKernel>,
    /// 4 weights per kernel (pattern tap order).
    pub weights: Vec<f32>,
    pub bias: Vec<f32>,
}

impl FkwLayer {
    /// Build from dense weights + a connectivity mask, assigning each
    /// surviving kernel its best pattern and projecting onto it.
    /// Filters keep their original order here; codegen::reorder permutes.
    pub fn from_dense(d: &DenseLayer, conn: &ConnectivityMask) -> FkwLayer {
        assert_eq!(d.kh, 3);
        assert_eq!(d.kw, 3);
        assert_eq!(conn.cin, d.cin);
        assert_eq!(conn.cout, d.cout);
        let mut offsets = vec![0u32];
        let mut kernels = Vec::new();
        let mut weights = Vec::new();
        for co in 0..d.cout {
            for ci in 0..d.cin {
                if !conn.is_alive(ci, co) {
                    continue;
                }
                let mut k = [0f32; 9];
                for ky in 0..3 {
                    for kx in 0..3 {
                        k[ky * 3 + kx] = d.at(co, ci, ky, kx);
                    }
                }
                let pid = patterns::assign_pattern(&k);
                kernels.push(FkwKernel {
                    ci: ci as u16,
                    pattern: pid,
                });
                for &(dy, dx) in &PATTERN_SET_4[pid as usize] {
                    weights.push(k[dy * 3 + dx]);
                }
            }
            offsets.push(kernels.len() as u32);
        }
        FkwLayer {
            cout: d.cout,
            cin: d.cin,
            filter_order: (0..d.cout as u32).collect(),
            offsets,
            kernels,
            weights,
            bias: d.bias.clone(),
        }
    }

    pub fn kernel_count(&self) -> usize {
        self.kernels.len()
    }

    /// Surviving weight count (4 per kernel).
    pub fn nnz(&self) -> usize {
        self.weights.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.filter_order.len() * 4
            + self.offsets.len() * 4
            + self.kernels.len() * 3 // u16 ci + u8 pattern
            + self.weights.len() * 4
            + self.bias.len() * 4
    }

    /// Expand to dense OIHW (original filter order) for correctness tests.
    pub fn to_dense(&self) -> DenseLayer {
        let mut weights = vec![0f32; self.cout * self.cin * 9];
        for (phys, &co) in self.filter_order.iter().enumerate() {
            let co = co as usize;
            for e in self.offsets[phys] as usize
                ..self.offsets[phys + 1] as usize
            {
                let kern = self.kernels[e];
                let taps = &PATTERN_SET_4[kern.pattern as usize];
                for (t, &(dy, dx)) in taps.iter().enumerate() {
                    let oi = ((co * self.cin + kern.ci as usize) * 3 + dy)
                        * 3
                        + dx;
                    weights[oi] = self.weights[e * 4 + t];
                }
            }
        }
        DenseLayer {
            cout: self.cout,
            cin: self.cin,
            kh: 3,
            kw: 3,
            weights,
            bias: self.bias.clone(),
        }
    }
}

/// Compression-rate report for one layer (paper's storage comparison).
#[derive(Debug, Clone)]
pub struct CompressionReport {
    pub dense_bytes: usize,
    pub csr_bytes: usize,
    pub fkw_bytes: usize,
    pub nnz: usize,
    pub total: usize,
}

impl CompressionReport {
    pub fn build(d: &DenseLayer, fkw: &FkwLayer) -> CompressionReport {
        // CSR of the *same* pruned weights (expand fkw, re-sparsify).
        let pruned = fkw.to_dense();
        let csr = CsrLayer::from_dense(&pruned, None);
        CompressionReport {
            dense_bytes: d.size_bytes(),
            csr_bytes: csr.size_bytes(),
            fkw_bytes: fkw.size_bytes(),
            nnz: fkw.nnz(),
            total: d.weights.len(),
        }
    }
    pub fn fkw_vs_csr(&self) -> f64 {
        self.csr_bytes as f64 / self.fkw_bytes as f64
    }
    pub fn fkw_vs_dense(&self) -> f64 {
        self.dense_bytes as f64 / self.fkw_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterns::connectivity::prune_connectivity;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_dense(rng: &mut Rng, cout: usize, cin: usize) -> DenseLayer {
        DenseLayer {
            cout,
            cin,
            kh: 3,
            kw: 3,
            weights: (0..cout * cin * 9).map(|_| rng.normal_f32()).collect(),
            bias: (0..cout).map(|_| rng.normal_f32()).collect(),
        }
    }

    /// HWIO view of an OIHW dense layer (for the pruning helpers).
    fn to_hwio(d: &DenseLayer) -> Vec<f32> {
        let mut out = vec![0f32; d.weights.len()];
        for co in 0..d.cout {
            for ci in 0..d.cin {
                for ky in 0..d.kh {
                    for kx in 0..d.kw {
                        out[((ky * d.kw + kx) * d.cin + ci) * d.cout + co] =
                            d.at(co, ci, ky, kx);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn csr_round_trip() {
        prop::check("csr-round-trip", 30, |g| {
            let cout = g.usize(1, 6);
            let cin = g.usize(1, 6);
            let mut rng = g.rng().clone();
            let mut d = random_dense(&mut rng, cout, cin);
            // sparsify ~60%
            for w in d.weights.iter_mut() {
                if rng.f64() < 0.6 {
                    *w = 0.0;
                }
            }
            let csr = CsrLayer::from_dense(&d, None);
            let back = csr.to_dense();
            prop::assert_allclose(&d.weights, &back.weights, 0.0, 0.0)
        });
    }

    #[test]
    fn fkw_round_trip_is_pattern_projection() {
        prop::check("fkw-round-trip", 30, |g| {
            let cout = g.usize(1, 6);
            let cin = g.usize(1, 6);
            let mut rng = g.rng().clone();
            let d = random_dense(&mut rng, cout, cin);
            let conn = ConnectivityMask::all_alive(cin, cout);
            let fkw = FkwLayer::from_dense(&d, &conn);
            if fkw.kernel_count() != cin * cout {
                return Err("kernel count".into());
            }
            let back = fkw.to_dense();
            // Every kernel of `back` must equal the pattern projection of
            // the original kernel.
            for co in 0..cout {
                for ci in 0..cin {
                    let mut k = [0f32; 9];
                    for ky in 0..3 {
                        for kx in 0..3 {
                            k[ky * 3 + kx] = d.at(co, ci, ky, kx);
                        }
                    }
                    let (proj, _) = crate::patterns::project_kernel(&k);
                    for ky in 0..3 {
                        for kx in 0..3 {
                            let want = proj[ky * 3 + kx];
                            let got = back.at(co, ci, ky, kx);
                            if (want - got).abs() > 0.0 {
                                return Err(format!(
                                    "kernel ({ci},{co}) tap ({ky},{kx})"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fkw_respects_connectivity() {
        let mut rng = Rng::seed_from(5);
        let d = random_dense(&mut rng, 8, 8);
        let hwio = to_hwio(&d);
        let conn = prune_connectivity(&hwio, 3, 3, 8, 8, 0.4);
        let fkw = FkwLayer::from_dense(&d, &conn);
        assert_eq!(fkw.kernel_count(), conn.alive_count());
        let back = fkw.to_dense();
        for co in 0..8 {
            for ci in 0..8 {
                if !conn.is_alive(ci, co) {
                    for ky in 0..3 {
                        for kx in 0..3 {
                            assert_eq!(back.at(co, ci, ky, kx), 0.0);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn fkw_beats_csr_storage() {
        let mut rng = Rng::seed_from(9);
        let d = random_dense(&mut rng, 32, 32);
        let conn = ConnectivityMask::all_alive(32, 32);
        let fkw = FkwLayer::from_dense(&d, &conn);
        let report = CompressionReport::build(&d, &fkw);
        // CSR stores a 4-byte index per weight; FKW stores 3 bytes per
        // 4-weight kernel -> must win clearly.
        assert!(
            report.fkw_vs_csr() > 1.3,
            "fkw {} vs csr {}",
            report.fkw_bytes,
            report.csr_bytes
        );
        // 4/9 pattern keep ratio -> roughly 2x smaller than dense.
        assert!(report.fkw_vs_dense() > 1.7);
    }
}
