//! The CoCo-Gen executor: pattern + connectivity pruned convolution with
//! filter-kernel reorder, register-level load redundancy elimination and
//! tuned tiling (paper §2.1.3). This is the hot path the performance pass
//! optimizes — see EXPERIMENTS.md §Perf.
//!
//! Execution structure (mirrors the generated mobile code):
//!   parallel over reordered filter blocks (co_block)      `[TLP]`
//!     per filter: walk its kernels (sorted by pattern)    `[low divergence]`
//!       per pattern tap (static 4-entry unroll)           `[ILP]`
//!         row AXPY over the output row                    `[SIMD]`
//! The input row needed by a tap is loaded once per (kernel, tap) and
//! streamed through a contiguous AXPY; with the row tile sized by the
//! tuner the touched input rows stay in L1 across the four taps — the
//! register/L1-level load redundancy elimination of the paper.
//!
//! Every kernel here is generic over the weight store via [`FkwView`]:
//! f32 weights ([`FkwLayer`]) or weight-only int8 ([`QuantFkw`]), where
//! the 4 tap weights of a kernel are dequantized in-register on load
//! (one scale multiply per tap) — no f32 weight materialization, no
//! per-call allocation. `conv2d_quant*` are the int8 entry points.

use crate::codegen::TileConfig;
use crate::compress::{FkwKernel, FkwLayer};
use crate::exec::tensor::{fill_shifted_row, same_pad, BatchView, Tensor,
                          TensorView};
use crate::patterns::{Tap, PATTERN_SET_4};
use crate::quant::QuantFkw;

/// Borrowed structural view of a pattern-compact layer, generic over the
/// weight store (f32 or dequant-on-load int8). The executors run one
/// code path for both; the only difference is how a kernel's 4 tap
/// weights materialize into registers.
#[derive(Clone, Copy)]
pub struct FkwView<'a> {
    cout: usize,
    cin: usize,
    filter_order: &'a [u32],
    offsets: &'a [u32],
    kernels: &'a [FkwKernel],
    bias: &'a [f32],
    weights: FkwWeights<'a>,
}

#[derive(Clone, Copy)]
enum FkwWeights<'a> {
    F32(&'a [f32]),
    /// Int8 weights + per-original-output-channel scales.
    I8 { q: &'a [i8], scales: &'a [f32] },
}

impl<'a> FkwView<'a> {
    pub fn from_f32(l: &'a FkwLayer) -> FkwView<'a> {
        FkwView {
            cout: l.cout,
            cin: l.cin,
            filter_order: &l.filter_order,
            offsets: &l.offsets,
            kernels: &l.kernels,
            bias: &l.bias,
            weights: FkwWeights::F32(&l.weights),
        }
    }

    pub fn from_quant(l: &'a QuantFkw) -> FkwView<'a> {
        FkwView {
            cout: l.cout,
            cin: l.cin,
            filter_order: &l.filter_order,
            offsets: &l.offsets,
            kernels: &l.kernels,
            bias: &l.bias,
            weights: FkwWeights::I8 {
                q: &l.weights_q,
                scales: &l.scales,
            },
        }
    }

    /// The 4 tap weights of kernel entry `e` (whose filter's original
    /// output channel is `co`), dequantized in-register for the int8
    /// store — a stack array, never a heap allocation.
    #[inline]
    fn wts(&self, e: usize, co: usize) -> [f32; 4] {
        // Twin of the verifier's FKW structure proof
        // (codegen::verify): offsets end at the kernel count and
        // weights carry 4 entries per kernel, so `e * 4 + 3` stays
        // in bounds; `co` comes from `filter_order`, a verified
        // permutation of `0..cout == scales.len()`.
        debug_assert!(e < self.kernels.len() && co < self.cout,
                      "kernel entry outside the verified structure");
        match self.weights {
            FkwWeights::F32(w) => {
                [w[e * 4], w[e * 4 + 1], w[e * 4 + 2], w[e * 4 + 3]]
            }
            FkwWeights::I8 { q, scales } => {
                let s = scales[co];
                [
                    q[e * 4] as f32 * s,
                    q[e * 4 + 1] as f32 * s,
                    q[e * 4 + 2] as f32 * s,
                    q[e * 4 + 3] as f32 * s,
                ]
            }
        }
    }
}

/// Pattern-sparse conv2d from an FKW layer (3x3 kernels), SAME padding.
///
/// Workers claim *physical* filter groups (the reordered execution order:
/// similar filters together -> uniform task cost under the work-stealing
/// scheduler) but write into the *original* output channel positions, so
/// downstream layers see unpermuted channels.
pub fn conv2d(input: &Tensor, layer: &FkwLayer, stride: usize, relu: bool,
              threads: usize, tile: TileConfig) -> Tensor {
    alloc_out(input, layer.cout, stride, |view, out| {
        conv2d_view_into(view, &FkwView::from_f32(layer), stride, relu,
                         threads, tile, out);
    })
}

/// [`conv2d`] over weight-only int8 weights (dequant-on-load).
pub fn conv2d_quant(input: &Tensor, layer: &QuantFkw, stride: usize,
                    relu: bool, threads: usize, tile: TileConfig)
                    -> Tensor {
    alloc_out(input, layer.cout, stride, |view, out| {
        conv2d_view_into(view, &FkwView::from_quant(layer), stride, relu,
                         threads, tile, out);
    })
}

/// [`conv2d`] writing into a preassigned output buffer (arena slot).
pub fn conv2d_into(input: TensorView<'_>, layer: &FkwLayer, stride: usize,
                   relu: bool, threads: usize, tile: TileConfig,
                   out: &mut [f32]) {
    conv2d_view_into(input, &FkwView::from_f32(layer), stride, relu,
                     threads, tile, out);
}

/// [`conv2d_quant`] writing into a preassigned output buffer.
pub fn conv2d_quant_into(input: TensorView<'_>, layer: &QuantFkw,
                         stride: usize, relu: bool, threads: usize,
                         tile: TileConfig, out: &mut [f32]) {
    conv2d_view_into(input, &FkwView::from_quant(layer), stride, relu,
                     threads, tile, out);
}

/// Fused batched pattern conv (row-AXPY path): the compressed weight
/// stream — kernel list, pattern taps, tap weights — is decoded once per
/// (row-tile, kernel) and applied to every image in the batch, so at
/// batch `n` the weight traffic is 1/n of running the images one by one.
/// Output layout `[n][cout][hw]`; bit-identical per image to
/// [`conv2d_into`] on that image alone.
pub fn conv2d_batch_into(input: BatchView<'_>, layer: &FkwLayer,
                         stride: usize, relu: bool, threads: usize,
                         tile: TileConfig, out: &mut [f32]) {
    conv2d_view_batch_into(input, &FkwView::from_f32(layer), stride, relu,
                           threads, tile, out);
}

/// [`conv2d_batch_into`] over weight-only int8 weights: the 4 tap
/// weights of a kernel are dequantized in-register once per
/// (row-tile, kernel) for the whole batch.
pub fn conv2d_quant_batch_into(input: BatchView<'_>, layer: &QuantFkw,
                               stride: usize, relu: bool, threads: usize,
                               tile: TileConfig, out: &mut [f32]) {
    conv2d_view_batch_into(input, &FkwView::from_quant(layer), stride,
                           relu, threads, tile, out);
}

/// Allocate the output tensor of a 3x3 SAME conv and fill it via `f`.
fn alloc_out<F>(input: &Tensor, cout: usize, stride: usize, f: F) -> Tensor
where
    F: FnOnce(TensorView<'_>, &mut [f32]),
{
    let (h_out, _) = same_pad(input.h, 3, stride);
    let (w_out, _) = same_pad(input.w, 3, stride);
    let mut out = Tensor::zeros(cout, h_out, w_out);
    f(input.view(), &mut out.data);
    out
}

fn conv2d_view_into(input: TensorView<'_>, layer: &FkwView<'_>,
                    stride: usize, relu: bool, threads: usize,
                    tile: TileConfig, out: &mut [f32]) {
    let (h_out, pad_h) = same_pad(input.h, 3, stride);
    let (w_out, pad_w) = same_pad(input.w, 3, stride);
    let hw = h_out * w_out;
    assert_eq!(out.len(), layer.cout * hw, "output buffer size mismatch");
    let co_block = tile.co_block.max(1);
    let h_tile = tile.h_tile.max(1);
    let cout = layer.cout;

    // One slot per original output channel; each is taken exactly once by
    // the worker that owns the corresponding physical filter.
    let plane_slots: Vec<std::sync::Mutex<Option<&mut [f32]>>> = out
        .chunks_mut(hw)
        .map(|c| std::sync::Mutex::new(Some(c)))
        .collect();
    let n_groups = cout.div_ceil(co_block);
    let counter = std::sync::atomic::AtomicUsize::new(0);
    let workers = threads.max(1).min(n_groups.max(1));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let g = counter
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if g >= n_groups {
                    break;
                }
                for phys in g * co_block..((g + 1) * co_block).min(cout) {
                    let co = layer.filter_order[phys] as usize;
                    let mut guard = plane_slots[co].lock().unwrap();
                    let plane = guard.as_deref_mut().unwrap();
                    filter_conv(
                        plane, input, layer, phys, co, stride, relu,
                        h_tile, h_out, w_out, pad_h, pad_w,
                    );
                }
            });
        }
    });
}

/// Per-(row-tile, kernel) execution geometry, decoded once and valid
/// for every image of a batch (all images share one shape): fused-path
/// eligibility and the interior x-range common to all 4 taps.
struct KernelGeom {
    fused: bool,
    x_lo: usize,
    x_hi: usize,
}

/// Decide the fused 4-tap fast path (stride 1, all tap rows interior
/// over the whole tile, non-empty common x-range) and the common
/// interior x-range.
#[allow(clippy::too_many_arguments)]
fn kernel_geom(taps: &[Tap; 4], y0: usize, y1: usize, stride: usize,
               pad_h: usize, pad_w: usize, w_out: usize, in_h: usize,
               in_w: usize) -> KernelGeom {
    // Fused 4-tap fast path (stride 1, all rows interior): one pass over
    // the output row with four input-row streams — 4x less out-row
    // load/store traffic than tap-by-tap (EXPERIMENTS.md §Perf
    // iteration 3).
    let mut fused = stride == 1;
    if fused {
        for y in y0..y1 {
            for &(dy, _) in taps.iter() {
                let iy = (y + dy) as isize - pad_h as isize;
                if iy < 0 || iy >= in_h as isize {
                    fused = false;
                }
            }
            if !fused {
                break;
            }
        }
    }
    // interior x-range common to all taps (empty -> unfused)
    let x_lo = taps
        .iter()
        .map(|&(_, dx)| pad_w.saturating_sub(dx))
        .max()
        .unwrap();
    let x_hi = taps
        .iter()
        .map(|&(_, dx)| (in_w + pad_w - dx).min(w_out))
        .min()
        .unwrap();
    if x_lo >= x_hi {
        fused = false;
    }
    KernelGeom { fused, x_lo, x_hi }
}

/// Accumulate one kernel's 4 taps into one image's output plane for the
/// row tile `[y0, y1)`, following the precomputed geometry. This is the
/// single body both the per-image and the batched walks execute, so the
/// two are bit-identical by construction.
#[allow(clippy::too_many_arguments)]
fn kernel_apply(plane: &mut [f32], in_plane: &[f32], taps: &[Tap; 4],
                wts: [f32; 4], g: &KernelGeom, y0: usize, y1: usize,
                stride: usize, pad_h: usize, pad_w: usize, w_out: usize,
                in_h: usize, in_w: usize) {
    let (x_lo, x_hi) = (g.x_lo, g.x_hi);
    if g.fused {
        for y in y0..y1 {
            let row = |t: usize| -> &[f32] {
                let (dy, dx) = taps[t];
                let iy = (y + dy) - pad_h;
                let s0 = x_lo + dx - pad_w;
                &in_plane[iy * in_w + s0..iy * in_w + s0 + (x_hi - x_lo)]
            };
            {
                let (r0, r1, r2, r3) = (row(0), row(1), row(2), row(3));
                let (w0, w1, w2, w3) = (wts[0], wts[1], wts[2], wts[3]);
                let out_row =
                    &mut plane[y * w_out + x_lo..y * w_out + x_hi];
                for (i, o) in out_row.iter_mut().enumerate() {
                    *o += w0 * r0[i]
                        + w1 * r1[i]
                        + w2 * r2[i]
                        + w3 * r3[i];
                }
            }
            // borders outside the common range: per-tap
            for (t, &(dy, dx)) in taps.iter().enumerate() {
                let t_lo = pad_w.saturating_sub(dx);
                let t_hi = (in_w + pad_w - dx).min(w_out);
                let w = wts[t];
                let iy = (y + dy) - pad_h;
                let in_row = &in_plane[iy * in_w..(iy + 1) * in_w];
                let out_row = &mut plane[y * w_out..(y + 1) * w_out];
                for x in t_lo..t_hi.min(x_lo.max(t_lo)) {
                    out_row[x] += w * in_row[x + dx - pad_w];
                }
                for x in x_hi.max(t_lo)..t_hi {
                    out_row[x] += w * in_row[x + dx - pad_w];
                }
            }
        }
    } else {
        for (t, &(dy, dx)) in taps.iter().enumerate() {
            let w = wts[t];
            tap_rows(
                plane, in_plane, w, dy, dx, y0, y1, stride, pad_h,
                pad_w, w_out, in_h, in_w,
            );
        }
    }
}

/// Compute one filter's output plane.
#[inline]
#[allow(clippy::too_many_arguments)]
fn filter_conv(plane: &mut [f32], input: TensorView<'_>,
               layer: &FkwView<'_>, phys: usize, co: usize, stride: usize,
               relu: bool, h_tile: usize, h_out: usize, w_out: usize,
               pad_h: usize, pad_w: usize) {
    plane.fill(layer.bias[co]);
    let k_lo = layer.offsets[phys] as usize;
    let k_hi = layer.offsets[phys + 1] as usize;
    // Row-tiled kernel walk: all kernels revisit the same output row tile
    // while its input rows are hot (load redundancy elimination).
    for y0 in (0..h_out).step_by(h_tile) {
        let y1 = (y0 + h_tile).min(h_out);
        for e in k_lo..k_hi {
            let kern = layer.kernels[e];
            let ci = kern.ci as usize;
            let in_plane = input.plane(ci);
            let taps = &PATTERN_SET_4[kern.pattern as usize];
            let wts = layer.wts(e, co);
            let g = kernel_geom(taps, y0, y1, stride, pad_h, pad_w,
                                w_out, input.h, input.w);
            kernel_apply(plane, in_plane, taps, wts, &g, y0, y1, stride,
                         pad_h, pad_w, w_out, input.h, input.w);
        }
    }
    if relu {
        for v in plane.iter_mut() {
            *v = v.max(0.0);
        }
    }
}

/// Compute one filter's output plane for *every* image of the batch:
/// the weight stream — kernel entries, taps, tap weights, geometry — is
/// decoded once per (row-tile, kernel) and the inner image loop reuses
/// it, which is where the batch amortizes the compressed-weight
/// traffic. The per-image (tile, kernel, tap) order is exactly
/// [`filter_conv`]'s, so results are bit-identical per image.
#[allow(clippy::too_many_arguments)]
fn filter_conv_batch(planes: &mut [&mut [f32]], input: BatchView<'_>,
                     layer: &FkwView<'_>, phys: usize, co: usize,
                     stride: usize, relu: bool, h_tile: usize,
                     h_out: usize, w_out: usize, pad_h: usize,
                     pad_w: usize) {
    for p in planes.iter_mut() {
        p.fill(layer.bias[co]);
    }
    let k_lo = layer.offsets[phys] as usize;
    let k_hi = layer.offsets[phys + 1] as usize;
    for y0 in (0..h_out).step_by(h_tile) {
        let y1 = (y0 + h_tile).min(h_out);
        for e in k_lo..k_hi {
            let kern = layer.kernels[e];
            let ci = kern.ci as usize;
            let taps = &PATTERN_SET_4[kern.pattern as usize];
            let wts = layer.wts(e, co);
            let g = kernel_geom(taps, y0, y1, stride, pad_h, pad_w,
                                w_out, input.h, input.w);
            for (img, plane) in planes.iter_mut().enumerate() {
                kernel_apply(plane, input.image(img).plane(ci), taps,
                             wts, &g, y0, y1, stride, pad_h, pad_w,
                             w_out, input.h, input.w);
            }
        }
    }
    if relu {
        for p in planes.iter_mut() {
            for v in p.iter_mut() {
                *v = v.max(0.0);
            }
        }
    }
}

/// Batched edition of [`conv2d_view_into`]: workers still claim physical
/// filter groups, but each filter computes its plane for all `n` images
/// before moving on (weight decode amortized across the batch). Output
/// layout `[n][cout][hw]`.
fn conv2d_view_batch_into(input: BatchView<'_>, layer: &FkwView<'_>,
                          stride: usize, relu: bool, threads: usize,
                          tile: TileConfig, out: &mut [f32]) {
    let (h_out, pad_h) = same_pad(input.h, 3, stride);
    let (w_out, pad_w) = same_pad(input.w, 3, stride);
    let hw = h_out * w_out;
    let n = input.n;
    let cout = layer.cout;
    assert_eq!(out.len(), n * cout * hw, "output buffer size mismatch");
    let co_block = tile.co_block.max(1);
    let h_tile = tile.h_tile.max(1);

    // Slot (img * cout + co): each taken exactly once by the worker that
    // owns the corresponding physical filter.
    let plane_slots: Vec<std::sync::Mutex<Option<&mut [f32]>>> = out
        .chunks_mut(hw)
        .map(|c| std::sync::Mutex::new(Some(c)))
        .collect();
    let n_groups = cout.div_ceil(co_block);
    let counter = std::sync::atomic::AtomicUsize::new(0);
    let workers = threads.max(1).min(n_groups.max(1));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let g = counter
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if g >= n_groups {
                    break;
                }
                for phys in g * co_block..((g + 1) * co_block).min(cout) {
                    let co = layer.filter_order[phys] as usize;
                    let mut guards: Vec<_> = (0..n)
                        .map(|img| {
                            plane_slots[img * cout + co].lock().unwrap()
                        })
                        .collect();
                    let mut planes: Vec<&mut [f32]> = guards
                        .iter_mut()
                        .map(|gd| gd.as_deref_mut().unwrap())
                        .collect();
                    filter_conv_batch(
                        &mut planes, input, layer, phys, co, stride,
                        relu, h_tile, h_out, w_out, pad_h, pad_w,
                    );
                }
            });
        }
    });
}

/// The compile-time half of the pattern-GEMM lowering: which (ci, tap)
/// shifted-input rows the layer's surviving kernels actually touch.
/// Depends only on the layer *structure*, so the plan lowering builds it
/// once and every inference reuses it.
#[derive(Debug, Clone)]
pub struct PatternGemmPlan {
    /// [(ci * 9) + tap_id] -> row index in U, or `u32::MAX` if unused.
    row_of: Vec<u32>,
    /// Number of live rows in U.
    n_rows: usize,
}

impl PatternGemmPlan {
    /// Build the row map for a layer's surviving kernels.
    pub fn build(cin: usize, kernels: &[FkwKernel]) -> PatternGemmPlan {
        let mut used = vec![false; cin * 9];
        for k in kernels {
            let taps = &PATTERN_SET_4[k.pattern as usize];
            for &(dy, dx) in taps {
                used[k.ci as usize * 9 + dy * 3 + dx] = true;
            }
        }
        let mut row_of = vec![u32::MAX; cin * 9];
        let mut next = 0u32;
        for (i, u) in used.iter().enumerate() {
            if *u {
                row_of[i] = next;
                next += 1;
            }
        }
        PatternGemmPlan {
            row_of,
            n_rows: next as usize,
        }
    }

    /// Number of live rows in the packed U matrix. Exposed so the
    /// static plan verifier (`codegen::verify`) can prove every
    /// surviving tap maps inside the panel before the plan serves.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// The row map `[(ci * 9) + dy*3 + dx] -> U row` (`u32::MAX` =
    /// unused tap). Exposed for the verifier's bounds proof.
    pub fn row_map(&self) -> &[u32] {
        &self.row_of
    }
}

/// Pattern-aware im2col + GEMM path: build the shifted-input matrix
/// `U[(ci,tap)][hw]` ONCE for the union of taps that actually occur, then
/// one GEMM per filter row over its surviving (ci,tap) columns.
///
/// Chosen by the dispatcher for deep layers (small spatial dims, large
/// channel counts) where the row-AXPY path's per-row overhead dominates:
/// U costs 4*cin*hw writes amortized over cout filters, and the inner
/// loop becomes a dense dot over hw-length rows — the "pattern-aware
/// lowering" counterpart of the paper's GPU code generation.
pub fn conv2d_gemm(input: &Tensor, layer: &FkwLayer, stride: usize,
                   relu: bool, threads: usize) -> Tensor {
    let gp = PatternGemmPlan::build(layer.cin, &layer.kernels);
    let mut u_buf = Vec::new();
    alloc_out(input, layer.cout, stride, |view, out| {
        conv2d_gemm_view_into(view, &FkwView::from_f32(layer), stride,
                              relu, threads, &gp, &mut u_buf, out);
    })
}

/// [`conv2d_gemm`] over weight-only int8 weights (dequant-on-load).
pub fn conv2d_gemm_quant(input: &Tensor, layer: &QuantFkw, stride: usize,
                         relu: bool, threads: usize) -> Tensor {
    let gp = PatternGemmPlan::build(layer.cin, &layer.kernels);
    let mut u_buf = Vec::new();
    alloc_out(input, layer.cout, stride, |view, out| {
        conv2d_gemm_view_into(view, &FkwView::from_quant(layer), stride,
                              relu, threads, &gp, &mut u_buf, out);
    })
}

/// [`conv2d_gemm`] writing into a preassigned output buffer, with the
/// row map precomputed at lowering time and the U matrix in a reusable
/// scratch buffer.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_gemm_into(input: TensorView<'_>, layer: &FkwLayer,
                        stride: usize, relu: bool, threads: usize,
                        gp: &PatternGemmPlan, u_buf: &mut Vec<f32>,
                        out: &mut [f32]) {
    conv2d_gemm_view_into(input, &FkwView::from_f32(layer), stride, relu,
                          threads, gp, u_buf, out);
}

/// [`conv2d_gemm_quant`] writing into a preassigned output buffer.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_gemm_quant_into(input: TensorView<'_>, layer: &QuantFkw,
                              stride: usize, relu: bool, threads: usize,
                              gp: &PatternGemmPlan, u_buf: &mut Vec<f32>,
                              out: &mut [f32]) {
    conv2d_gemm_view_into(input, &FkwView::from_quant(layer), stride,
                          relu, threads, gp, u_buf, out);
}

/// Fused batched pattern-GEMM conv: one shared `U[(ci,tap)][n*hw]`
/// shifted-input matrix for the whole batch and one kernel walk per
/// filter per batch. Output layout `[n][cout][hw]`; bit-identical per
/// image to [`conv2d_gemm_into`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_gemm_batch_into(input: BatchView<'_>, layer: &FkwLayer,
                              stride: usize, relu: bool, threads: usize,
                              gp: &PatternGemmPlan, u_buf: &mut Vec<f32>,
                              out: &mut [f32]) {
    conv2d_gemm_view_batch_into(input, &FkwView::from_f32(layer), stride,
                                relu, threads, gp, u_buf, out);
}

/// [`conv2d_gemm_batch_into`] over weight-only int8 weights
/// (dequant-on-load, once per kernel per batch).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_gemm_quant_batch_into(input: BatchView<'_>,
                                    layer: &QuantFkw, stride: usize,
                                    relu: bool, threads: usize,
                                    gp: &PatternGemmPlan,
                                    u_buf: &mut Vec<f32>,
                                    out: &mut [f32]) {
    conv2d_gemm_view_batch_into(input, &FkwView::from_quant(layer),
                                stride, relu, threads, gp, u_buf, out);
}

/// Build the shifted-input matrix `U[(ci,tap)][n*hw]` for the whole
/// batch — image `i`'s columns occupy `[i*hw, (i+1)*hw)` of every live
/// row (n = 1 is the single-image layout).
#[allow(clippy::too_many_arguments)]
fn build_u_matrix(input: BatchView<'_>, cin: usize, gp: &PatternGemmPlan,
                  stride: usize, pad_h: usize, pad_w: usize,
                  h_out: usize, w_out: usize, u_buf: &mut Vec<f32>) {
    let hw = h_out * w_out;
    let nhw = input.n * hw;
    u_buf.clear();
    u_buf.resize(gp.n_rows * nhw, 0.0);
    let u_mat = &mut u_buf[..];
    for img in 0..input.n {
        let image = input.image(img);
        for ci in 0..cin {
            let plane = image.plane(ci);
            for dy in 0..3 {
                for dx in 0..3 {
                    let r = gp.row_of[ci * 9 + dy * 3 + dx];
                    if r == u32::MAX {
                        continue;
                    }
                    // Twin of the verifier's row-map proof
                    // (codegen::verify): live rows index inside U.
                    debug_assert!((r as usize) < gp.n_rows,
                                  "row map escapes the U panel");
                    let dst = &mut u_mat[r as usize * nhw + img * hw
                        ..r as usize * nhw + (img + 1) * hw];
                    for y in 0..h_out {
                        fill_shifted_row(
                            &mut dst[y * w_out..(y + 1) * w_out], plane,
                            input.h, input.w, y, dy, dx, stride, pad_h,
                            pad_w, w_out,
                        );
                    }
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn conv2d_gemm_view_into(input: TensorView<'_>, layer: &FkwView<'_>,
                         stride: usize, relu: bool, threads: usize,
                         gp: &PatternGemmPlan, u_buf: &mut Vec<f32>,
                         out: &mut [f32]) {
    conv2d_gemm_view_batch_into(BatchView::of_single(input), layer,
                                stride, relu, threads, gp, u_buf, out);
}

/// Batched pattern-GEMM path: U is built once for the whole batch, and
/// every filter's kernel walk — the compressed weight traversal —
/// happens once per batch, with each tap's AXPY streaming over all `n`
/// images' U columns. The per-image (kernel, tap) accumulation order is
/// the single-image order, so results are bit-identical per image.
/// Output layout `[n][cout][hw]`.
#[allow(clippy::too_many_arguments)]
fn conv2d_gemm_view_batch_into(input: BatchView<'_>, layer: &FkwView<'_>,
                               stride: usize, relu: bool, threads: usize,
                               gp: &PatternGemmPlan, u_buf: &mut Vec<f32>,
                               out: &mut [f32]) {
    let (h_out, pad_h) = same_pad(input.h, 3, stride);
    let (w_out, pad_w) = same_pad(input.w, 3, stride);
    let hw = h_out * w_out;
    let n = input.n;
    let nhw = n * hw;
    let cout = layer.cout;
    assert_eq!(out.len(), n * cout * hw, "output buffer size mismatch");
    let cin = layer.cin;
    let row_of = &gp.row_of;
    assert_eq!(row_of.len(), cin * 9, "gemm plan built for other layer");
    build_u_matrix(input, cin, gp, stride, pad_h, pad_w, h_out, w_out,
                   u_buf);
    // Per-filter sparse-row GEMV over the shared U, all images per
    // kernel walk.
    let u_mat = &u_buf[..];
    let plane_slots: Vec<std::sync::Mutex<Option<&mut [f32]>>> = out
        .chunks_mut(hw)
        .map(|c| std::sync::Mutex::new(Some(c)))
        .collect();
    let counter = std::sync::atomic::AtomicUsize::new(0);
    let workers = threads.max(1).min(cout.max(1));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let phys = counter
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if phys >= cout {
                    break;
                }
                let co = layer.filter_order[phys] as usize;
                let mut guards: Vec<_> = (0..n)
                    .map(|img| {
                        plane_slots[img * cout + co].lock().unwrap()
                    })
                    .collect();
                let mut planes: Vec<&mut [f32]> = guards
                    .iter_mut()
                    .map(|gd| gd.as_deref_mut().unwrap())
                    .collect();
                for p in planes.iter_mut() {
                    p.fill(layer.bias[co]);
                }
                for e in layer.offsets[phys] as usize
                    ..layer.offsets[phys + 1] as usize
                {
                    let kern = layer.kernels[e];
                    let taps = &PATTERN_SET_4[kern.pattern as usize];
                    let wts = layer.wts(e, co);
                    for (t, &(dy, dx)) in taps.iter().enumerate() {
                        let r = row_of
                            [kern.ci as usize * 9 + dy * 3 + dx]
                            as usize;
                        // Twin of the verifier's row-map proof: a
                        // surviving tap is never unmapped (u32::MAX)
                        // and lands inside the packed U panel.
                        debug_assert!(r < gp.n_rows,
                                      "tap row escapes the U panel");
                        let w = wts[t];
                        for (img, plane) in
                            planes.iter_mut().enumerate()
                        {
                            let u_row = &u_mat[r * nhw + img * hw
                                ..r * nhw + (img + 1) * hw];
                            // Tier-dispatched AXPY (AVX2 FMA on the
                            // SIMD tier) over the U row.
                            crate::exec::gemm::axpy(plane, u_row, w);
                        }
                    }
                }
                if relu {
                    for p in planes.iter_mut() {
                        for v in p.iter_mut() {
                            *v = v.max(0.0);
                        }
                    }
                }
            });
        }
    });
}

/// Dispatch on the tuner's path decision (TileConfig::use_gemm).
pub fn conv2d_auto(input: &Tensor, layer: &FkwLayer, stride: usize,
                   relu: bool, threads: usize, tile: TileConfig) -> Tensor {
    if tile.use_gemm {
        conv2d_gemm(input, layer, stride, relu, threads)
    } else {
        conv2d(input, layer, stride, relu, threads, tile)
    }
}

/// [`conv2d_auto`] over weight-only int8 weights (dequant-on-load).
pub fn conv2d_quant_auto(input: &Tensor, layer: &QuantFkw, stride: usize,
                         relu: bool, threads: usize, tile: TileConfig)
                         -> Tensor {
    if tile.use_gemm {
        conv2d_gemm_quant(input, layer, stride, relu, threads)
    } else {
        conv2d_quant(input, layer, stride, relu, threads, tile)
    }
}

/// Accumulate one tap over output rows [y0, y1): the SIMD inner loop.
#[inline]
#[allow(clippy::too_many_arguments)]
fn tap_rows(plane: &mut [f32], in_plane: &[f32], w: f32, dy: usize,
            dx: usize, y0: usize, y1: usize, stride: usize, pad_h: usize,
            pad_w: usize, w_out: usize, in_h: usize, in_w: usize) {
    for y in y0..y1 {
        let iy = (y * stride + dy) as isize - pad_h as isize;
        if iy < 0 || iy >= in_h as isize {
            continue;
        }
        let in_row = &in_plane[iy as usize * in_w..(iy as usize + 1) * in_w];
        let out_row = &mut plane[y * w_out..(y + 1) * w_out];
        if stride == 1 {
            // Contiguous AXPY with border clamp:
            // ix = x + dx - pad_w in [0, in_w)
            let x_lo = pad_w.saturating_sub(dx);
            let x_hi = (in_w + pad_w - dx).min(w_out);
            if x_lo < x_hi {
                let src0 = x_lo + dx - pad_w;
                let dst = &mut out_row[x_lo..x_hi];
                let src = &in_row[src0..src0 + (x_hi - x_lo)];
                crate::exec::gemm::axpy(dst, src, w);
            }
        } else {
            for (x, o) in out_row.iter_mut().enumerate() {
                let ix = (x * stride + dx) as isize - pad_w as isize;
                if ix >= 0 && (ix as usize) < in_w {
                    *o += w * in_row[ix as usize];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::reorder::filter_kernel_reorder;
    use crate::compress::DenseLayer;
    use crate::exec::naive;
    use crate::patterns::connectivity::ConnectivityMask;
    use crate::util::prop;

    /// Oracle: expand FKW to dense, run the naive engine.
    fn oracle(input: &Tensor, layer: &FkwLayer, stride: usize, relu: bool)
              -> Tensor {
        naive::conv2d(input, &layer.to_dense(), stride, relu, 1)
    }

    #[test]
    fn matches_dense_expansion() {
        prop::check("pattern-conv-vs-oracle", 25, |g| {
            let cin = g.usize(1, 8);
            let cout = g.usize(1, 10);
            let h = g.usize(3, 14);
            let w = g.usize(3, 14);
            let stride = *g.pick(&[1usize, 2]);
            let keep = g.f64(0.3, 1.0);
            let relu = g.bool();
            let mut rng = g.rng().clone();
            let input = Tensor::random(cin, h, w, &mut rng);
            let dense = DenseLayer {
                cout,
                cin,
                kh: 3,
                kw: 3,
                weights: (0..cout * cin * 9)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let conn = crate::codegen::prune_conn_oihw(&dense, keep);
            let mut fkw = FkwLayer::from_dense(&dense, &conn);
            filter_kernel_reorder(&mut fkw);
            let tile = TileConfig {
                h_tile: g.usize(1, 8),
                co_block: g.usize(1, 4),
                use_gemm: false,
            };
            let got = conv2d(&input, &fkw, stride, relu,
                             g.usize(1, 4), tile);
            let want = oracle(&input, &fkw, stride, relu);
            if got.max_abs_diff(&want) > 1e-4 {
                return Err(format!("diff {}", got.max_abs_diff(&want)));
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_path_matches_axpy_path() {
        prop::check("pattern-gemm-vs-axpy", 25, |g| {
            let cin = g.usize(1, 10);
            let cout = g.usize(1, 12);
            let h = g.usize(3, 16);
            let w = g.usize(3, 16);
            let stride = *g.pick(&[1usize, 2]);
            let keep = g.f64(0.3, 1.0);
            let relu = g.bool();
            let mut rng = g.rng().clone();
            let input = Tensor::random(cin, h, w, &mut rng);
            let dense = DenseLayer {
                cout,
                cin,
                kh: 3,
                kw: 3,
                weights: (0..cout * cin * 9)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let conn = crate::codegen::prune_conn_oihw(&dense, keep);
            let mut fkw = FkwLayer::from_dense(&dense, &conn);
            filter_kernel_reorder(&mut fkw);
            let a = conv2d(&input, &fkw, stride, relu, 2,
                           TileConfig::default());
            let b = conv2d_gemm(&input, &fkw, stride, relu,
                                g.usize(1, 4));
            if a.max_abs_diff(&b) > 1e-4 {
                return Err(format!("diff {}", a.max_abs_diff(&b)));
            }
            Ok(())
        });
    }

    #[test]
    fn fully_connected_all_alive_matches_projected_dense() {
        let mut g = prop::Gen::replay(99);
        let mut rng = g.rng().clone();
        let input = Tensor::random(4, 10, 10, &mut rng);
        let dense = DenseLayer {
            cout: 6,
            cin: 4,
            kh: 3,
            kw: 3,
            weights: (0..6 * 4 * 9).map(|_| rng.normal_f32()).collect(),
            bias: vec![0.0; 6],
        };
        let conn = ConnectivityMask::all_alive(4, 6);
        let fkw = FkwLayer::from_dense(&dense, &conn);
        let got = conv2d(&input, &fkw, 1, false, 2, TileConfig::default());
        let want = oracle(&input, &fkw, 1, false);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn batch_paths_match_per_image_bitwise() {
        prop::check("pattern-batch-vs-single", 20, |g| {
            let n = g.usize(1, 5);
            let cin = g.usize(1, 6);
            let cout = g.usize(1, 8);
            let h = g.usize(3, 12);
            let w = g.usize(3, 12);
            let stride = *g.pick(&[1usize, 2]);
            let keep = g.f64(0.3, 1.0);
            let relu = g.bool();
            let tile = TileConfig {
                h_tile: g.usize(1, 8),
                co_block: g.usize(1, 4),
                use_gemm: false,
            };
            let mut rng = g.rng().clone();
            let dense = DenseLayer {
                cout,
                cin,
                kh: 3,
                kw: 3,
                weights: (0..cout * cin * 9)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let conn = crate::codegen::prune_conn_oihw(&dense, keep);
            let mut fkw = FkwLayer::from_dense(&dense, &conn);
            filter_kernel_reorder(&mut fkw);
            let qf = QuantFkw::quantize(&fkw);
            let images: Vec<Tensor> = (0..n)
                .map(|_| Tensor::random(cin, h, w, &mut rng))
                .collect();
            let mut packed = Vec::new();
            for t in &images {
                packed.extend_from_slice(&t.data);
            }
            let view = BatchView::new(n, cin, h, w, &packed);
            let (h_out, _) = same_pad(h, 3, stride);
            let (w_out, _) = same_pad(w, 3, stride);
            let per = cout * h_out * w_out;
            let gp = PatternGemmPlan::build(cin, &fkw.kernels);
            let mut u_buf = Vec::new();
            let mut axpy = vec![0f32; n * per];
            conv2d_batch_into(view, &fkw, stride, relu, 2, tile,
                              &mut axpy);
            let mut gemm = vec![0f32; n * per];
            conv2d_gemm_batch_into(view, &fkw, stride, relu, 2, &gp,
                                   &mut u_buf, &mut gemm);
            let mut q_axpy = vec![0f32; n * per];
            conv2d_quant_batch_into(view, &qf, stride, relu, 2, tile,
                                    &mut q_axpy);
            let mut q_gemm = vec![0f32; n * per];
            conv2d_gemm_quant_batch_into(view, &qf, stride, relu, 2,
                                         &gp, &mut u_buf, &mut q_gemm);
            for (i, t) in images.iter().enumerate() {
                let want =
                    conv2d(t, &fkw, stride, relu, 1, tile);
                if axpy[i * per..(i + 1) * per] != want.data[..] {
                    return Err(format!("axpy batch diverged at {i}"));
                }
                let want_g = conv2d_gemm(t, &fkw, stride, relu, 1);
                if gemm[i * per..(i + 1) * per] != want_g.data[..] {
                    return Err(format!("gemm batch diverged at {i}"));
                }
                let want_q =
                    conv2d_quant(t, &qf, stride, relu, 1, tile);
                if q_axpy[i * per..(i + 1) * per] != want_q.data[..] {
                    return Err(format!("quant axpy diverged at {i}"));
                }
                let want_qg =
                    conv2d_gemm_quant(t, &qf, stride, relu, 1);
                if q_gemm[i * per..(i + 1) * per] != want_qg.data[..] {
                    return Err(format!("quant gemm diverged at {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quant_paths_match_dequantized_layer_bitwise() {
        // Dequant-on-load materializes the exact same f32 tap weights the
        // dequantized layer stores, through the same loop structure, so
        // AXPY and GEMM quant paths are bit-identical to running the
        // dequantized f32 layer on the corresponding f32 path.
        prop::check("pattern-quant-vs-dequantized", 20, |g| {
            let cin = g.usize(1, 8);
            let cout = g.usize(1, 10);
            let h = g.usize(3, 14);
            let w = g.usize(3, 14);
            let stride = *g.pick(&[1usize, 2]);
            let keep = g.f64(0.3, 1.0);
            let relu = g.bool();
            let mut rng = g.rng().clone();
            let input = Tensor::random(cin, h, w, &mut rng);
            let dense = DenseLayer {
                cout,
                cin,
                kh: 3,
                kw: 3,
                weights: (0..cout * cin * 9)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let conn = crate::codegen::prune_conn_oihw(&dense, keep);
            let mut fkw = FkwLayer::from_dense(&dense, &conn);
            filter_kernel_reorder(&mut fkw);
            let qf = QuantFkw::quantize(&fkw);
            let deq = qf.dequantize();
            let tile = TileConfig {
                h_tile: g.usize(1, 8),
                co_block: g.usize(1, 4),
                use_gemm: false,
            };
            let a = conv2d_quant(&input, &qf, stride, relu,
                                 g.usize(1, 4), tile);
            let b = conv2d(&input, &deq, stride, relu, 1, tile);
            if a.data != b.data {
                return Err(format!("axpy diff {}", a.max_abs_diff(&b)));
            }
            let c = conv2d_gemm_quant(&input, &qf, stride, relu, 2);
            let d = conv2d_gemm(&input, &deq, stride, relu, 1);
            if c.data != d.data {
                return Err(format!("gemm diff {}", c.max_abs_diff(&d)));
            }
            Ok(())
        });
    }

    #[test]
    fn quant_auto_dispatches_both_paths() {
        let mut g = prop::Gen::replay(123);
        let mut rng = g.rng().clone();
        let input = Tensor::random(6, 12, 12, &mut rng);
        let dense = DenseLayer {
            cout: 8,
            cin: 6,
            kh: 3,
            kw: 3,
            weights: (0..8 * 6 * 9).map(|_| rng.normal_f32()).collect(),
            bias: (0..8).map(|_| rng.normal_f32()).collect(),
        };
        let conn = ConnectivityMask::all_alive(6, 8);
        let mut fkw = FkwLayer::from_dense(&dense, &conn);
        filter_kernel_reorder(&mut fkw);
        let qf = QuantFkw::quantize(&fkw);
        let axpy = conv2d_quant_auto(&input, &qf, 1, true, 2, TileConfig {
            h_tile: 4,
            co_block: 2,
            use_gemm: false,
        });
        let gemm = conv2d_quant_auto(&input, &qf, 1, true, 2, TileConfig {
            h_tile: 1,
            co_block: 1,
            use_gemm: true,
        });
        assert!(axpy.max_abs_diff(&gemm) < 1e-4);
    }
}
