//! Blocked single-precision GEMM for the im2col engine and FC layers.
//!
//! `C[M][N] += A[M][K] * B[K][N]`, all row-major. On the SIMD dispatch
//! tier (see [`crate::exec::micro`]) both operands are packed into
//! register-tiled panels and run through the explicit AVX2+FMA 6x16
//! microkernel. On the scalar tier the seed kernel runs unchanged: 4
//! rows of A at a time with a K-blocked broadcast-AXPY inner loop over
//! contiguous rows of B — auto-vectorizes well and keeps the B row in
//! registers/L1 across the 4 accumulator rows.

use crate::exec::micro;
use crate::util::threadpool;

const KC: usize = 256; // K-panel kept in L1/L2 between row sweeps
const MR: usize = 4; // register rows

/// C = A * B (+ existing C contents). Row-major everywhere. Dispatches
/// once per call on the cached CPU tier; per-element results are
/// independent of thread count and of where a column sits in the
/// operand (so batched and single-image conv calls stay bit-identical
/// per image on either tier).
pub fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize,
            n: usize, threads: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if micro::tier().is_simd() {
        micro::gemm_simd(a, b, c, m, k, n, threads);
        return;
    }
    // Parallelize over blocks of MR rows of C.
    threadpool::parallel_chunks_mut(c, MR * n, threads, |blk, c_blk| {
        let row0 = blk * MR;
        let rows = c_blk.len() / n;
        for k0 in (0..k).step_by(KC) {
            let k1 = (k0 + KC).min(k);
            match rows {
                4 => micro_4(a, b, c_blk, row0, k0, k1, k, n),
                _ => {
                    for r in 0..rows {
                        let a_row = &a[(row0 + r) * k..(row0 + r) * k + k];
                        let c_row = &mut c_blk[r * n..(r + 1) * n];
                        for kk in k0..k1 {
                            axpy(c_row, &b[kk * n..kk * n + n], a_row[kk]);
                        }
                    }
                }
            }
        }
    });
}

/// 4-row micro-kernel: each B row is loaded once and feeds 4 accumulator
/// rows (register-level load redundancy elimination on the B panel).
#[inline]
fn micro_4(a: &[f32], b: &[f32], c_blk: &mut [f32], row0: usize, k0: usize,
           k1: usize, k: usize, n: usize) {
    let (c0, rest) = c_blk.split_at_mut(n);
    let (c1, rest) = rest.split_at_mut(n);
    let (c2, c3) = rest.split_at_mut(n);
    for kk in k0..k1 {
        let b_row = &b[kk * n..kk * n + n];
        let w0 = a[row0 * k + kk];
        let w1 = a[(row0 + 1) * k + kk];
        let w2 = a[(row0 + 2) * k + kk];
        let w3 = a[(row0 + 3) * k + kk];
        for i in 0..n {
            let bv = b_row[i];
            c0[i] += w0 * bv;
            c1[i] += w1 * bv;
            c2[i] += w2 * bv;
            c3[i] += w3 * bv;
        }
    }
}

/// y += w * x over equal-length slices, tier-dispatched (AVX2 FMA on
/// the SIMD tier, the seed scalar loop otherwise).
#[inline]
pub fn axpy(y: &mut [f32], x: &[f32], w: f32) {
    debug_assert_eq!(y.len(), x.len());
    micro::axpy(y, x, w);
}

/// `C[M][N] += A[M][K] * B[N][K]^T` — the transposed-B GEMM the sequence
/// tier runs: projections keep weights row-major `[d_out, d_in]` (so B's
/// rows are contiguous), and attention scores are `Q K^T` with both
/// operands row-major. Each `C[i][j]` is one sequential dot product, so
/// results are bit-identical for every thread count (threads split rows
/// of C, never a reduction) — the property the compiled-vs-reference
/// sequence tests lean on.
pub fn gemm_nt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize,
               n: usize, threads: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    threadpool::parallel_chunks_mut(c, n, threads, |row, c_row| {
        let a_row = &a[row * k..(row + 1) * k];
        for (j, out) in c_row.iter_mut().enumerate() {
            let b_row = &b[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for (x, w) in a_row.iter().zip(b_row) {
                acc += x * w;
            }
            *out += acc;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn reference(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
                 -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matches_reference_across_shapes() {
        prop::check("gemm-vs-ref", 25, |g| {
            let m = g.usize(1, 40);
            let k = g.usize(1, 64);
            let n = g.usize(1, 48);
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let mut c = vec![0f32; m * n];
            gemm(&a, &b, &mut c, m, k, n, g.usize(1, 4));
            let want = reference(&a, &b, m, k, n);
            prop::assert_allclose(&c, &want, 1e-4, 1e-4)
        });
    }

    #[test]
    fn gemm_nt_matches_reference_and_ignores_thread_count() {
        prop::check("gemm-nt-vs-ref", 25, |g| {
            let m = g.usize(1, 24);
            let k = g.usize(1, 48);
            let n = g.usize(1, 32);
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(n * k);
            let mut c = vec![0f32; m * n];
            gemm_nt(&a, &b, &mut c, m, k, n, 1);
            let mut c4 = vec![0f32; m * n];
            gemm_nt(&a, &b, &mut c4, m, k, n, 4);
            if c != c4 {
                return Err("thread count changed gemm_nt bits".into());
            }
            // B^T reference via the row-major gemm oracle.
            let mut bt = vec![0f32; k * n];
            for j in 0..n {
                for kk in 0..k {
                    bt[kk * n + j] = b[j * k + kk];
                }
            }
            let want = reference(&a, &bt, m, k, n);
            prop::assert_allclose(&c, &want, 1e-4, 1e-4)
        });
    }

    #[test]
    fn gemm_nt_accumulates_into_c() {
        let a = vec![2.0f32, 1.0];
        let b = vec![3.0f32, -1.0];
        let mut c = vec![5.0f32];
        gemm_nt(&a, &b, &mut c, 1, 2, 1, 1);
        assert_eq!(c[0], 5.0 + 2.0 * 3.0 - 1.0);
    }

    #[test]
    fn accumulates_into_c() {
        let a = vec![1.0f32];
        let b = vec![2.0f32];
        let mut c = vec![10.0f32];
        gemm(&a, &b, &mut c, 1, 1, 1, 1);
        assert_eq!(c[0], 12.0);
    }

    #[test]
    fn large_k_panels() {
        let mut rng = Rng::seed_from(4);
        let (m, k, n) = (8, 700, 16); // k > KC exercises panel loop
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let mut c = vec![0f32; m * n];
        gemm(&a, &b, &mut c, m, k, n, 4);
        let want = reference(&a, &b, m, k, n);
        prop::assert_allclose(&c, &want, 1e-3, 1e-3).unwrap();
    }
}
