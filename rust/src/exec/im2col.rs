//! im2col + GEMM convolution — the "optimizing-compiler" dense baseline
//! (stands in for TVM's default CPU conv lowering in Fig. 5).

use crate::compress::DenseLayer;
use crate::exec::gemm::{axpy, gemm};
use crate::exec::micro;
use crate::exec::tensor::{fill_shifted_row, same_pad, BatchView, Tensor,
                          TensorView};
use crate::quant::QuantDense;
use crate::util::threadpool;

/// Scratch buffers reused across layers to avoid re-allocating the
/// im2col matrix (and, on the batched path, the pre-scatter GEMM output)
/// per call — part of the fair-baseline treatment.
#[derive(Default)]
pub struct Im2colScratch {
    buf: Vec<f32>,
    /// Batched-path GEMM output `[cout][n*hw]`, scattered into the
    /// `[n][cout][hw]` activation layout after the per-layer GEMM.
    acc: Vec<f32>,
    /// Packed B panel for the compile-time-packed conv kernel (the
    /// activation side repacks per call; the weight side is packed
    /// once at lowering).
    pack_b: Vec<f32>,
}

/// Fill `scratch` with the `[K][N*HW]` patch matrix for a (kh, kw, cin)
/// kernel over the whole batch — image `i`'s patches occupy columns
/// `[i*hw, (i+1)*hw)` of every row, so one GEMM per layer covers the
/// batch and the weight panel streams once per batch, not once per
/// image. Returns the per-image output geometry. Shared by the f32 and
/// the weight-only-int8 GEMM paths (n = 1 is the single-image case).
fn im2col_patches(input: BatchView<'_>, kh: usize, kw: usize, cin: usize,
                  stride: usize, scratch: &mut Im2colScratch)
                  -> (usize, usize) {
    let (h_out, pad_h) = same_pad(input.h, kh, stride);
    let (w_out, pad_w) = same_pad(input.w, kw, stride);
    let hw = h_out * w_out;
    let nhw = input.n * hw;
    let kdim = cin * kh * kw;
    scratch.buf.clear();
    scratch.buf.resize(kdim * nhw, 0.0);
    let cols = &mut scratch.buf;
    for img in 0..input.n {
        let image = input.image(img);
        for ci in 0..cin {
            let plane = image.plane(ci);
            for ky in 0..kh {
                for kx in 0..kw {
                    let krow = (ci * kh + ky) * kw + kx;
                    let dst = &mut cols
                        [krow * nhw + img * hw..krow * nhw + (img + 1) * hw];
                    for y in 0..h_out {
                        fill_shifted_row(
                            &mut dst[y * w_out..(y + 1) * w_out], plane,
                            input.h, input.w, y, ky, kx, stride, pad_h,
                            pad_w, w_out,
                        );
                    }
                }
            }
        }
    }
    (h_out, w_out)
}

/// Dense conv via im2col + GEMM, SAME padding, optional fused ReLU.
pub fn conv2d(input: &Tensor, layer: &DenseLayer, stride: usize, relu: bool,
              threads: usize, scratch: &mut Im2colScratch) -> Tensor {
    let (h_out, _) = same_pad(input.h, layer.kh, stride);
    let (w_out, _) = same_pad(input.w, layer.kw, stride);
    let mut out = Tensor::zeros(layer.cout, h_out, w_out);
    conv2d_into(input.view(), layer, stride, relu, threads, scratch,
                &mut out.data);
    out
}

/// [`conv2d`] writing into a preassigned output buffer (arena slot);
/// allocation-free once `scratch` has warmed to the layer's patch size.
pub fn conv2d_into(input: TensorView<'_>, layer: &DenseLayer,
                   stride: usize, relu: bool, threads: usize,
                   scratch: &mut Im2colScratch, out: &mut [f32]) {
    let (h_out, w_out) = im2col_patches(BatchView::of_single(input),
                                        layer.kh, layer.kw, layer.cin,
                                        stride, scratch);
    let hw = h_out * w_out;
    let kdim = layer.cin * layer.kh * layer.kw;
    let cols = &scratch.buf;
    assert_eq!(out.len(), layer.cout * hw, "output buffer size mismatch");

    // C[cout][HW] = W[cout][K] x cols[K][HW]
    for co in 0..layer.cout {
        out[co * hw..(co + 1) * hw].fill(layer.bias[co]);
    }
    gemm(&layer.weights, cols, out, layer.cout, kdim, hw, threads);
    if relu {
        for v in out.iter_mut() {
            *v = v.max(0.0);
        }
    }
}

/// [`conv2d_into`] running a compile-time-packed weight panel through
/// the register-tiled microkernel ([`micro::gemm_packed`]): the A-pack
/// was done once at lowering, so per inference only the patch matrix
/// is packed. On the scalar tier this falls back to [`conv2d_into`]
/// (the pack is simply unused) — and on the SIMD tier the dispatched
/// [`gemm`] runs the identical packed kernel — so the packed engine is
/// bit-identical to the im2col engine on every tier, which is what
/// lets the autotuner register it without disturbing the
/// compiled-vs-direct bit-identity oracles.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_packed_into(input: TensorView<'_>, layer: &DenseLayer,
                          pack: &micro::PackedA, stride: usize,
                          relu: bool, threads: usize,
                          scratch: &mut Im2colScratch, out: &mut [f32]) {
    if !micro::tier().is_simd() {
        conv2d_into(input, layer, stride, relu, threads, scratch, out);
        return;
    }
    let (h_out, w_out) = im2col_patches(BatchView::of_single(input),
                                        layer.kh, layer.kw, layer.cin,
                                        stride, scratch);
    let hw = h_out * w_out;
    let kdim = layer.cin * layer.kh * layer.kw;
    // Debug twin of the verifier's `PackedPanelMismatch` proof
    // (`codegen::verify`), which checks pack.m/pack.k/buf length
    // against the conv this panel feeds at compile time — release
    // builds are covered there, before any kernel runs.
    debug_assert_eq!((pack.m, pack.k), (layer.cout, kdim));
    assert_eq!(out.len(), layer.cout * hw, "output buffer size mismatch");
    for co in 0..layer.cout {
        out[co * hw..(co + 1) * hw].fill(layer.bias[co]);
    }
    micro::pack_b(&scratch.buf, kdim, hw, &mut scratch.pack_b);
    micro::gemm_packed(pack.buf(), &scratch.pack_b, out, layer.cout,
                       kdim, hw, threads);
    if relu {
        for v in out.iter_mut() {
            *v = v.max(0.0);
        }
    }
}

/// Fused batched [`conv2d_packed_into`]: one B-pack and one tiled GEMM
/// for the whole batch. Bit-identical per image to the single-image
/// packed path (tile columns accumulate independently of their panel
/// position).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_packed_batch_into(input: BatchView<'_>, layer: &DenseLayer,
                                pack: &micro::PackedA, stride: usize,
                                relu: bool, threads: usize,
                                scratch: &mut Im2colScratch,
                                out: &mut [f32]) {
    if !micro::tier().is_simd() {
        conv2d_batch_into(input, layer, stride, relu, threads, scratch,
                          out);
        return;
    }
    let n = input.n;
    let (h_out, w_out) = im2col_patches(input, layer.kh, layer.kw,
                                        layer.cin, stride, scratch);
    let hw = h_out * w_out;
    let nhw = n * hw;
    let kdim = layer.cin * layer.kh * layer.kw;
    // Debug twin of the verifier's `PackedPanelMismatch` proof — see
    // `conv2d_packed_into`.
    debug_assert_eq!((pack.m, pack.k), (layer.cout, kdim));
    assert_eq!(out.len(), n * layer.cout * hw,
               "output buffer size mismatch");
    scratch.acc.clear();
    scratch.acc.resize(layer.cout * nhw, 0.0);
    for co in 0..layer.cout {
        scratch.acc[co * nhw..(co + 1) * nhw].fill(layer.bias[co]);
    }
    micro::pack_b(&scratch.buf, kdim, nhw, &mut scratch.pack_b);
    micro::gemm_packed(pack.buf(), &scratch.pack_b, &mut scratch.acc,
                       layer.cout, kdim, nhw, threads);
    scatter_batch(&scratch.acc, out, n, layer.cout, hw, relu, |v, _| v);
}

/// Fused batched conv: one `[K][n*hw]` patch matrix and a *single* GEMM
/// for the whole batch, so the weight panel streams once per batch
/// instead of once per image — the batch-amortization the compiled
/// batched pipeline is built on. The GEMM result (`[cout][n*hw]`, bias
/// pre-filled so per-element accumulation order matches [`conv2d_into`]
/// exactly) is scattered into the `[n][cout][hw]` activation layout.
/// Bit-identical per image to `conv2d_into` on that image alone.
pub fn conv2d_batch_into(input: BatchView<'_>, layer: &DenseLayer,
                         stride: usize, relu: bool, threads: usize,
                         scratch: &mut Im2colScratch, out: &mut [f32]) {
    let n = input.n;
    let (h_out, w_out) = im2col_patches(input, layer.kh, layer.kw,
                                        layer.cin, stride, scratch);
    let hw = h_out * w_out;
    let nhw = n * hw;
    let kdim = layer.cin * layer.kh * layer.kw;
    assert_eq!(out.len(), n * layer.cout * hw,
               "output buffer size mismatch");
    scratch.acc.clear();
    scratch.acc.resize(layer.cout * nhw, 0.0);
    for co in 0..layer.cout {
        scratch.acc[co * nhw..(co + 1) * nhw].fill(layer.bias[co]);
    }
    gemm(&layer.weights, &scratch.buf, &mut scratch.acc, layer.cout,
         kdim, nhw, threads);
    scatter_batch(&scratch.acc, out, n, layer.cout, hw, relu,
                  |v, _| v);
}

/// Scatter the batched GEMM output `acc[cout][n*hw]` into the
/// `[n][cout][hw]` activation layout, applying `finish(value, co)` (the
/// quant path's scale+bias fusion; identity for f32) and the fused ReLU.
fn scatter_batch<F>(acc: &[f32], out: &mut [f32], n: usize, cout: usize,
                    hw: usize, relu: bool, finish: F)
where
    F: Fn(f32, usize) -> f32,
{
    let nhw = n * hw;
    let chw = cout * hw;
    for img in 0..n {
        for co in 0..cout {
            let src = &acc[co * nhw + img * hw..co * nhw + (img + 1) * hw];
            let dst =
                &mut out[img * chw + co * hw..img * chw + (co + 1) * hw];
            for (d, s) in dst.iter_mut().zip(src) {
                let v = finish(*s, co);
                *d = if relu { v.max(0.0) } else { v };
            }
        }
    }
}

/// Weight-only int8 conv via im2col: the f32 patch matrix is shared with
/// the dense path, but each filter row stays i8 — every weight is loaded
/// as an integer, widened in-register, and streamed through an AXPY over
/// the patch rows; the per-channel scale and bias are fused in one final
/// pass per plane. No f32 weight materialization, no allocation beyond
/// the (reused) scratch and the output tensor.
pub fn conv2d_quant(input: &Tensor, layer: &QuantDense, stride: usize,
                    relu: bool, threads: usize,
                    scratch: &mut Im2colScratch) -> Tensor {
    let (h_out, _) = same_pad(input.h, layer.kh, stride);
    let (w_out, _) = same_pad(input.w, layer.kw, stride);
    let mut out = Tensor::zeros(layer.cout, h_out, w_out);
    conv2d_quant_into(input.view(), layer, stride, relu, threads, scratch,
                      &mut out.data);
    out
}

/// [`conv2d_quant`] writing into a preassigned output buffer.
pub fn conv2d_quant_into(input: TensorView<'_>, layer: &QuantDense,
                         stride: usize, relu: bool, threads: usize,
                         scratch: &mut Im2colScratch, out: &mut [f32]) {
    let (h_out, w_out) = im2col_patches(BatchView::of_single(input),
                                        layer.kh, layer.kw, layer.cin,
                                        stride, scratch);
    let hw = h_out * w_out;
    let kdim = layer.cin * layer.kh * layer.kw;
    let cols: &[f32] = &scratch.buf;
    assert_eq!(out.len(), layer.cout * hw, "output buffer size mismatch");
    threadpool::parallel_chunks_mut(out, hw, threads, |co, plane| {
        plane.fill(0.0);
        let wrow = &layer.weights[co * kdim..(co + 1) * kdim];
        for (k, &qw) in wrow.iter().enumerate() {
            if qw == 0 {
                continue;
            }
            let w = qw as f32;
            // Tier-dispatched AXPY: the dequantized weight broadcasts
            // against the patch row (AVX2 FMA on the SIMD tier).
            axpy(plane, &cols[k * hw..(k + 1) * hw], w);
        }
        let scale = layer.scales[co];
        let bias = layer.bias[co];
        for v in plane.iter_mut() {
            let x = scale * *v + bias;
            *v = if relu { x.max(0.0) } else { x };
        }
    });
}

/// Fused batched weight-only-int8 conv: the i8 filter rows are decoded
/// exactly once per batch and each surviving weight streams through an
/// AXPY over the whole `[n*hw]` patch row; scale + bias fuse during the
/// scatter into `[n][cout][hw]`. Bit-identical per image to
/// [`conv2d_quant_into`] on that image alone.
pub fn conv2d_quant_batch_into(input: BatchView<'_>, layer: &QuantDense,
                               stride: usize, relu: bool, threads: usize,
                               scratch: &mut Im2colScratch,
                               out: &mut [f32]) {
    let n = input.n;
    let (h_out, w_out) = im2col_patches(input, layer.kh, layer.kw,
                                        layer.cin, stride, scratch);
    let hw = h_out * w_out;
    let nhw = n * hw;
    let kdim = layer.cin * layer.kh * layer.kw;
    assert_eq!(out.len(), n * layer.cout * hw,
               "output buffer size mismatch");
    scratch.acc.clear();
    scratch.acc.resize(layer.cout * nhw, 0.0);
    let cols: &[f32] = &scratch.buf;
    threadpool::parallel_chunks_mut(
        &mut scratch.acc, nhw, threads, |co, plane| {
            let wrow = &layer.weights[co * kdim..(co + 1) * kdim];
            for (k, &qw) in wrow.iter().enumerate() {
                if qw == 0 {
                    continue;
                }
                let w = qw as f32;
                axpy(plane, &cols[k * nhw..(k + 1) * nhw], w);
            }
        },
    );
    scatter_batch(&scratch.acc, out, n, layer.cout, hw, relu, |v, co| {
        layer.scales[co] * v + layer.bias[co]
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::naive;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn matches_naive_across_shapes() {
        prop::check("im2col-vs-naive", 20, |g| {
            let cin = g.usize(1, 6);
            let cout = g.usize(1, 8);
            let h = g.usize(3, 12);
            let w = g.usize(3, 12);
            let k = *g.pick(&[1usize, 3]);
            let stride = *g.pick(&[1usize, 2]);
            let mut rng = g.rng().clone();
            let input = Tensor::random(cin, h, w, &mut rng);
            let layer = DenseLayer {
                cout,
                cin,
                kh: k,
                kw: k,
                weights: (0..cout * cin * k * k)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let a = naive::conv2d(&input, &layer, stride, false, 1);
            let mut scratch = Im2colScratch::default();
            let b = conv2d(&input, &layer, stride, false, 2, &mut scratch);
            if a.max_abs_diff(&b) > 1e-4 {
                return Err(format!("diff {}", a.max_abs_diff(&b)));
            }
            Ok(())
        });
    }

    #[test]
    fn scratch_reuse_is_clean() {
        let mut rng = Rng::seed_from(8);
        let input = Tensor::random(3, 8, 8, &mut rng);
        let big = DenseLayer {
            cout: 4,
            cin: 3,
            kh: 3,
            kw: 3,
            weights: (0..4 * 3 * 9).map(|_| rng.normal_f32()).collect(),
            bias: vec![0.0; 4],
        };
        let mut scratch = Im2colScratch::default();
        let first = conv2d(&input, &big, 1, false, 1, &mut scratch);
        // run a smaller conv in between (shrinks logical buffer)
        let small = DenseLayer {
            cout: 2,
            cin: 3,
            kh: 1,
            kw: 1,
            weights: (0..2 * 3).map(|_| rng.normal_f32()).collect(),
            bias: vec![0.0; 2],
        };
        let _ = conv2d(&input, &small, 1, false, 1, &mut scratch);
        let again = conv2d(&input, &big, 1, false, 1, &mut scratch);
        assert!(first.max_abs_diff(&again) < 1e-6);
    }

    #[test]
    fn batch_matches_per_image_bitwise() {
        prop::check("im2col-batch-vs-single", 20, |g| {
            let n = g.usize(1, 5);
            let cin = g.usize(1, 5);
            let cout = g.usize(1, 7);
            let h = g.usize(3, 10);
            let w = g.usize(3, 10);
            let k = *g.pick(&[1usize, 3]);
            let stride = *g.pick(&[1usize, 2]);
            let relu = g.bool();
            let mut rng = g.rng().clone();
            let layer = DenseLayer {
                cout,
                cin,
                kh: k,
                kw: k,
                weights: (0..cout * cin * k * k)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let images: Vec<Tensor> = (0..n)
                .map(|_| Tensor::random(cin, h, w, &mut rng))
                .collect();
            let mut packed = Vec::new();
            for t in &images {
                packed.extend_from_slice(&t.data);
            }
            let view = crate::exec::tensor::BatchView::new(
                n, cin, h, w, &packed);
            let q = crate::quant::QuantDense::quantize(&layer);
            let per = {
                let (ho, _) = same_pad(h, k, stride);
                let (wo, _) = same_pad(w, k, stride);
                cout * ho * wo
            };
            let mut scratch = Im2colScratch::default();
            let mut got = vec![0f32; n * per];
            conv2d_batch_into(view, &layer, stride, relu, 2,
                              &mut scratch, &mut got);
            let mut got_q = vec![0f32; n * per];
            conv2d_quant_batch_into(view, &q, stride, relu, 2,
                                    &mut scratch, &mut got_q);
            for (i, t) in images.iter().enumerate() {
                let mut want = vec![0f32; per];
                conv2d_into(t.view(), &layer, stride, relu, 1,
                            &mut scratch, &mut want);
                if got[i * per..(i + 1) * per] != want[..] {
                    return Err(format!("f32 batch diverged at image {i}"));
                }
                let mut want_q = vec![0f32; per];
                conv2d_quant_into(t.view(), &q, stride, relu, 1,
                                  &mut scratch, &mut want_q);
                if got_q[i * per..(i + 1) * per] != want_q[..] {
                    return Err(format!("quant batch diverged at {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn packed_conv_bit_matches_im2col_conv() {
        // On the SIMD tier both paths run the identical packed kernel
        // (per-call pack vs compile-time pack of the same weights); on
        // the scalar tier the packed entry falls back to the plain
        // path. Either way: bit-identical, single and batched.
        prop::check("im2col-packed-vs-plain", 15, |g| {
            let n = g.usize(1, 4);
            let cin = g.usize(1, 5);
            let cout = g.usize(1, 9);
            let h = g.usize(3, 10);
            let w = g.usize(3, 10);
            let k = *g.pick(&[1usize, 3]);
            let stride = *g.pick(&[1usize, 2]);
            let relu = g.bool();
            let mut rng = g.rng().clone();
            let layer = DenseLayer {
                cout,
                cin,
                kh: k,
                kw: k,
                weights: (0..cout * cin * k * k)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let pack = micro::PackedA::pack(&layer.weights, cout,
                                            cin * k * k);
            let per = {
                let (ho, _) = same_pad(h, k, stride);
                let (wo, _) = same_pad(w, k, stride);
                cout * ho * wo
            };
            let mut scratch = Im2colScratch::default();
            let images: Vec<Tensor> = (0..n)
                .map(|_| Tensor::random(cin, h, w, &mut rng))
                .collect();
            let mut flat = Vec::new();
            for t in &images {
                flat.extend_from_slice(&t.data);
            }
            let view = crate::exec::tensor::BatchView::new(
                n, cin, h, w, &flat);
            let mut want_b = vec![0f32; n * per];
            conv2d_batch_into(view, &layer, stride, relu, 2,
                              &mut scratch, &mut want_b);
            let view = crate::exec::tensor::BatchView::new(
                n, cin, h, w, &flat);
            let mut got_b = vec![0f32; n * per];
            conv2d_packed_batch_into(view, &layer, &pack, stride, relu,
                                     2, &mut scratch, &mut got_b);
            if got_b != want_b {
                return Err("packed batch diverged from im2col".into());
            }
            for (i, t) in images.iter().enumerate() {
                let mut want = vec![0f32; per];
                conv2d_into(t.view(), &layer, stride, relu, 1,
                            &mut scratch, &mut want);
                let mut got = vec![0f32; per];
                conv2d_packed_into(t.view(), &layer, &pack, stride,
                                   relu, 1, &mut scratch, &mut got);
                if got != want {
                    return Err(format!(
                        "packed single diverged at image {i}"));
                }
                if got_b[i * per..(i + 1) * per] != got[..] {
                    return Err(format!(
                        "packed batch != packed single at {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quant_matches_naive_quant_across_shapes() {
        // Both engines compute s*sum(q*x)+b; only f32 summation order
        // differs, so the agreement tolerance is tight.
        prop::check("im2col-quant-vs-naive-quant", 20, |g| {
            let cin = g.usize(1, 6);
            let cout = g.usize(1, 8);
            let h = g.usize(3, 12);
            let w = g.usize(3, 12);
            let k = *g.pick(&[1usize, 3]);
            let stride = *g.pick(&[1usize, 2]);
            let relu = g.bool();
            let mut rng = g.rng().clone();
            let input = Tensor::random(cin, h, w, &mut rng);
            let layer = DenseLayer {
                cout,
                cin,
                kh: k,
                kw: k,
                weights: (0..cout * cin * k * k)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let q = crate::quant::QuantDense::quantize(&layer);
            let a = naive::conv2d_quant(&input, &q, stride, relu, 1);
            let mut scratch = Im2colScratch::default();
            let b = conv2d_quant(&input, &q, stride, relu, 2, &mut scratch);
            let scale = a.data.iter().fold(0f32, |m, v| m.max(v.abs()));
            if a.max_abs_diff(&b) > 1e-3 * scale.max(1.0) {
                return Err(format!("diff {}", a.max_abs_diff(&b)));
            }
            Ok(())
        });
    }
}
