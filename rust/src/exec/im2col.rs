//! im2col + GEMM convolution — the "optimizing-compiler" dense baseline
//! (stands in for TVM's default CPU conv lowering in Fig. 5).

use crate::compress::DenseLayer;
use crate::exec::gemm::gemm;
use crate::exec::tensor::{same_pad, Tensor, TensorView};
use crate::quant::QuantDense;
use crate::util::threadpool;

/// Scratch buffer reused across layers to avoid re-allocating the im2col
/// matrix per call (part of the fair-baseline treatment).
#[derive(Default)]
pub struct Im2colScratch {
    buf: Vec<f32>,
}

/// Fill `scratch` with the `[K][HW]` patch matrix for a (kh, kw, cin)
/// kernel over `input`; returns the output geometry. Shared by the f32
/// and the weight-only-int8 GEMM paths.
fn im2col_patches(input: TensorView<'_>, kh: usize, kw: usize, cin: usize,
                  stride: usize, scratch: &mut Im2colScratch)
                  -> (usize, usize) {
    let (h_out, pad_h) = same_pad(input.h, kh, stride);
    let (w_out, pad_w) = same_pad(input.w, kw, stride);
    let hw = h_out * w_out;
    let kdim = cin * kh * kw;
    scratch.buf.clear();
    scratch.buf.resize(kdim * hw, 0.0);
    let cols = &mut scratch.buf;
    for ci in 0..cin {
        let plane = input.plane(ci);
        for ky in 0..kh {
            for kx in 0..kw {
                let krow = (ci * kh + ky) * kw + kx;
                let dst = &mut cols[krow * hw..(krow + 1) * hw];
                for y in 0..h_out {
                    let iy = (y * stride + ky) as isize - pad_h as isize;
                    if iy < 0 || iy >= input.h as isize {
                        continue; // stays zero
                    }
                    let src_row =
                        &plane[iy as usize * input.w..(iy as usize + 1)
                            * input.w];
                    let dst_row = &mut dst[y * w_out..(y + 1) * w_out];
                    if stride == 1 {
                        // contiguous copy with border clamp
                        let x_lo = pad_w.saturating_sub(kx);
                        let x_hi =
                            (input.w + pad_w - kx).min(w_out);
                        if x_lo < x_hi {
                            let src_lo = x_lo + kx - pad_w;
                            dst_row[x_lo..x_hi].copy_from_slice(
                                &src_row[src_lo..src_lo + (x_hi - x_lo)],
                            );
                        }
                    } else {
                        for (x, d) in dst_row.iter_mut().enumerate() {
                            let ix = (x * stride + kx) as isize
                                - pad_w as isize;
                            if ix >= 0 && (ix as usize) < input.w {
                                *d = src_row[ix as usize];
                            }
                        }
                    }
                }
            }
        }
    }
    (h_out, w_out)
}

/// Dense conv via im2col + GEMM, SAME padding, optional fused ReLU.
pub fn conv2d(input: &Tensor, layer: &DenseLayer, stride: usize, relu: bool,
              threads: usize, scratch: &mut Im2colScratch) -> Tensor {
    let (h_out, _) = same_pad(input.h, layer.kh, stride);
    let (w_out, _) = same_pad(input.w, layer.kw, stride);
    let mut out = Tensor::zeros(layer.cout, h_out, w_out);
    conv2d_into(input.view(), layer, stride, relu, threads, scratch,
                &mut out.data);
    out
}

/// [`conv2d`] writing into a preassigned output buffer (arena slot);
/// allocation-free once `scratch` has warmed to the layer's patch size.
pub fn conv2d_into(input: TensorView<'_>, layer: &DenseLayer,
                   stride: usize, relu: bool, threads: usize,
                   scratch: &mut Im2colScratch, out: &mut [f32]) {
    let (h_out, w_out) = im2col_patches(input, layer.kh, layer.kw,
                                        layer.cin, stride, scratch);
    let hw = h_out * w_out;
    let kdim = layer.cin * layer.kh * layer.kw;
    let cols = &scratch.buf;
    assert_eq!(out.len(), layer.cout * hw, "output buffer size mismatch");

    // C[cout][HW] = W[cout][K] x cols[K][HW]
    for co in 0..layer.cout {
        out[co * hw..(co + 1) * hw].fill(layer.bias[co]);
    }
    gemm(&layer.weights, cols, out, layer.cout, kdim, hw, threads);
    if relu {
        for v in out.iter_mut() {
            *v = v.max(0.0);
        }
    }
}

/// Weight-only int8 conv via im2col: the f32 patch matrix is shared with
/// the dense path, but each filter row stays i8 — every weight is loaded
/// as an integer, widened in-register, and streamed through an AXPY over
/// the patch rows; the per-channel scale and bias are fused in one final
/// pass per plane. No f32 weight materialization, no allocation beyond
/// the (reused) scratch and the output tensor.
pub fn conv2d_quant(input: &Tensor, layer: &QuantDense, stride: usize,
                    relu: bool, threads: usize,
                    scratch: &mut Im2colScratch) -> Tensor {
    let (h_out, _) = same_pad(input.h, layer.kh, stride);
    let (w_out, _) = same_pad(input.w, layer.kw, stride);
    let mut out = Tensor::zeros(layer.cout, h_out, w_out);
    conv2d_quant_into(input.view(), layer, stride, relu, threads, scratch,
                      &mut out.data);
    out
}

/// [`conv2d_quant`] writing into a preassigned output buffer.
pub fn conv2d_quant_into(input: TensorView<'_>, layer: &QuantDense,
                         stride: usize, relu: bool, threads: usize,
                         scratch: &mut Im2colScratch, out: &mut [f32]) {
    let (h_out, w_out) = im2col_patches(input, layer.kh, layer.kw,
                                        layer.cin, stride, scratch);
    let hw = h_out * w_out;
    let kdim = layer.cin * layer.kh * layer.kw;
    let cols: &[f32] = &scratch.buf;
    assert_eq!(out.len(), layer.cout * hw, "output buffer size mismatch");
    threadpool::parallel_chunks_mut(out, hw, threads, |co, plane| {
        plane.fill(0.0);
        let wrow = &layer.weights[co * kdim..(co + 1) * kdim];
        for (k, &qw) in wrow.iter().enumerate() {
            if qw == 0 {
                continue;
            }
            let w = qw as f32;
            let src = &cols[k * hw..(k + 1) * hw];
            for (o, i) in plane.iter_mut().zip(src.iter()) {
                *o += w * *i;
            }
        }
        let scale = layer.scales[co];
        let bias = layer.bias[co];
        for v in plane.iter_mut() {
            let x = scale * *v + bias;
            *v = if relu { x.max(0.0) } else { x };
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::naive;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn matches_naive_across_shapes() {
        prop::check("im2col-vs-naive", 20, |g| {
            let cin = g.usize(1, 6);
            let cout = g.usize(1, 8);
            let h = g.usize(3, 12);
            let w = g.usize(3, 12);
            let k = *g.pick(&[1usize, 3]);
            let stride = *g.pick(&[1usize, 2]);
            let mut rng = g.rng().clone();
            let input = Tensor::random(cin, h, w, &mut rng);
            let layer = DenseLayer {
                cout,
                cin,
                kh: k,
                kw: k,
                weights: (0..cout * cin * k * k)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let a = naive::conv2d(&input, &layer, stride, false, 1);
            let mut scratch = Im2colScratch::default();
            let b = conv2d(&input, &layer, stride, false, 2, &mut scratch);
            if a.max_abs_diff(&b) > 1e-4 {
                return Err(format!("diff {}", a.max_abs_diff(&b)));
            }
            Ok(())
        });
    }

    #[test]
    fn scratch_reuse_is_clean() {
        let mut rng = Rng::seed_from(8);
        let input = Tensor::random(3, 8, 8, &mut rng);
        let big = DenseLayer {
            cout: 4,
            cin: 3,
            kh: 3,
            kw: 3,
            weights: (0..4 * 3 * 9).map(|_| rng.normal_f32()).collect(),
            bias: vec![0.0; 4],
        };
        let mut scratch = Im2colScratch::default();
        let first = conv2d(&input, &big, 1, false, 1, &mut scratch);
        // run a smaller conv in between (shrinks logical buffer)
        let small = DenseLayer {
            cout: 2,
            cin: 3,
            kh: 1,
            kw: 1,
            weights: (0..2 * 3).map(|_| rng.normal_f32()).collect(),
            bias: vec![0.0; 2],
        };
        let _ = conv2d(&input, &small, 1, false, 1, &mut scratch);
        let again = conv2d(&input, &big, 1, false, 1, &mut scratch);
        assert!(first.max_abs_diff(&again) < 1e-6);
    }

    #[test]
    fn quant_matches_naive_quant_across_shapes() {
        // Both engines compute s*sum(q*x)+b; only f32 summation order
        // differs, so the agreement tolerance is tight.
        prop::check("im2col-quant-vs-naive-quant", 20, |g| {
            let cin = g.usize(1, 6);
            let cout = g.usize(1, 8);
            let h = g.usize(3, 12);
            let w = g.usize(3, 12);
            let k = *g.pick(&[1usize, 3]);
            let stride = *g.pick(&[1usize, 2]);
            let relu = g.bool();
            let mut rng = g.rng().clone();
            let input = Tensor::random(cin, h, w, &mut rng);
            let layer = DenseLayer {
                cout,
                cin,
                kh: k,
                kw: k,
                weights: (0..cout * cin * k * k)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let q = crate::quant::QuantDense::quantize(&layer);
            let a = naive::conv2d_quant(&input, &q, stride, relu, 1);
            let mut scratch = Im2colScratch::default();
            let b = conv2d_quant(&input, &q, stride, relu, 2, &mut scratch);
            let scale = a.data.iter().fold(0f32, |m, v| m.max(v.abs()));
            if a.max_abs_diff(&b) > 1e-3 * scale.max(1.0) {
                return Err(format!("diff {}", a.max_abs_diff(&b)));
            }
            Ok(())
        });
    }
}
