//! Native executors: run an ExecPlan (IR + per-layer weights/strategy)
//! over planar NCHW tensors. The engines implement the Fig. 5 framework
//! axis and are validated against each other by property tests.
//!
//! Execution is ahead-of-time compiled: `codegen::lower` turns the plan
//! into a `CompiledPipeline` (per-layer kernel choice, bound weights,
//! preassigned arena slots) exactly once; [`ModelExecutor::run`] is a
//! flat walk over the compiled ops with zero per-layer dispatch and no
//! activation allocation beyond its arena.

pub mod csr;
pub mod gemm;
pub mod im2col;
pub mod micro;
pub mod naive;
pub mod ops;
pub mod pattern;
pub mod tensor;
pub mod winograd;

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::codegen::{Arena, CompiledPipeline, ExecPlan};
use crate::util::threadpool;
pub use tensor::{BatchView, Tensor, TensorView};

/// Reusable engine scratch owned by one executor: the im2col patch
/// matrix, the Winograd input/product buffers, and the pattern-GEMM
/// shifted-input matrix. All warm to their steady-state sizes on the
/// first inference and are never reallocated after.
#[derive(Default)]
pub struct ExecScratch {
    pub im2col: im2col::Im2colScratch,
    pub wino_u: Vec<f32>,
    pub wino_m: Vec<f32>,
    pub gemm_u: Vec<f32>,
}

/// Stateful model executor: a compiled pipeline plus the mutable state
/// one inference stream needs (activation arena + engine scratch).
///
/// Owns no reference to the `ExecPlan` it was compiled from — the
/// pipeline's ops hold `Arc`s to every weight they bind, so the
/// executor is `Send + 'static` and serving workers can own one across
/// threads while each weight tensor exists once per process.
pub struct ModelExecutor {
    pub threads: usize,
    pipeline: Arc<CompiledPipeline>,
    arena: Arena,
    scratch: ExecScratch,
    /// Reusable packing buffer for fused batches (`[N][C][H][W]`).
    batch_in: Vec<f32>,
}

impl ModelExecutor {
    /// Compile `plan` and build an executor for it. The plan is not
    /// retained; the pipeline keeps the bound weights alive.
    pub fn new(plan: &ExecPlan, threads: usize) -> ModelExecutor {
        Self::with_pipeline(Arc::new(plan.compile()), threads)
    }

    /// Compile `plan` with a leading batch dimension of `max_batch` and
    /// build an executor whose [`ModelExecutor::run_batch`] is a *fused*
    /// walk: one pass over the compiled ops per batch, every layer's
    /// weights decoded/streamed once per batch. Single-image
    /// [`ModelExecutor::run`] still works (and stays bit-identical); the
    /// arena is `max_batch` times the single-image footprint.
    pub fn new_batched(plan: &ExecPlan, threads: usize, max_batch: usize)
                       -> ModelExecutor {
        Self::with_pipeline(
            Arc::new(plan.compile_batched(max_batch.max(1))),
            threads,
        )
    }

    /// Executor over a shared plan (convenience for callers holding an
    /// `Arc<ExecPlan>`; equivalent to [`ModelExecutor::new`]).
    pub fn shared(plan: Arc<ExecPlan>, threads: usize) -> ModelExecutor {
        Self::new(&plan, threads)
    }

    /// Executor over a pipeline compiled elsewhere (an `ExecutorPool`
    /// lowers once and hands every slot the same `Arc`).
    pub fn with_pipeline(pipeline: Arc<CompiledPipeline>, threads: usize)
                         -> ModelExecutor {
        let arena = Arena::for_pipeline(&pipeline);
        ModelExecutor {
            threads,
            pipeline,
            arena,
            scratch: ExecScratch::default(),
            batch_in: Vec::new(),
        }
    }

    /// The compiled op pipeline (kernel choices, slot assignment).
    pub fn pipeline(&self) -> &CompiledPipeline {
        &self.pipeline
    }

    /// Resident bytes of the activation arena. Constant across runs —
    /// the regression guard the arena-reuse tests assert on.
    pub fn arena_bytes(&self) -> usize {
        self.arena.bytes()
    }

    /// Run a batch of inputs, preserving order.
    ///
    /// On a batch-compiled executor ([`ModelExecutor::new_batched`])
    /// this is a *fused* walk: the batch packs into one `[N][C][H][W]`
    /// buffer and each compiled op serves every image in a single
    /// kernel call, so per-layer weight traffic is paid once per batch
    /// (batches larger than the compiled cap run in cap-sized fused
    /// chunks). On a single-image pipeline it degrades to a sequential
    /// per-image loop. Either way, every output is bit-identical to
    /// [`ModelExecutor::run`] on that input alone. For parallel
    /// fan-out across cores use [`ExecutorPool`].
    pub fn run_batch(&mut self, inputs: &[Tensor]) -> Vec<Tensor> {
        let cap = self.pipeline.max_batch();
        if cap <= 1 {
            return inputs.iter().map(|x| self.run(x)).collect();
        }
        let mut out = Vec::with_capacity(inputs.len());
        for chunk in inputs.chunks(cap) {
            if chunk.len() == 1 {
                out.push(self.run(&chunk[0]));
                continue;
            }
            self.batch_in.clear();
            for t in chunk {
                assert_eq!(t.shape(), self.pipeline.input,
                           "input shape mismatch");
                self.batch_in.extend_from_slice(&t.data);
            }
            out.extend(self.pipeline.execute_batched(
                chunk.len(),
                &self.batch_in,
                &mut self.arena,
                &mut self.scratch,
                self.threads,
            ));
        }
        out
    }

    /// [`ModelExecutor::run_batch`] over a pre-packed `[N][C][H][W]`
    /// buffer — the zero-copy serving entry point: callers that already
    /// hold (or can convert straight into) the packed layout skip the
    /// per-image `Tensor` intermediates and the second pack copy.
    /// Batches above the compiled cap run in cap-sized fused chunks;
    /// results are bit-identical to [`ModelExecutor::run`] per image.
    pub fn run_batch_packed(&mut self, n: usize, input: &[f32])
                            -> Vec<Tensor> {
        let per = self.pipeline.input.elements();
        assert_eq!(input.len(), n * per, "packed batch length mismatch");
        let cap = self.pipeline.max_batch().max(1);
        let mut out = Vec::with_capacity(n);
        for start in (0..n).step_by(cap) {
            let m = cap.min(n - start);
            out.extend(self.pipeline.execute_batched(
                m,
                &input[start * per..(start + m) * per],
                &mut self.arena,
                &mut self.scratch,
                self.threads,
            ));
        }
        out
    }

    /// Run one input through the model; returns the final tensor.
    ///
    /// This is a straight walk over the compiled ops: dispatch happened
    /// once, at lowering, and every intermediate activation lives in a
    /// preassigned arena slot.
    pub fn run(&mut self, input: &Tensor) -> Tensor {
        self.pipeline
            .execute(input, &mut self.arena, &mut self.scratch,
                     self.threads)
    }
}

/// Elastic sizing for an [`ExecutorPool`]: the active-slot count moves
/// between `floor` and `max`, driven by queue-depth watermarks with
/// consecutive-observation hysteresis so bursty depth readings don't
/// thrash the pool. Slots are all allocated up front (scaling never
/// recompiles or reallocates); scaling only changes how many may be
/// claimed concurrently.
#[derive(Debug, Clone, Copy)]
pub struct ElasticConfig {
    /// Minimum active slots (the scale-down target); at least 1.
    pub floor: usize,
    /// Maximum active slots (the scale-up ceiling).
    pub max: usize,
    /// Scale up one slot after `hysteresis` consecutive observations
    /// at or above this queue depth.
    pub high: usize,
    /// Scale down one slot after `hysteresis` consecutive observations
    /// at or below this depth; must be below `high` (the dead zone
    /// between the watermarks is what prevents thrash).
    pub low: usize,
    /// Consecutive same-side observations required before either move.
    pub hysteresis: usize,
}

/// One elastic resize of an [`ExecutorPool`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleEvent {
    /// The queue depth observed at the watermark crossing.
    pub depth: usize,
    /// Active slots before the move.
    pub from: usize,
    /// Active slots after the move.
    pub to: usize,
}

/// Shared append-only record of a pool's scale events — the handle a
/// test or operator keeps after the pool itself is consumed by a
/// serving backend ([`crate::coordinator::NativeBackend`]).
#[derive(Default)]
pub struct ScaleLog {
    events: Mutex<Vec<ScaleEvent>>,
}

impl ScaleLog {
    pub fn new() -> Arc<ScaleLog> {
        Arc::new(ScaleLog::default())
    }
    fn push(&self, e: ScaleEvent) {
        self.events.lock().unwrap().push(e);
    }
    /// Every scale event so far, in occurrence order.
    pub fn events(&self) -> Vec<ScaleEvent> {
        self.events.lock().unwrap().clone()
    }
}

/// Watermark streak state of an elastic pool.
#[derive(Default)]
struct Streaks {
    high: usize,
    low: usize,
}

struct Elastic {
    cfg: ElasticConfig,
    state: Mutex<Streaks>,
    log: Arc<ScaleLog>,
}

/// A fixed pool of [`ModelExecutor`] workers sharing one compiled
/// pipeline: the plan is lowered exactly once per pool ("compile once,
/// serve everywhere") and the pipeline's `Arc`-bound weights exist once
/// per process no matter how many slots serve them.
///
/// Each slot owns its executor (and thus its arena + scratch), so a
/// batch fans out across cores without cloning weights or re-allocating
/// buffers. Executors run single-threaded (`threads = 1`): parallelism
/// comes from running pool slots concurrently, which keeps per-image
/// numerics bit-identical to a sequential `ModelExecutor::run` — the
/// property the serving tests assert, and the reason an elastic pool's
/// results cannot depend on its size.
///
/// Free slots live in a Condvar-blocked index queue: a claimer with no
/// free slot *parks* until one is released instead of burning a core in
/// a yield loop — pools shared across concurrent `run_batch` callers
/// (several serving coordinators, tests) routinely oversubscribe.
///
/// An *elastic* pool ([`ExecutorPool::new_elastic`]) additionally
/// bounds concurrent claims to its live `active` count, which
/// [`ExecutorPool::observe_queue_depth`] moves between the configured
/// floor and max at watermark crossings.
pub struct ExecutorPool {
    slots: Vec<Mutex<ModelExecutor>>,
    /// Indices of currently-free slots.
    free: Mutex<Vec<usize>>,
    available: Condvar,
    /// Diagnostic: times a claimer had to park on the condvar (each
    /// increment is one blocking wait, not a spin iteration).
    waits: AtomicUsize,
    /// Slots currently claimable (`slots.len()` for fixed pools).
    active: AtomicUsize,
    elastic: Option<Elastic>,
}

/// An exclusively-claimed pool slot; releases its index (and wakes one
/// parked claimer) on drop.
struct PoolSlot<'a> {
    exec: Option<MutexGuard<'a, ModelExecutor>>,
    index: usize,
    pool: &'a ExecutorPool,
}

impl Deref for PoolSlot<'_> {
    type Target = ModelExecutor;
    fn deref(&self) -> &Self::Target {
        self.exec.as_ref().unwrap()
    }
}

impl DerefMut for PoolSlot<'_> {
    fn deref_mut(&mut self) -> &mut Self::Target {
        self.exec.as_mut().unwrap()
    }
}

impl Drop for PoolSlot<'_> {
    fn drop(&mut self) {
        // Unlock the slot before its index becomes claimable again.
        self.exec.take();
        self.pool.free.lock().unwrap().push(self.index);
        self.pool.available.notify_one();
    }
}

impl ExecutorPool {
    /// Pool with `workers` executor slots (clamped to at least 1) over a
    /// shared plan, lowered once. Serving backends size this to one slot
    /// per core via `util::threadpool::default_threads`.
    pub fn new(plan: Arc<ExecPlan>, workers: usize) -> ExecutorPool {
        let workers = workers.max(1);
        let pipeline = Arc::new(plan.compile());
        ExecutorPool {
            slots: (0..workers)
                .map(|_| {
                    Mutex::new(ModelExecutor::with_pipeline(
                        pipeline.clone(),
                        1,
                    ))
                })
                .collect(),
            free: Mutex::new((0..workers).collect()),
            available: Condvar::new(),
            waits: AtomicUsize::new(0),
            active: AtomicUsize::new(workers),
            elastic: None,
        }
    }

    /// An elastic pool: `cfg.max` slots allocated up front (one lowered
    /// pipeline shared by all), `cfg.floor` of them active initially.
    /// [`ExecutorPool::observe_queue_depth`] grows and shrinks the
    /// active count at watermark crossings; every resize is appended to
    /// `log`, the handle callers keep for observing scale decisions
    /// after the pool is consumed by a serving backend.
    pub fn new_elastic(plan: Arc<ExecPlan>, cfg: ElasticConfig,
                       log: Arc<ScaleLog>) -> ExecutorPool {
        assert!(cfg.floor >= 1, "elastic floor must be at least 1");
        assert!(cfg.max >= cfg.floor,
                "elastic max ({}) below floor ({})", cfg.max, cfg.floor);
        assert!(cfg.low < cfg.high,
                "elastic watermarks must satisfy low < high");
        assert!(cfg.hysteresis >= 1, "hysteresis must be at least 1");
        let mut pool = ExecutorPool::new(plan, cfg.max);
        pool.active = AtomicUsize::new(cfg.floor);
        pool.elastic = Some(Elastic {
            cfg,
            state: Mutex::new(Streaks::default()),
            log,
        });
        pool
    }

    /// Number of executor slots (the elastic ceiling for elastic
    /// pools).
    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Slots currently claimable. Equals [`ExecutorPool::workers`] for
    /// fixed pools; moves between the configured floor and max for
    /// elastic ones.
    pub fn active_workers(&self) -> usize {
        self.active.load(Ordering::SeqCst).clamp(1, self.slots.len())
    }

    /// Feed one queue-depth observation to the elastic controller
    /// (no-op on fixed pools). After `hysteresis` *consecutive*
    /// observations at or above `high` the pool activates one more
    /// slot (up to `max`); after `hysteresis` consecutive observations
    /// at or below `low` it retires one (down to `floor`). A reading
    /// in the dead zone between the watermarks resets both streaks, so
    /// only sustained pressure — not a burst — moves the pool.
    pub fn observe_queue_depth(&self, depth: usize) {
        let Some(el) = &self.elastic else { return };
        let mut st = el.state.lock().unwrap();
        let active = self.active.load(Ordering::SeqCst);
        if depth >= el.cfg.high && active < el.cfg.max {
            st.high += 1;
            st.low = 0;
            if st.high >= el.cfg.hysteresis {
                st.high = 0;
                self.active.store(active + 1, Ordering::SeqCst);
                el.log.push(ScaleEvent {
                    depth,
                    from: active,
                    to: active + 1,
                });
                // Claimers may be parked with free-but-inactive slots
                // available; the new active bound admits them.
                self.available.notify_all();
            }
        } else if depth <= el.cfg.low && active > el.cfg.floor {
            st.low += 1;
            st.high = 0;
            if st.low >= el.cfg.hysteresis {
                st.low = 0;
                self.active.store(active - 1, Ordering::SeqCst);
                el.log.push(ScaleEvent {
                    depth,
                    from: active,
                    to: active - 1,
                });
            }
        } else {
            st.high = 0;
            st.low = 0;
        }
    }

    /// How many times a claimer has blocked waiting for a slot. Bounded
    /// by the number of oversubscribed claims (plus spurious wakeups) —
    /// the regression guard against reintroducing a spin loop, whose
    /// equivalent count grows with *wait time*, not claim count.
    pub fn wait_count(&self) -> usize {
        self.waits.load(Ordering::Relaxed)
    }

    /// Claim a free executor slot, parking on the condvar while all are
    /// busy. Within one `run_batch` call concurrency is capped at
    /// `workers()`, so waiting only happens when multiple callers share
    /// the pool.
    fn claim(&self) -> PoolSlot<'_> {
        let mut free = self.free.lock().unwrap();
        let index = loop {
            // Only indices below the live active bound are claimable:
            // a scaled-down elastic pool leaves its retired slots in
            // the free list but never hands them out, and a scale-up
            // (which re-checks here after notify_all) re-admits them.
            let active = self.active.load(Ordering::SeqCst);
            if let Some(pos) = free.iter().rposition(|&i| i < active) {
                break free.swap_remove(pos);
            }
            self.waits.fetch_add(1, Ordering::Relaxed);
            free = self.available.wait(free).unwrap();
        };
        drop(free);
        // The index is exclusively ours, so the slot mutex is free (a
        // dropping PoolSlot unlocks before returning its index); lock()
        // only recovers a poisoned guard after a panicked run.
        let exec = match self.slots[index].lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        PoolSlot {
            exec: Some(exec),
            index,
            pool: self,
        }
    }

    /// Run every input through the model, fanning items out across the
    /// pool via `util::threadpool`. Outputs are in input order.
    pub fn run_batch(&self, inputs: &[Tensor]) -> Vec<Tensor> {
        threadpool::parallel_map(inputs.len(), self.active_workers(),
                                 |i| {
            Some(self.claim().run(&inputs[i]))
        })
        .into_iter()
        .map(|t| t.expect("pool worker produced no output"))
        .collect()
    }

    /// Like [`ExecutorPool::run_batch`], but item `i`'s input tensor is
    /// produced by `make(i)` on the claiming worker — so per-item prep
    /// (e.g. the serving path's NHWC→CHW layout conversion) runs in
    /// parallel with inference instead of serially before it.
    pub fn run_batch_map<F>(&self, n: usize, make: F) -> Vec<Tensor>
    where
        F: Fn(usize) -> Tensor + Sync,
    {
        threadpool::parallel_map(n, self.active_workers(), |i| {
            let input = make(i);
            Some(self.claim().run(&input))
        })
        .into_iter()
        .map(|t| t.expect("pool worker produced no output"))
        .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{build_plan, PruneConfig, Scheme};
    use crate::ir::zoo;
    use crate::ir::{Chw, IrBuilder};
    use crate::util::rng::Rng;

    fn tiny_ir() -> crate::ir::ModelIR {
        let mut b = IrBuilder::new("t", Chw::new(3, 12, 12));
        b.conv("c1", 3, 8, 1, true);
        let skip = b.last();
        b.conv("c2", 3, 8, 1, false)
            .add("a", skip, true)
            .conv("c3", 3, 16, 2, true)
            .maxpool("p")
            .gap("g")
            .dense("fc", 5, false);
        b.build().unwrap()
    }

    #[test]
    fn dense_naive_and_im2col_agree_end_to_end() {
        let ir = tiny_ir();
        let p1 = build_plan(&ir, Scheme::DenseNaive, PruneConfig::default(),
                            42);
        let p2 = build_plan(&ir, Scheme::DenseIm2col,
                            PruneConfig::default(), 42);
        let mut rng = Rng::seed_from(0);
        let x = Tensor::random(3, 12, 12, &mut rng);
        let a = ModelExecutor::new(&p1, 2).run(&x);
        let b = ModelExecutor::new(&p2, 2).run(&x);
        assert!(a.max_abs_diff(&b) < 1e-3, "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn cocogen_runs_and_is_finite() {
        let ir = tiny_ir();
        let p = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(), 42);
        let mut rng = Rng::seed_from(1);
        let x = Tensor::random(3, 12, 12, &mut rng);
        let out = ModelExecutor::new(&p, 2).run(&x);
        assert_eq!(out.c, 5);
        assert!(out.iter_finite());
    }

    #[test]
    fn csr_scheme_runs() {
        let ir = tiny_ir();
        let p = build_plan(&ir, Scheme::SparseCsr,
                           PruneConfig::default(), 42);
        let mut rng = Rng::seed_from(2);
        let x = Tensor::random(3, 12, 12, &mut rng);
        let out = ModelExecutor::new(&p, 2).run(&x);
        assert_eq!(out.c, 5);
        assert!(out.iter_finite());
    }

    #[test]
    fn winograd_scheme_runs_through_pretransformed_weights() {
        let ir = tiny_ir();
        let wino = build_plan(&ir, Scheme::DenseWinograd,
                              PruneConfig::default(), 42);
        let naive = build_plan(&ir, Scheme::DenseNaive,
                               PruneConfig::default(), 42);
        let mut rng = Rng::seed_from(8);
        let x = Tensor::random(3, 12, 12, &mut rng);
        let a = ModelExecutor::new(&wino, 2).run(&x);
        let b = ModelExecutor::new(&naive, 2).run(&x);
        assert!(a.max_abs_diff(&b) < 1e-3, "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn arena_is_reused_across_runs_without_growth() {
        let ir = tiny_ir();
        let p = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                           42);
        let mut exec = ModelExecutor::new(&p, 2);
        let mut rng = Rng::seed_from(11);
        let x1 = Tensor::random(3, 12, 12, &mut rng);
        let x2 = Tensor::random(3, 12, 12, &mut rng);
        let out1 = exec.run(&x1);
        let bytes = exec.arena_bytes();
        assert_eq!(bytes, p.peak_activation_bytes());
        // interleave a different input, then repeat the first: identical
        // results out of recycled buffers, no arena growth
        let _ = exec.run(&x2);
        let out1_again = exec.run(&x1);
        assert_eq!(out1.data, out1_again.data,
                   "stale arena contents leaked into a later run");
        assert_eq!(exec.arena_bytes(), bytes, "arena grew across runs");
    }

    #[test]
    fn pool_matches_sequential_bitwise() {
        let ir = tiny_ir();
        let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                              42)
            .into_shared();
        let pool = ExecutorPool::new(plan.clone(), 4);
        let mut rng = Rng::seed_from(9);
        let inputs: Vec<Tensor> = (0..10)
            .map(|_| Tensor::random(3, 12, 12, &mut rng))
            .collect();
        let outs = pool.run_batch(&inputs);
        let mut seq = ModelExecutor::new(&plan, 1);
        for (x, got) in inputs.iter().zip(&outs) {
            let want = seq.run(x);
            assert_eq!(want.data, got.data, "pool diverged from sequential");
        }
    }

    #[test]
    fn shared_executor_is_send() {
        fn assert_send<T: Send>(_: &T) {}
        let ir = tiny_ir();
        let plan = build_plan(&ir, Scheme::DenseIm2col,
                              PruneConfig::default(), 1)
            .into_shared();
        let exec = ModelExecutor::shared(plan, 2);
        assert_send(&exec);
    }

    #[test]
    fn fused_batch_matches_sequential_and_chunks_oversized() {
        let ir = tiny_ir();
        let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                              42);
        // cap 4, 10 inputs: 4 + 4 + 2 fused chunks
        let mut fused = ModelExecutor::new_batched(&plan, 2, 4);
        let mut seq = ModelExecutor::new(&plan, 2);
        let mut rng = Rng::seed_from(14);
        let inputs: Vec<Tensor> = (0..10)
            .map(|_| Tensor::random(3, 12, 12, &mut rng))
            .collect();
        let outs = fused.run_batch(&inputs);
        assert_eq!(outs.len(), inputs.len());
        for (x, got) in inputs.iter().zip(&outs) {
            let want = seq.run(x);
            assert_eq!(want.data, got.data,
                       "fused batch diverged from sequential run");
        }
        // single-image run on the batch-compiled executor also agrees
        let a = fused.run(&inputs[0]);
        let b = seq.run(&inputs[0]);
        assert_eq!(a.data, b.data);
        assert!(fused.run_batch(&[]).is_empty());
        // the packed zero-copy entry point matches the Tensor-slice one
        let mut packed = Vec::new();
        for t in &inputs {
            packed.extend_from_slice(&t.data);
        }
        let packed_outs = fused.run_batch_packed(inputs.len(), &packed);
        for (got, want) in packed_outs.iter().zip(&outs) {
            assert_eq!(got.data, want.data,
                       "packed batch diverged from run_batch");
        }
    }

    #[test]
    fn run_batch_preserves_order() {
        let ir = tiny_ir();
        let plan = build_plan(&ir, Scheme::DenseIm2col,
                              PruneConfig::default(), 5);
        let mut rng = Rng::seed_from(4);
        let inputs: Vec<Tensor> = (0..3)
            .map(|_| Tensor::random(3, 12, 12, &mut rng))
            .collect();
        let mut exec = ModelExecutor::new(&plan, 2);
        let batch = exec.run_batch(&inputs);
        for (x, got) in inputs.iter().zip(&batch) {
            let want = ModelExecutor::new(&plan, 2).run(x);
            assert_eq!(want.data, got.data);
        }
    }

    #[test]
    fn mobilenet_with_depthwise_runs() {
        let ir = zoo::mobilenet_v2(32, 10);
        let p = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(), 3);
        let mut rng = Rng::seed_from(3);
        let x = Tensor::random(3, 32, 32, &mut rng);
        let out = ModelExecutor::new(&p, 4).run(&x);
        assert_eq!(out.c, 10);
        assert!(out.iter_finite());
    }

    #[test]
    fn cocogen_quant_scheme_runs_and_tracks_fp32() {
        let ir = tiny_ir();
        let fp32 = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                              42);
        let quant = build_plan(&ir, Scheme::CocoGenQuant,
                               PruneConfig::default(), 42);
        let mut rng = Rng::seed_from(6);
        let x = Tensor::random(3, 12, 12, &mut rng);
        let a = ModelExecutor::new(&fp32, 2).run(&x);
        let b = ModelExecutor::new(&quant, 2).run(&x);
        assert_eq!(b.c, 5);
        assert!(b.iter_finite());
        // weight-only int8: output stays close to the fp32 plan built
        // from the identical seed (same masks, same reorder).
        let scale = a.data.iter().fold(0f32, |m, v| m.max(v.abs()));
        assert!(
            a.max_abs_diff(&b) < 0.05 * scale.max(1.0),
            "quant diverged: {} vs scale {}",
            a.max_abs_diff(&b),
            scale
        );
    }

    #[test]
    fn quant_pool_matches_sequential_bitwise() {
        let ir = tiny_ir();
        let plan = build_plan(&ir, Scheme::CocoGenQuant,
                              PruneConfig::default(), 42)
            .into_shared();
        let pool = ExecutorPool::new(plan.clone(), 4);
        let mut rng = Rng::seed_from(10);
        let inputs: Vec<Tensor> = (0..8)
            .map(|_| Tensor::random(3, 12, 12, &mut rng))
            .collect();
        let outs = pool.run_batch(&inputs);
        let mut seq = ModelExecutor::new(&plan, 1);
        for (x, got) in inputs.iter().zip(&outs) {
            let want = seq.run(x);
            assert_eq!(want.data, got.data,
                       "quant pool diverged from sequential");
        }
    }

    #[test]
    fn elastic_pool_scales_at_pinned_watermark_crossings() {
        let ir = tiny_ir();
        let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                              42)
            .into_shared();
        let cfg = ElasticConfig {
            floor: 1,
            max: 3,
            high: 4,
            low: 1,
            hysteresis: 2,
        };
        let log = ScaleLog::new();
        let pool = ExecutorPool::new_elastic(plan, cfg, log.clone());
        assert_eq!(pool.workers(), 3, "all slots exist up front");
        assert_eq!(pool.active_workers(), 1, "starts at the floor");
        // A fixed depth trace must produce exactly the pinned events:
        // two highs per step up, a dead-zone reading that resets the
        // streaks, then two lows per step down.
        for d in [5, 5, 5, 5, 2, 1, 1, 0, 0] {
            pool.observe_queue_depth(d);
        }
        assert_eq!(
            log.events(),
            vec![
                ScaleEvent { depth: 5, from: 1, to: 2 },
                ScaleEvent { depth: 5, from: 2, to: 3 },
                ScaleEvent { depth: 1, from: 3, to: 2 },
                ScaleEvent { depth: 0, from: 2, to: 1 },
            ]
        );
        assert_eq!(pool.active_workers(), 1, "back at the floor");
        // Saturation: at max, highs are absorbed without events.
        for _ in 0..10 {
            pool.observe_queue_depth(100);
        }
        assert_eq!(pool.active_workers(), 3);
        assert_eq!(log.events().len(), 6, "capped at max");
        // A single low between highs (hysteresis) must not scale down.
        pool.observe_queue_depth(0);
        pool.observe_queue_depth(5);
        pool.observe_queue_depth(0);
        assert_eq!(pool.active_workers(), 3,
                   "one-off lows must not shrink the pool");
    }

    #[test]
    fn elastic_pool_matches_fixed_pool_bitwise() {
        // Slot count must never leak into numerics: an elastic pool
        // mid-scale produces the same bits as a fixed one.
        let ir = tiny_ir();
        let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                              42)
            .into_shared();
        let cfg = ElasticConfig {
            floor: 1,
            max: 4,
            high: 2,
            low: 0,
            hysteresis: 1,
        };
        let pool = ExecutorPool::new_elastic(plan.clone(), cfg,
                                             ScaleLog::new());
        let fixed = ExecutorPool::new(plan.clone(), 4);
        let mut rng = Rng::seed_from(21);
        let inputs: Vec<Tensor> = (0..8)
            .map(|_| Tensor::random(3, 12, 12, &mut rng))
            .collect();
        let mut seq = ModelExecutor::new(&plan, 1);
        for round in 0..3 {
            // Scale somewhere new each round (up, up, down...).
            pool.observe_queue_depth(if round < 2 { 10 } else { 0 });
            let a = pool.run_batch(&inputs);
            let b = fixed.run_batch(&inputs);
            for ((x, got), fx) in inputs.iter().zip(&a).zip(&b) {
                let want = seq.run(x);
                assert_eq!(want.data, got.data,
                           "elastic pool diverged from sequential");
                assert_eq!(want.data, fx.data,
                           "fixed pool diverged from sequential");
            }
        }
    }

    #[test]
    fn oversubscribed_claims_block_instead_of_spinning() {
        let ir = tiny_ir();
        let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                              42)
            .into_shared();
        // 2 slots, 8 concurrent run_batch callers: up to 16 live claims.
        let pool = ExecutorPool::new(plan.clone(), 2);
        let mut rng = Rng::seed_from(5);
        let inputs: Vec<Tensor> = (0..6)
            .map(|_| Tensor::random(3, 12, 12, &mut rng))
            .collect();
        let mut seq = ModelExecutor::new(&plan, 1);
        let want: Vec<Tensor> = inputs.iter().map(|x| seq.run(x)).collect();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let outs = pool.run_batch(&inputs);
                    for (got, w) in outs.iter().zip(&want) {
                        assert_eq!(got.data, w.data);
                    }
                });
            }
        });
        // Every block is one condvar park. A yield-spin would register
        // (wait-time x core-speed) iterations here — orders of magnitude
        // beyond the claim count.
        let claims = 8 * inputs.len();
        assert!(
            pool.wait_count() <= claims * 100,
            "claim path spun: {} waits for {} claims",
            pool.wait_count(),
            claims
        );
    }
}
