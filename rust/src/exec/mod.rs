//! Native executors: run an ExecPlan (IR + per-layer weights/strategy)
//! over planar NCHW tensors. Four engines implement the Fig. 5 framework
//! axis; all four are validated against each other by property tests.

pub mod csr;
pub mod gemm;
pub mod im2col;
pub mod naive;
pub mod ops;
pub mod pattern;
pub mod tensor;
pub mod winograd;

use crate::codegen::{ExecPlan, LayerPlan, Scheme};
use crate::ir::LayerKind;
pub use tensor::Tensor;

/// Stateful model executor (owns im2col scratch).
pub struct ModelExecutor<'a> {
    pub plan: &'a ExecPlan,
    pub threads: usize,
    scratch: im2col::Im2colScratch,
}

impl<'a> ModelExecutor<'a> {
    pub fn new(plan: &'a ExecPlan, threads: usize) -> Self {
        ModelExecutor {
            plan,
            threads,
            scratch: im2col::Im2colScratch::default(),
        }
    }

    /// Run one input through the model; returns the final tensor.
    pub fn run(&mut self, input: &Tensor) -> Tensor {
        assert_eq!(input.shape(), self.plan.ir.input,
                   "input shape mismatch");
        let n = self.plan.ir.layers.len();
        // Keep outputs that later Add layers reference.
        let mut needed = vec![false; n];
        for l in &self.plan.ir.layers {
            if let LayerKind::Add { from, .. } = l.kind {
                needed[from] = true;
            }
        }
        let mut saved: Vec<Option<Tensor>> = vec![None; n];
        let mut cur = input.clone();
        for (i, (layer, plan)) in self
            .plan
            .ir
            .layers
            .iter()
            .zip(&self.plan.layers)
            .enumerate()
        {
            let out = match (&layer.kind, plan) {
                (LayerKind::Conv { stride, relu, .. }, LayerPlan::Dense(d)) => {
                    // Dense layers inside non-naive schemes (1x1 convs the
                    // pattern pass leaves dense, CSR scheme's non-3x3
                    // layers) use the strong im2col lowering; only the
                    // DenseNaive baseline is interpreter-style throughout.
                    // The Winograd scheme applies F(2x2,3x3) where legal.
                    match self.plan.scheme {
                        Scheme::DenseNaive => naive::conv2d(
                            &cur, d, *stride, *relu, self.threads,
                        ),
                        Scheme::DenseWinograd
                            if d.kh == 3 && d.kw == 3 && *stride == 1 =>
                        {
                            winograd::conv2d(&cur, d, *relu, self.threads)
                        }
                        _ => im2col::conv2d(
                            &cur, d, *stride, *relu, self.threads,
                            &mut self.scratch,
                        ),
                    }
                }
                (LayerKind::Conv { stride, relu, .. }, LayerPlan::Csr(c)) => {
                    csr::conv2d(&cur, c, *stride, *relu, self.threads)
                }
                (
                    LayerKind::Conv { stride, relu, .. },
                    LayerPlan::Fkw { layer: f, tile },
                ) => pattern::conv2d_auto(&cur, f, *stride, *relu,
                                          self.threads, *tile),
                (
                    LayerKind::DwConv { stride, relu },
                    LayerPlan::Depthwise { weights, bias },
                ) => ops::depthwise3x3(&cur, weights, bias, *stride, *relu),
                (LayerKind::MaxPool2, _) => ops::maxpool2(&cur),
                (LayerKind::GlobalAvgPool, _) => ops::gap(&cur),
                (
                    LayerKind::Dense { cout, relu },
                    LayerPlan::Fc { weights, bias },
                ) => ops::dense(&cur, weights, bias, *cout, *relu),
                (LayerKind::Add { from, relu }, _) => {
                    let skip = saved[*from]
                        .as_ref()
                        .expect("Add source not saved");
                    ops::add(&cur, skip, *relu)
                }
                (k, p) => panic!(
                    "layer {} kind {:?} has incompatible plan {:?}",
                    layer.name, k, std::mem::discriminant(p)
                ),
            };
            if needed[i] {
                saved[i] = Some(out.clone());
            }
            cur = out;
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{build_plan, PruneConfig, Scheme};
    use crate::ir::zoo;
    use crate::ir::{Chw, IrBuilder};
    use crate::util::rng::Rng;

    fn tiny_ir() -> crate::ir::ModelIR {
        let mut b = IrBuilder::new("t", Chw::new(3, 12, 12));
        b.conv("c1", 3, 8, 1, true);
        let skip = b.last();
        b.conv("c2", 3, 8, 1, false)
            .add("a", skip, true)
            .conv("c3", 3, 16, 2, true)
            .maxpool("p")
            .gap("g")
            .dense("fc", 5, false);
        b.build().unwrap()
    }

    #[test]
    fn dense_naive_and_im2col_agree_end_to_end() {
        let ir = tiny_ir();
        let p1 = build_plan(&ir, Scheme::DenseNaive, PruneConfig::default(),
                            42);
        let p2 = build_plan(&ir, Scheme::DenseIm2col,
                            PruneConfig::default(), 42);
        let mut rng = Rng::seed_from(0);
        let x = Tensor::random(3, 12, 12, &mut rng);
        let a = ModelExecutor::new(&p1, 2).run(&x);
        let b = ModelExecutor::new(&p2, 2).run(&x);
        assert!(a.max_abs_diff(&b) < 1e-3, "diff {}", a.max_abs_diff(&b));
    }

    #[test]
    fn cocogen_runs_and_is_finite() {
        let ir = tiny_ir();
        let p = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(), 42);
        let mut rng = Rng::seed_from(1);
        let x = Tensor::random(3, 12, 12, &mut rng);
        let out = ModelExecutor::new(&p, 2).run(&x);
        assert_eq!(out.c, 5);
        assert!(out.iter_finite());
    }

    #[test]
    fn csr_scheme_runs() {
        let ir = tiny_ir();
        let p = build_plan(&ir, Scheme::SparseCsr {},
                           PruneConfig::default(), 42);
        let mut rng = Rng::seed_from(2);
        let x = Tensor::random(3, 12, 12, &mut rng);
        let out = ModelExecutor::new(&p, 2).run(&x);
        assert_eq!(out.c, 5);
        assert!(out.iter_finite());
    }

    #[test]
    fn mobilenet_with_depthwise_runs() {
        let ir = zoo::mobilenet_v2(32, 10);
        let p = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(), 3);
        let mut rng = Rng::seed_from(3);
        let x = Tensor::random(3, 32, 32, &mut rng);
        let out = ModelExecutor::new(&p, 4).run(&x);
        assert_eq!(out.c, 10);
        assert!(out.iter_finite());
    }
}
