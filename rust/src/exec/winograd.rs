//! Winograd F(2x2, 3x3) convolution — the fast dense algorithm mobile
//! frameworks (MNN) use for 3x3 stride-1 convs, and the baseline the
//! paper contrasts with pattern pruning (§2.1.1: filter/channel pruning
//! is Winograd-compatible; pattern pruning is not, which is why CoCo-Gen
//! must win through codegen instead).
//!
//! Standard formulation: Y = A^T [ (G g G^T) .* (B^T d B) ] A, evaluated
//! as 16 per-frequency GEMMs of [cout x cin] @ [cin x tiles] — 2.25x
//! fewer multiplies than direct conv on the tile interior.

use crate::compress::DenseLayer;
use crate::exec::gemm::gemm;
use crate::exec::tensor::{same_pad, BatchView, Tensor, TensorView};

/// Transform one 3x3 kernel g -> 4x4: G g G^T.
fn transform_kernel(g: &[f32]) -> [f32; 16] {
    // G = [[1,0,0],[.5,.5,.5],[.5,-.5,.5],[0,0,1]]
    let mut tmp = [0f32; 12]; // G g : 4x3
    for r in 0..4 {
        for c in 0..3 {
            tmp[r * 3 + c] = match r {
                0 => g[c],
                1 => 0.5 * (g[c] + g[3 + c] + g[6 + c]),
                2 => 0.5 * (g[c] - g[3 + c] + g[6 + c]),
                _ => g[6 + c],
            };
        }
    }
    let mut out = [0f32; 16]; // (G g) G^T : 4x4
    for r in 0..4 {
        let row = &tmp[r * 3..r * 3 + 3];
        out[r * 4] = row[0];
        out[r * 4 + 1] = 0.5 * (row[0] + row[1] + row[2]);
        out[r * 4 + 2] = 0.5 * (row[0] - row[1] + row[2]);
        out[r * 4 + 3] = row[2];
    }
    out
}

/// Transform one 4x4 input tile d -> B^T d B.
#[inline]
fn transform_input(d: &[f32; 16]) -> [f32; 16] {
    // B^T = [[1,0,-1,0],[0,1,1,0],[0,-1,1,0],[0,1,0,-1]]
    let mut tmp = [0f32; 16]; // B^T d
    for c in 0..4 {
        let (d0, d1, d2, d3) =
            (d[c], d[4 + c], d[8 + c], d[12 + c]);
        tmp[c] = d0 - d2;
        tmp[4 + c] = d1 + d2;
        tmp[8 + c] = d2 - d1;
        tmp[12 + c] = d1 - d3;
    }
    let mut out = [0f32; 16]; // (B^T d) B
    for r in 0..4 {
        let (t0, t1, t2, t3) = (
            tmp[r * 4],
            tmp[r * 4 + 1],
            tmp[r * 4 + 2],
            tmp[r * 4 + 3],
        );
        out[r * 4] = t0 - t2;
        out[r * 4 + 1] = t1 + t2;
        out[r * 4 + 2] = t2 - t1;
        out[r * 4 + 3] = t1 - t3;
    }
    out
}

/// Inverse transform: 4x4 m -> 2x2 output tile: A^T m A.
#[inline]
fn transform_output(m: &[f32; 16]) -> [f32; 4] {
    // A^T = [[1,1,1,0],[0,1,-1,-1]]
    let mut tmp = [0f32; 8]; // A^T m : 2x4
    for c in 0..4 {
        tmp[c] = m[c] + m[4 + c] + m[8 + c];
        tmp[4 + c] = m[4 + c] - m[8 + c] - m[12 + c];
    }
    [
        tmp[0] + tmp[1] + tmp[2],
        tmp[1] - tmp[2] - tmp[3],
        tmp[4] + tmp[5] + tmp[6],
        tmp[5] - tmp[6] - tmp[7],
    ]
}

/// Winograd-domain weights, transformed once at plan-lowering time so
/// the per-inference path skips the `G g G^T` kernel transform entirely
/// (the compiled pipeline binds these instead of the raw `DenseLayer`).
#[derive(Debug, Clone)]
pub struct WinogradWeights {
    pub cout: usize,
    pub cin: usize,
    /// `V[16][cout][cin]`: per-frequency transformed kernels.
    pub v: Vec<f32>,
    pub bias: Vec<f32>,
}

impl WinogradWeights {
    /// Transform a dense 3x3 layer into the Winograd domain.
    pub fn transform(layer: &DenseLayer) -> WinogradWeights {
        assert_eq!(layer.kh, 3);
        assert_eq!(layer.kw, 3);
        let (cin, cout) = (layer.cin, layer.cout);
        let mut v = vec![0f32; 16 * cout * cin];
        for co in 0..cout {
            for ci in 0..cin {
                let base = (co * cin + ci) * 9;
                let tk = transform_kernel(&layer.weights[base..base + 9]);
                for f in 0..16 {
                    v[(f * cout + co) * cin + ci] = tk[f];
                }
            }
        }
        WinogradWeights {
            cout,
            cin,
            v,
            bias: layer.bias.clone(),
        }
    }
}

/// Winograd conv2d (3x3, stride 1 only), SAME padding.
pub fn conv2d(input: &Tensor, layer: &DenseLayer, relu: bool,
              threads: usize) -> Tensor {
    let tw = WinogradWeights::transform(layer);
    let (h_out, _) = same_pad(input.h, 3, 1);
    let (w_out, _) = same_pad(input.w, 3, 1);
    let mut out = Tensor::zeros(layer.cout, h_out, w_out);
    let (mut u, mut m) = (Vec::new(), Vec::new());
    conv2d_pre_into(input.view(), &tw, relu, threads, &mut u, &mut m,
                    &mut out.data);
    out
}

/// Winograd conv over pre-transformed weights, writing into a
/// preassigned output buffer. `u_buf`/`m_buf` are reusable scratch for
/// the transformed input tiles and the per-frequency GEMM results —
/// allocation-free once warmed to this layer's tile count.
pub fn conv2d_pre_into(input: TensorView<'_>, layer: &WinogradWeights,
                       relu: bool, threads: usize, u_buf: &mut Vec<f32>,
                       m_buf: &mut Vec<f32>, out: &mut [f32]) {
    let (h_out, pad_h) = same_pad(input.h, 3, 1);
    let (w_out, pad_w) = same_pad(input.w, 3, 1);
    let th = h_out.div_ceil(2);
    let tw = w_out.div_ceil(2);
    let tiles = th * tw;
    let (cin, cout) = (layer.cin, layer.cout);
    assert_eq!(out.len(), cout * h_out * w_out,
               "output buffer size mismatch");

    let v = &layer.v;
    // U[16][cin][tiles]: transformed input tiles.
    u_buf.clear();
    u_buf.resize(16 * cin * tiles, 0.0);
    let u = &mut u_buf[..];
    for ci in 0..cin {
        let plane = input.plane(ci);
        for ty in 0..th {
            for tx in 0..tw {
                let mut d = [0f32; 16];
                for r in 0..4 {
                    let iy = (2 * ty + r) as isize - pad_h as isize;
                    if iy < 0 || iy >= input.h as isize {
                        continue;
                    }
                    for c in 0..4 {
                        let ix = (2 * tx + c) as isize - pad_w as isize;
                        if ix >= 0 && (ix as usize) < input.w {
                            d[r * 4 + c] =
                                plane[iy as usize * input.w + ix as usize];
                        }
                    }
                }
                let td = transform_input(&d);
                let t = ty * tw + tx;
                for f in 0..16 {
                    u[(f * cin + ci) * tiles + t] = td[f];
                }
            }
        }
    }
    // M[16][cout][tiles] = V[f] @ U[f] (16 GEMMs).
    m_buf.clear();
    m_buf.resize(16 * cout * tiles, 0.0);
    let m = &mut m_buf[..];
    for f in 0..16 {
        gemm(
            &v[f * cout * cin..(f + 1) * cout * cin],
            &u[f * cin * tiles..(f + 1) * cin * tiles],
            &mut m[f * cout * tiles..(f + 1) * cout * tiles],
            cout,
            cin,
            tiles,
            threads,
        );
    }
    // Inverse transform into the output.
    for co in 0..cout {
        let b = layer.bias[co];
        let plane = &mut out[co * h_out * w_out..(co + 1) * h_out * w_out];
        for ty in 0..th {
            for tx in 0..tw {
                let t = ty * tw + tx;
                let mut freq = [0f32; 16];
                for (f, fr) in freq.iter_mut().enumerate() {
                    *fr = m[(f * cout + co) * tiles + t];
                }
                let y4 = transform_output(&freq);
                for dy in 0..2 {
                    for dx in 0..2 {
                        let yy = 2 * ty + dy;
                        let xx = 2 * tx + dx;
                        if yy < h_out && xx < w_out {
                            let val = y4[dy * 2 + dx] + b;
                            plane[yy * w_out + xx] =
                                if relu { val.max(0.0) } else { val };
                        }
                    }
                }
            }
        }
    }
}

/// Batched [`conv2d_pre_into`]: per-image loop behind the same
/// `[N][C][H][W]` signature as the fused engines (the weight transform
/// is already amortized at lowering time, so the per-image cost is the
/// tile transforms, which scale with the batch either way).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_pre_batch_into(input: BatchView<'_>,
                             layer: &WinogradWeights, relu: bool,
                             threads: usize, u_buf: &mut Vec<f32>,
                             m_buf: &mut Vec<f32>, out: &mut [f32]) {
    let (h_out, _) = same_pad(input.h, 3, 1);
    let (w_out, _) = same_pad(input.w, 3, 1);
    let per = layer.cout * h_out * w_out;
    assert_eq!(out.len(), input.n * per, "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(per).enumerate() {
        conv2d_pre_into(input.image(img), layer, relu, threads, u_buf,
                        m_buf, chunk);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::naive;
    use crate::util::prop;

    #[test]
    fn matches_naive_across_shapes() {
        prop::check("winograd-vs-naive", 25, |g| {
            let cin = g.usize(1, 6);
            let cout = g.usize(1, 8);
            let h = g.usize(3, 13);
            let w = g.usize(3, 13);
            let mut rng = g.rng().clone();
            let input = Tensor::random(cin, h, w, &mut rng);
            let layer = DenseLayer {
                cout,
                cin,
                kh: 3,
                kw: 3,
                weights: (0..cout * cin * 9)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let a = naive::conv2d(&input, &layer, 1, false, 1);
            let b = conv2d(&input, &layer, false, g.usize(1, 4));
            if a.max_abs_diff(&b) > 5e-4 {
                return Err(format!("diff {}", a.max_abs_diff(&b)));
            }
            Ok(())
        });
    }

    #[test]
    fn relu_fused() {
        let mut rng = crate::util::rng::Rng::seed_from(2);
        let input = Tensor::random(3, 8, 8, &mut rng);
        let layer = DenseLayer {
            cout: 4,
            cin: 3,
            kh: 3,
            kw: 3,
            weights: (0..4 * 3 * 9).map(|_| rng.normal_f32()).collect(),
            bias: vec![0.0; 4],
        };
        let b = conv2d(&input, &layer, true, 1);
        assert!(b.data.iter().all(|v| *v >= 0.0));
    }

    #[test]
    fn kernel_transform_known_value() {
        // identity-ish kernel: centre 1 -> transformed G e G^T
        let mut g = [0f32; 9];
        g[4] = 1.0;
        let t = transform_kernel(&g);
        // row pattern for centre kernel: [0, .5, -.5, 0] outer products
        assert!((t[5] - 0.25).abs() < 1e-6);
        assert!((t[6] + 0.25).abs() < 1e-6);
        assert!((t[0]).abs() < 1e-6);
    }
}
