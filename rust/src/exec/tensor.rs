//! Planar NCHW (batch-1) tensor used by the native executors.
//!
//! Channels-first planar layout makes every conv inner loop a contiguous
//! row AXPY — the layout CoCo-Gen's generated mobile code uses for its
//! SIMD inner loops (and the layout that lets register-level load
//! redundancy elimination work on rows).

use crate::ir::Chw;
use crate::util::rng::Rng;

/// A single-image activation tensor: planar `[C][H][W]`, f32.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(c: usize, h: usize, w: usize) -> Tensor {
        Tensor {
            c,
            h,
            w,
            data: vec![0f32; c * h * w],
        }
    }

    pub fn from_shape(s: Chw) -> Tensor {
        Tensor::zeros(s.c, s.h, s.w)
    }

    pub fn random(c: usize, h: usize, w: usize, rng: &mut Rng) -> Tensor {
        Tensor {
            c,
            h,
            w,
            data: (0..c * h * w).map(|_| rng.normal_f32()).collect(),
        }
    }

    pub fn shape(&self) -> Chw {
        Chw::new(self.c, self.h, self.w)
    }

    #[inline]
    pub fn plane(&self, c: usize) -> &[f32] {
        &self.data[c * self.h * self.w..(c + 1) * self.h * self.w]
    }

    #[inline]
    pub fn plane_mut(&mut self, c: usize) -> &mut [f32] {
        let hw = self.h * self.w;
        &mut self.data[c * hw..(c + 1) * hw]
    }

    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> f32 {
        self.data[(c * self.h + y) * self.w + x]
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: f32) {
        self.data[(c * self.h + y) * self.w + x] = v;
    }

    /// Max |a-b| over all elements (shape must match).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max)
    }

    pub fn iter_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }

    /// Borrowed view of this tensor (what the `*_into` kernel entry
    /// points consume, so arena slots can feed kernels without owning a
    /// `Tensor`).
    pub fn view(&self) -> TensorView<'_> {
        TensorView::new(self.c, self.h, self.w, &self.data)
    }
}

/// Borrowed planar `[C][H][W]` activation view. The compiled-op pipeline
/// reads layer inputs straight out of arena slots through this — same
/// accessors as [`Tensor`], no ownership, no copy.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: &'a [f32],
}

impl<'a> TensorView<'a> {
    pub fn new(c: usize, h: usize, w: usize, data: &'a [f32])
               -> TensorView<'a> {
        assert_eq!(data.len(), c * h * w, "view length mismatch");
        TensorView { c, h, w, data }
    }

    pub fn shape(&self) -> Chw {
        Chw::new(self.c, self.h, self.w)
    }

    #[inline]
    pub fn plane(&self, c: usize) -> &'a [f32] {
        &self.data[c * self.h * self.w..(c + 1) * self.h * self.w]
    }

    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> f32 {
        self.data[(c * self.h + y) * self.w + x]
    }

    /// Copy into an owned tensor.
    pub fn to_tensor(&self) -> Tensor {
        Tensor {
            c: self.c,
            h: self.h,
            w: self.w,
            data: self.data.to_vec(),
        }
    }
}

/// SAME-padding geometry for a conv with kernel k and stride s:
/// returns (out_size, pad_low).
pub fn same_pad(in_size: usize, k: usize, stride: usize) -> (usize, usize) {
    let out = in_size.div_ceil(stride);
    let pad_total = ((out - 1) * stride + k).saturating_sub(in_size);
    (out, pad_total / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_and_access() {
        let mut t = Tensor::zeros(2, 3, 4);
        t.set(1, 2, 3, 5.0);
        assert_eq!(t.at(1, 2, 3), 5.0);
        assert_eq!(t.plane(1)[2 * 4 + 3], 5.0);
        assert_eq!(t.data.len(), 24);
    }

    #[test]
    fn same_pad_matches_xla() {
        // k=3 s=1: out=in, pad 1
        assert_eq!(same_pad(16, 3, 1), (16, 1));
        // k=3 s=2 even in: out=in/2, pad_total=1 -> low 0
        assert_eq!(same_pad(16, 3, 2), (8, 0));
        // k=3 s=2 odd in
        assert_eq!(same_pad(15, 3, 2), (8, 1));
        // k=1
        assert_eq!(same_pad(16, 1, 1), (16, 0));
        assert_eq!(same_pad(16, 1, 2), (8, 0));
    }

    #[test]
    fn diff() {
        let a = Tensor::zeros(1, 2, 2);
        let mut b = Tensor::zeros(1, 2, 2);
        b.set(0, 1, 1, 0.5);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
