//! Planar NCHW (batch-1) tensor used by the native executors.
//!
//! Channels-first planar layout makes every conv inner loop a contiguous
//! row AXPY — the layout CoCo-Gen's generated mobile code uses for its
//! SIMD inner loops (and the layout that lets register-level load
//! redundancy elimination work on rows).

use crate::ir::Chw;
use crate::util::rng::Rng;

/// A single-image activation tensor: planar `[C][H][W]`, f32.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(c: usize, h: usize, w: usize) -> Tensor {
        Tensor {
            c,
            h,
            w,
            data: vec![0f32; c * h * w],
        }
    }

    pub fn from_shape(s: Chw) -> Tensor {
        Tensor::zeros(s.c, s.h, s.w)
    }

    pub fn random(c: usize, h: usize, w: usize, rng: &mut Rng) -> Tensor {
        Tensor {
            c,
            h,
            w,
            data: (0..c * h * w).map(|_| rng.normal_f32()).collect(),
        }
    }

    pub fn shape(&self) -> Chw {
        Chw::new(self.c, self.h, self.w)
    }

    #[inline]
    pub fn plane(&self, c: usize) -> &[f32] {
        &self.data[c * self.h * self.w..(c + 1) * self.h * self.w]
    }

    #[inline]
    pub fn plane_mut(&mut self, c: usize) -> &mut [f32] {
        let hw = self.h * self.w;
        &mut self.data[c * hw..(c + 1) * hw]
    }

    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> f32 {
        self.data[(c * self.h + y) * self.w + x]
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: f32) {
        self.data[(c * self.h + y) * self.w + x] = v;
    }

    /// Max |a-b| over all elements (shape must match).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max)
    }

    pub fn iter_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }

    /// Borrowed view of this tensor (what the `*_into` kernel entry
    /// points consume, so arena slots can feed kernels without owning a
    /// `Tensor`).
    pub fn view(&self) -> TensorView<'_> {
        TensorView::new(self.c, self.h, self.w, &self.data)
    }
}

/// Borrowed planar `[C][H][W]` activation view. The compiled-op pipeline
/// reads layer inputs straight out of arena slots through this — same
/// accessors as [`Tensor`], no ownership, no copy.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: &'a [f32],
}

impl<'a> TensorView<'a> {
    pub fn new(c: usize, h: usize, w: usize, data: &'a [f32])
               -> TensorView<'a> {
        assert_eq!(data.len(), c * h * w, "view length mismatch");
        TensorView { c, h, w, data }
    }

    pub fn shape(&self) -> Chw {
        Chw::new(self.c, self.h, self.w)
    }

    #[inline]
    pub fn plane(&self, c: usize) -> &'a [f32] {
        &self.data[c * self.h * self.w..(c + 1) * self.h * self.w]
    }

    #[inline]
    pub fn at(&self, c: usize, y: usize, x: usize) -> f32 {
        self.data[(c * self.h + y) * self.w + x]
    }

    /// Copy into an owned tensor.
    pub fn to_tensor(&self) -> Tensor {
        Tensor {
            c: self.c,
            h: self.h,
            w: self.w,
            data: self.data.to_vec(),
        }
    }
}

/// Borrowed batch of `n` same-shape planar images, contiguous
/// `[N][C][H][W]` — the activation layout of a batch-compiled pipeline
/// (`codegen::lower_batched`). The `*_batch_into` kernel entry points
/// consume this so one kernel call serves the whole batch (weights
/// decoded/streamed once per batch, not once per image).
#[derive(Debug, Clone, Copy)]
pub struct BatchView<'a> {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: &'a [f32],
}

impl<'a> BatchView<'a> {
    pub fn new(n: usize, c: usize, h: usize, w: usize, data: &'a [f32])
               -> BatchView<'a> {
        assert_eq!(data.len(), n * c * h * w, "batch view length mismatch");
        BatchView { n, c, h, w, data }
    }

    /// View of a single image as a batch of one.
    pub fn of_single(t: TensorView<'a>) -> BatchView<'a> {
        BatchView::new(1, t.c, t.h, t.w, t.data)
    }

    /// Per-image shape.
    pub fn shape(&self) -> Chw {
        Chw::new(self.c, self.h, self.w)
    }

    /// Elements per image.
    pub fn image_elems(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Borrowed view of image `i`.
    #[inline]
    pub fn image(&self, i: usize) -> TensorView<'a> {
        let per = self.image_elems();
        TensorView::new(self.c, self.h, self.w,
                        &self.data[i * per..(i + 1) * per])
    }
}

/// Copy one SAME-padded shifted input row (tap offset `(dy, dx)`,
/// output row `y`) into a row of a patch / shifted-input matrix, with
/// border clamp; out-of-range destination elements are left untouched
/// (callers zero-fill). Shared by the im2col patch builder and the
/// pattern-GEMM U-matrix builder so their border handling can never
/// desynchronize.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn fill_shifted_row(dst_row: &mut [f32], plane: &[f32],
                               in_h: usize, in_w: usize, y: usize,
                               dy: usize, dx: usize, stride: usize,
                               pad_h: usize, pad_w: usize, w_out: usize) {
    let iy = (y * stride + dy) as isize - pad_h as isize;
    if iy < 0 || iy >= in_h as isize {
        return; // stays zero
    }
    let src_row = &plane[iy as usize * in_w..(iy as usize + 1) * in_w];
    if stride == 1 {
        // contiguous copy with border clamp
        let x_lo = pad_w.saturating_sub(dx);
        let x_hi = (in_w + pad_w - dx).min(w_out);
        if x_lo < x_hi {
            let src_lo = x_lo + dx - pad_w;
            dst_row[x_lo..x_hi].copy_from_slice(
                &src_row[src_lo..src_lo + (x_hi - x_lo)],
            );
        }
    } else {
        for (x, d) in dst_row.iter_mut().enumerate() {
            let ix = (x * stride + dx) as isize - pad_w as isize;
            if ix >= 0 && (ix as usize) < in_w {
                *d = src_row[ix as usize];
            }
        }
    }
}

/// SAME-padding geometry for a conv with kernel k and stride s:
/// returns (out_size, pad_low).
pub fn same_pad(in_size: usize, k: usize, stride: usize) -> (usize, usize) {
    let out = in_size.div_ceil(stride);
    let pad_total = ((out - 1) * stride + k).saturating_sub(in_size);
    (out, pad_total / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_and_access() {
        let mut t = Tensor::zeros(2, 3, 4);
        t.set(1, 2, 3, 5.0);
        assert_eq!(t.at(1, 2, 3), 5.0);
        assert_eq!(t.plane(1)[2 * 4 + 3], 5.0);
        assert_eq!(t.data.len(), 24);
    }

    #[test]
    fn same_pad_matches_xla() {
        // k=3 s=1: out=in, pad 1
        assert_eq!(same_pad(16, 3, 1), (16, 1));
        // k=3 s=2 even in: out=in/2, pad_total=1 -> low 0
        assert_eq!(same_pad(16, 3, 2), (8, 0));
        // k=3 s=2 odd in
        assert_eq!(same_pad(15, 3, 2), (8, 1));
        // k=1
        assert_eq!(same_pad(16, 1, 1), (16, 0));
        assert_eq!(same_pad(16, 1, 2), (8, 0));
    }

    #[test]
    fn batch_view_slices_images() {
        let data: Vec<f32> = (0..2 * 2 * 3 * 4).map(|v| v as f32).collect();
        let b = BatchView::new(2, 2, 3, 4, &data);
        assert_eq!(b.image_elems(), 24);
        assert_eq!(b.image(0).at(1, 2, 3), 23.0);
        assert_eq!(b.image(1).at(0, 0, 0), 24.0);
        assert_eq!(b.image(1).plane(1)[0], 36.0);
    }

    #[test]
    fn diff() {
        let a = Tensor::zeros(1, 2, 2);
        let mut b = Tensor::zeros(1, 2, 2);
        b.set(0, 1, 1, 0.5);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
