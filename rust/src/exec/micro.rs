//! Packed, runtime-dispatched SIMD GEMM microkernels.
//!
//! The paper's latency numbers come from compiler-level kernel work:
//! reshaping the inner loops for the target ISA rather than leaning on
//! autovectorization. This module is that layer for the native x86-64
//! path. It provides
//!
//! * one-time CPU feature detection cached in a [`OnceLock`]
//!   ([`tier`]), with a deterministic scalar override
//!   (`COCOPIE_FORCE_SCALAR` / [`set_force_scalar`]) so both paths can
//!   be exercised on any host;
//! * BLIS-style panel packing: the weight (A) operand into `MR`-row
//!   strips ([`pack_a`], [`PackedA`] for the once-per-compile form) and
//!   the activation (B) operand into `NR`-column panels ([`pack_b`]),
//!   both k-major and zero-padded so edge tiles run the full-width
//!   kernel;
//! * a 6x16 register-tiled microkernel per tier — explicit AVX2+FMA
//!   intrinsics under `target_feature`, and a portable scalar twin —
//!   driven by [`gemm_packed`];
//! * dispatched [`dot`] / [`axpy`] primitives for the GEMV-shaped
//!   seams (FC rows, attention scores, the pattern-GEMM U-row and int8
//!   dequant-on-load AXPY streams).
//!
//! Numerics contract: within one tier every kernel is deterministic,
//! thread-count-invariant, and position-independent per output element
//! (a C column's value never depends on which tile or batch slot it
//! occupied), which is what keeps the pipeline bit-identity pins
//! (batched vs single, compiled vs direct) green per tier. Across
//! tiers results differ only by FMA/reassociation rounding.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use crate::util::threadpool;

/// Microkernel register rows (A-panel strip height).
pub const MR: usize = 6;
/// Microkernel register columns (B-panel width; two AVX2 vectors).
pub const NR: usize = 16;

/// Kernel dispatch tier, resolved once per process (modulo the
/// force-scalar override) and consulted by every dispatched kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Packed 6x16 microkernels using AVX2 vector FMA.
    Avx2Fma,
    /// The portable scalar kernels (the seed implementations).
    Scalar,
}

impl Tier {
    /// Whether this tier runs the explicit-SIMD kernels.
    pub fn is_simd(self) -> bool {
        self != Tier::Scalar
    }

    /// Short display name for benches and `serve --list`.
    pub fn label(self) -> &'static str {
        match self {
            Tier::Avx2Fma => "avx2+fma",
            Tier::Scalar => "scalar",
        }
    }
}

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);
static ENV_FORCE: OnceLock<bool> = OnceLock::new();
static DETECTED: OnceLock<Tier> = OnceLock::new();

/// Pin dispatch to the scalar tier at runtime (`serve --no-simd`).
/// Takes effect on the next [`tier`] call; `false` restores
/// auto-detection (the cross-tier tests flip this both ways).
pub fn set_force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

fn force_scalar() -> bool {
    FORCE_SCALAR.load(Ordering::Relaxed)
        || *ENV_FORCE.get_or_init(|| {
            std::env::var("COCOPIE_FORCE_SCALAR")
                .is_ok_and(|v| !v.is_empty() && v != "0")
        })
}

// Under Miri the AVX2 intrinsics are not interpretable, so the whole
// SIMD path is compiled out (`not(miri)` on every `unsafe` kernel) and
// detection pins the scalar tier — Miri then exercises the exact
// packing/pointer arithmetic the scalar tier shares with SIMD.
#[cfg(all(target_arch = "x86_64", not(miri)))]
fn detect() -> Tier {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    {
        Tier::Avx2Fma
    } else {
        Tier::Scalar
    }
}

#[cfg(any(not(target_arch = "x86_64"), miri))]
fn detect() -> Tier {
    Tier::Scalar
}

/// The dispatch tier every kernel call routes through: scalar when
/// forced (env `COCOPIE_FORCE_SCALAR=1` or [`set_force_scalar`]),
/// otherwise the CPU-detected tier, cached after the first call.
pub fn tier() -> Tier {
    if force_scalar() {
        Tier::Scalar
    } else {
        *DETECTED.get_or_init(detect)
    }
}

/// Human-readable list of the SIMD features the dispatcher inspects,
/// as detected on this CPU (ignores any force-scalar override).
pub fn cpu_features() -> String {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        let mut have: Vec<&str> = Vec::new();
        for (name, on) in [
            ("avx", is_x86_feature_detected!("avx")),
            ("avx2", is_x86_feature_detected!("avx2")),
            ("fma", is_x86_feature_detected!("fma")),
            ("avx512f", is_x86_feature_detected!("avx512f")),
        ] {
            if on {
                have.push(name);
            }
        }
        if have.is_empty() {
            "x86-64 scalar".to_string()
        } else {
            have.join("+")
        }
    }
    #[cfg(any(not(target_arch = "x86_64"), miri))]
    {
        "portable scalar".to_string()
    }
}

/// Estimated peak f32 GFLOP/s for `threads` cores at the current
/// dispatch tier: 8 lanes x 2 flops (FMA) x 2 issue ports per cycle
/// for AVX2+FMA, 2 scalar flops per cycle otherwise, at the clock
/// reported by `/proc/cpuinfo` (2.0 GHz fallback). A roofline
/// denominator for the kernel bench, not a measurement.
pub fn peak_gflops(threads: usize) -> f64 {
    let per_cycle = if tier().is_simd() { 8.0 * 2.0 * 2.0 } else { 2.0 };
    per_cycle * cpu_ghz() * threads.max(1) as f64
}

fn cpu_ghz() -> f64 {
    let mut best = 0f64;
    if let Ok(info) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in info.lines() {
            let Some(rest) = line.strip_prefix("cpu MHz") else {
                continue;
            };
            if let Some(v) = rest.split(':').nth(1) {
                if let Ok(mhz) = v.trim().parse::<f64>() {
                    best = best.max(mhz);
                }
            }
        }
    }
    if best > 0.0 {
        best / 1000.0
    } else {
        2.0
    }
}

/// Pack row-major `A[M][K]` into `ceil(M/MR)` strips of `MR` rows,
/// k-major within each strip (`buf[strip][kk][r]`), zero-padding the
/// final strip so every tile runs the full-height kernel. The padded
/// rows never reach `C`: [`gemm_packed`] stores only real rows.
pub fn pack_a(a: &[f32], m: usize, k: usize, buf: &mut Vec<f32>) {
    assert_eq!(a.len(), m * k, "A size mismatch");
    let strips = m.div_ceil(MR);
    buf.clear();
    buf.resize(strips * MR * k, 0.0);
    for s in 0..strips {
        let base = s * MR * k;
        let rows = MR.min(m - s * MR);
        for r in 0..rows {
            let row = &a[(s * MR + r) * k..(s * MR + r + 1) * k];
            for (kk, &v) in row.iter().enumerate() {
                buf[base + kk * MR + r] = v;
            }
        }
    }
}

/// Pack row-major `B[K][N]` into `ceil(N/NR)` column panels, k-major
/// within each panel (`buf[panel][kk][j]`), zero-padding the final
/// panel. Zero columns cost redundant FMAs on the edge tile but keep
/// every real column's accumulation sequence independent of its panel
/// position — the property the batched-vs-single bit pins rely on.
pub fn pack_b(b: &[f32], k: usize, n: usize, buf: &mut Vec<f32>) {
    assert_eq!(b.len(), k * n, "B size mismatch");
    let panels = n.div_ceil(NR);
    buf.clear();
    buf.resize(panels * NR * k, 0.0);
    for p in 0..panels {
        let base = p * NR * k;
        let j0 = p * NR;
        let width = NR.min(n - j0);
        for kk in 0..k {
            let src = &b[kk * n + j0..kk * n + j0 + width];
            buf[base + kk * NR..base + kk * NR + width]
                .copy_from_slice(src);
        }
    }
}

/// The weight operand packed once — at pipeline compile time for the
/// packed conv kernel, so every inference skips the A-pack entirely
/// and the panel is `Arc`-shared like any other bound weight tensor.
#[derive(Debug, Clone)]
pub struct PackedA {
    buf: Vec<f32>,
    /// Logical row count (M = cout).
    pub m: usize,
    /// Shared dimension (K = cin*kh*kw).
    pub k: usize,
}

impl PackedA {
    /// Pack row-major `a[M][K]`.
    pub fn pack(a: &[f32], m: usize, k: usize) -> PackedA {
        let mut buf = Vec::new();
        pack_a(a, m, k, &mut buf);
        PackedA { buf, m, k }
    }

    /// The packed strips, `ceil(M/MR) * MR * K` elements.
    pub fn buf(&self) -> &[f32] {
        &self.buf
    }

    /// Resident bytes of the packed panel.
    pub fn bytes(&self) -> usize {
        self.buf.len() * std::mem::size_of::<f32>()
    }
}

/// Portable scalar 6x16 tile: `tile = A_strip * B_panel` over the full
/// shared dimension. Each tile element accumulates in k-order with
/// separate multiply and add — the rounding the scalar tier pins.
fn tile_scalar(ap: &[f32], bp: &[f32], k: usize,
               tile: &mut [f32; MR * NR]) {
    tile.fill(0.0);
    for kk in 0..k {
        let arow = &ap[kk * MR..kk * MR + MR];
        let brow = &bp[kk * NR..kk * NR + NR];
        for (r, &av) in arow.iter().enumerate() {
            let crow = &mut tile[r * NR..(r + 1) * NR];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// AVX2+FMA 6x16 tile: 12 `__m256` accumulators (6 rows x 2 vectors),
/// one broadcast per A element against two B vector loads per k step.
///
/// # Safety
/// Caller must have verified `avx2` and `fma` are available, and
/// `ap`/`bp` must hold at least `k*MR` / `k*NR` elements.
#[cfg(all(target_arch = "x86_64", not(miri)))]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn tile_avx2(ap: &[f32], bp: &[f32], k: usize,
                    tile: &mut [f32; MR * NR]) {
    use std::arch::x86_64::*;
    debug_assert!(ap.len() >= k * MR && bp.len() >= k * NR);
    let a = ap.as_ptr();
    let b = bp.as_ptr();
    let mut acc = [_mm256_setzero_ps(); MR * 2];
    for kk in 0..k {
        let b0 = _mm256_loadu_ps(b.add(kk * NR));
        let b1 = _mm256_loadu_ps(b.add(kk * NR + 8));
        let arow = a.add(kk * MR);
        for r in 0..MR {
            let av = _mm256_set1_ps(*arow.add(r));
            acc[2 * r] = _mm256_fmadd_ps(av, b0, acc[2 * r]);
            acc[2 * r + 1] = _mm256_fmadd_ps(av, b1, acc[2 * r + 1]);
        }
    }
    let out = tile.as_mut_ptr();
    for r in 0..MR {
        _mm256_storeu_ps(out.add(r * NR), acc[2 * r]);
        _mm256_storeu_ps(out.add(r * NR + 8), acc[2 * r + 1]);
    }
}

#[cfg(all(target_arch = "x86_64", not(miri)))]
#[inline]
fn run_tile(simd: bool, ap: &[f32], bp: &[f32], k: usize,
            tile: &mut [f32; MR * NR]) {
    if simd {
        // SAFETY: `simd` is true only after `tier()` confirmed
        // avx2+fma on this CPU. The `k*MR` / `k*NR` size contract
        // holds because `gemm_packed` asserts full-panel lengths for
        // both operands before slicing strips/panels — and for the
        // compile-time-packed weight operand the same length equation
        // (`ceil(M/MR)*MR*K` elements) is proven per plan by the
        // static verifier (`codegen::verify`, `PackedPanelMismatch`),
        // so release builds are covered without the debug assert.
        unsafe { tile_avx2(ap, bp, k, tile) };
    } else {
        tile_scalar(ap, bp, k, tile);
    }
}

#[cfg(any(not(target_arch = "x86_64"), miri))]
#[inline]
fn run_tile(simd: bool, ap: &[f32], bp: &[f32], k: usize,
            tile: &mut [f32; MR * NR]) {
    let _ = simd;
    tile_scalar(ap, bp, k, tile);
}

/// `C[M][N] += packed_A * packed_B` over panels from [`pack_a`] /
/// [`pack_b`]. Threads split `C` into `MR`-row strips (never a
/// reduction), every tile accumulates in registers over the full
/// shared dimension, and only real rows/columns are stored — so
/// results are bit-identical for every thread count and every panel
/// alignment of a given column, on both tiers.
pub fn gemm_packed(ap: &[f32], bp: &[f32], c: &mut [f32], m: usize,
                   k: usize, n: usize, threads: usize) {
    let strips = m.div_ceil(MR);
    let panels = n.div_ceil(NR);
    assert_eq!(ap.len(), strips * MR * k, "packed A size mismatch");
    assert_eq!(bp.len(), panels * NR * k, "packed B size mismatch");
    assert_eq!(c.len(), m * n, "output size mismatch");
    let simd = tier().is_simd();
    threadpool::parallel_chunks_mut(c, MR * n, threads, |strip, blk| {
        let a_strip = &ap[strip * MR * k..(strip + 1) * MR * k];
        let rows = blk.len() / n;
        let mut tile = [0f32; MR * NR];
        for p in 0..panels {
            let j0 = p * NR;
            let width = NR.min(n - j0);
            let b_panel = &bp[p * NR * k..(p + 1) * NR * k];
            run_tile(simd, a_strip, b_panel, k, &mut tile);
            for r in 0..rows {
                let dst = &mut blk[r * n + j0..r * n + j0 + width];
                let src = &tile[r * NR..r * NR + width];
                for (d, s) in dst.iter_mut().zip(src) {
                    *d += *s;
                }
            }
        }
    });
}

thread_local! {
    /// Per-thread A/B pack buffers for the drop-in `gemm` SIMD path,
    /// so repeated layer calls on one executor thread reuse capacity.
    static GEMM_PACKS: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// SIMD-tier body of `exec::gemm::gemm`: pack both operands into
/// thread-local buffers and run the tiled kernel. Bitwise identical to
/// the compile-time-packed path ([`PackedA`] + [`gemm_packed`]) on the
/// same inputs, which is what lets the autotuner's packed engine and
/// the dispatched im2col engine coexist under one bit-identity oracle.
pub(crate) fn gemm_simd(a: &[f32], b: &[f32], c: &mut [f32], m: usize,
                        k: usize, n: usize, threads: usize) {
    GEMM_PACKS.with(|cell| {
        let mut packs = cell.borrow_mut();
        let (pa, pb) = &mut *packs;
        pack_a(a, m, k, pa);
        pack_b(b, k, n, pb);
        gemm_packed(pa, pb, c, m, k, n, threads);
    });
}

/// Tier-dispatched dot product over equal-length slices. The scalar
/// path is the seed's sequential multiply-add; the AVX2 path uses two
/// 8-lane FMA accumulators and a horizontal sum.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    if tier().is_simd() {
        // SAFETY: tier() confirmed avx2+fma; dot_avx2 bounds every
        // load by min(a.len(), b.len()) itself, so no length
        // precondition is delegated to callers.
        return unsafe { dot_avx2(a, b) };
    }
    let mut acc = 0f32;
    for (x, w) in a.iter().zip(b) {
        acc += x * w;
    }
    acc
}

/// # Safety
/// Caller must have verified `avx2` and `fma` are available.
#[cfg(all(target_arch = "x86_64", not(miri)))]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len().min(b.len());
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut v0 = _mm256_setzero_ps();
    let mut v1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        v0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)),
                             _mm256_loadu_ps(bp.add(i)), v0);
        v1 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i + 8)),
                             _mm256_loadu_ps(bp.add(i + 8)), v1);
        i += 16;
    }
    if i + 8 <= n {
        v0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(i)),
                             _mm256_loadu_ps(bp.add(i)), v0);
        i += 8;
    }
    let v = _mm256_add_ps(v0, v1);
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps(v, 1);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
    let mut acc = _mm_cvtss_f32(s);
    while i < n {
        acc += *ap.add(i) * *bp.add(i);
        i += 1;
    }
    acc
}

/// Tier-dispatched `y += w * x`. Every `y[j]` receives exactly one
/// multiply-add per call on either tier (lanes are independent), so
/// AXPY-built results stay position-independent per element — the
/// batched-vs-single pins hold per tier.
#[inline]
pub fn axpy(y: &mut [f32], x: &[f32], w: f32) {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    if tier().is_simd() {
        // SAFETY: tier() confirmed avx2+fma; axpy_avx2 bounds every
        // load/store by min(y.len(), x.len()) itself.
        unsafe { axpy_avx2(y, x, w) };
        return;
    }
    for (yo, xo) in y.iter_mut().zip(x.iter()) {
        *yo += w * *xo;
    }
}

/// # Safety
/// Caller must have verified `avx2` and `fma` are available.
#[cfg(all(target_arch = "x86_64", not(miri)))]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_avx2(y: &mut [f32], x: &[f32], w: f32) {
    use std::arch::x86_64::*;
    let n = y.len().min(x.len());
    let wv = _mm256_set1_ps(w);
    let (yp, xp) = (y.as_mut_ptr(), x.as_ptr());
    let mut i = 0usize;
    while i + 8 <= n {
        let yv = _mm256_loadu_ps(yp.add(i));
        let xv = _mm256_loadu_ps(xp.add(i));
        _mm256_storeu_ps(yp.add(i), _mm256_fmadd_ps(wv, xv, yv));
        i += 8;
    }
    while i < n {
        *yp.add(i) += w * *xp.add(i);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn reference(a: &[f32], b: &[f32], m: usize, k: usize, n: usize)
                 -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn packed_gemm_matches_reference_across_shapes() {
        prop::check("packed-gemm-vs-ref", 25, |g| {
            let m = g.usize(1, 30);
            let k = g.usize(1, 40);
            let n = g.usize(1, 50);
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let pa = PackedA::pack(&a, m, k);
            let mut pb = Vec::new();
            pack_b(&b, k, n, &mut pb);
            let mut c = vec![0f32; m * n];
            gemm_packed(pa.buf(), &pb, &mut c, m, k, n, g.usize(1, 4));
            let want = reference(&a, &b, m, k, n);
            prop::assert_allclose(&c, &want, 1e-4, 1e-4)
        });
    }

    #[test]
    fn packed_gemm_thread_invariant_and_accumulating() {
        let mut rng = Rng::seed_from(9);
        let (m, k, n) = (13, 37, 29); // ragged tails on every axis
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let pa = PackedA::pack(&a, m, k);
        let mut pb = Vec::new();
        pack_b(&b, k, n, &mut pb);
        let base: Vec<f32> =
            (0..m * n).map(|_| rng.normal_f32()).collect();
        let mut c1 = base.clone();
        gemm_packed(pa.buf(), &pb, &mut c1, m, k, n, 1);
        let mut c4 = base.clone();
        gemm_packed(pa.buf(), &pb, &mut c4, m, k, n, 4);
        assert_eq!(c1, c4, "thread count changed packed gemm bits");
        let mut again = base.clone();
        gemm_packed(pa.buf(), &pb, &mut again, m, k, n, 1);
        assert_eq!(c1, again, "packed gemm not run-to-run deterministic");
        // C accumulation: re-running adds the product a second time.
        let mut twice = c1.clone();
        gemm_packed(pa.buf(), &pb, &mut twice, m, k, n, 2);
        let prod = reference(&a, &b, m, k, n);
        for ((t, o), p) in twice.iter().zip(&c1).zip(&prod) {
            let want = *o + *p;
            assert!((t - want).abs() <= 1e-3 * want.abs().max(1.0),
                    "not accumulating into C");
        }
    }

    #[test]
    fn pack_layouts_zero_pad_tails() {
        // m=7 -> two strips, second has one real row; n=5 -> one panel
        // with 11 zero columns.
        let m = 7;
        let k = 3;
        let a: Vec<f32> = (0..m * k).map(|i| i as f32 + 1.0).collect();
        let mut pa = Vec::new();
        pack_a(&a, m, k, &mut pa);
        assert_eq!(pa.len(), 2 * MR * k);
        // strip 1, kk=0 holds rows 6..12 -> only row 6 is real.
        let strip1 = &pa[MR * k..MR * k + MR];
        assert_eq!(strip1[0], a[6 * k]);
        assert!(strip1[1..].iter().all(|v| *v == 0.0));
        let n = 5;
        let b: Vec<f32> = (0..k * n).map(|i| i as f32 - 2.0).collect();
        let mut pb = Vec::new();
        pack_b(&b, k, n, &mut pb);
        assert_eq!(pb.len(), NR * k);
        for kk in 0..k {
            let row = &pb[kk * NR..(kk + 1) * NR];
            assert_eq!(&row[..n], &b[kk * n..(kk + 1) * n]);
            assert!(row[n..].iter().all(|v| *v == 0.0));
        }
    }

    #[cfg(all(target_arch = "x86_64", not(miri)))]
    #[test]
    fn avx2_tile_matches_scalar_tile() {
        if !(is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma"))
        {
            return;
        }
        let mut rng = Rng::seed_from(4);
        let k = 53;
        let ap: Vec<f32> =
            (0..k * MR).map(|_| rng.normal_f32()).collect();
        let bp: Vec<f32> =
            (0..k * NR).map(|_| rng.normal_f32()).collect();
        let mut scalar = [0f32; MR * NR];
        tile_scalar(&ap, &bp, k, &mut scalar);
        let mut simd = [0f32; MR * NR];
        // SAFETY: feature presence checked above.
        unsafe { tile_avx2(&ap, &bp, k, &mut simd) };
        for (s, v) in scalar.iter().zip(&simd) {
            assert!((s - v).abs() <= 1e-4 * s.abs().max(1.0),
                    "tile kernels diverged: {s} vs {v}");
        }
    }

    #[test]
    fn dot_and_axpy_match_scalar_semantics() {
        let mut rng = Rng::seed_from(11);
        for len in [0usize, 1, 7, 8, 9, 16, 17, 40] {
            let a: Vec<f32> =
                (0..len).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> =
                (0..len).map(|_| rng.normal_f32()).collect();
            let mut want = 0f32;
            for (x, w) in a.iter().zip(&b) {
                want += x * w;
            }
            let got = dot(&a, &b);
            assert!((got - want).abs() <= 1e-4 * want.abs().max(1.0),
                    "dot len {len}: {got} vs {want}");
            let mut y = a.clone();
            axpy(&mut y, &b, 0.5);
            for ((yv, av), bv) in y.iter().zip(&a).zip(&b) {
                let w = av + 0.5 * bv;
                assert!((yv - w).abs() <= 1e-5 * w.abs().max(1.0),
                        "axpy len {len}");
            }
        }
    }

    #[test]
    fn tier_reporting_is_populated() {
        let t = tier();
        assert!(!t.label().is_empty());
        assert!(!cpu_features().is_empty());
        assert!(peak_gflops(1) > 0.0);
        assert!(peak_gflops(4) > peak_gflops(1));
    }
}
