//! CSR sparse convolution — what non-structured pruning forces on the
//! executor (paper §2.1.1): per-weight index decoding, irregular access,
//! no tap-level unrolling. Deliberately representative, not crippled —
//! rows are still walked in AXPY form where possible.

use crate::compress::CsrLayer;
use crate::exec::tensor::{same_pad, BatchView, Tensor, TensorView};
use crate::util::threadpool;

/// Sparse conv2d from a CSR layer, SAME padding, optional fused ReLU.
pub fn conv2d(input: &Tensor, layer: &CsrLayer, stride: usize, relu: bool,
              threads: usize) -> Tensor {
    let (h_out, _) = same_pad(input.h, layer.kh, stride);
    let (w_out, _) = same_pad(input.w, layer.kw, stride);
    let mut out = Tensor::zeros(layer.cout, h_out, w_out);
    conv2d_into(input.view(), layer, stride, relu, threads, &mut out.data);
    out
}

/// [`conv2d`] writing into a preassigned output buffer (arena slot).
pub fn conv2d_into(input: TensorView<'_>, layer: &CsrLayer, stride: usize,
                   relu: bool, threads: usize, out: &mut [f32]) {
    let (h_out, pad_h) = same_pad(input.h, layer.kh, stride);
    let (w_out, pad_w) = same_pad(input.w, layer.kw, stride);
    let hw = h_out * w_out;
    assert_eq!(out.len(), layer.cout * hw, "output buffer size mismatch");
    let khw = layer.kh * layer.kw;
    threadpool::parallel_chunks_mut(out, hw, threads, |co, plane| {
        plane.fill(layer.bias[co]);
        for e in layer.row_ptr[co] as usize..layer.row_ptr[co + 1] as usize {
            // Decode the flat column index — the per-weight cost that
            // pattern storage avoids.
            let col = layer.col_idx[e] as usize;
            let ci = col / khw;
            let rem = col % khw;
            let ky = rem / layer.kw;
            let kx = rem % layer.kw;
            let w = layer.values[e];
            let in_plane = input.plane(ci);
            for y in 0..h_out {
                let iy = (y * stride + ky) as isize - pad_h as isize;
                if iy < 0 || iy >= input.h as isize {
                    continue;
                }
                let in_row = &in_plane
                    [iy as usize * input.w..(iy as usize + 1) * input.w];
                let out_row = &mut plane[y * w_out..(y + 1) * w_out];
                if stride == 1 {
                    let x_lo = pad_w.saturating_sub(kx);
                    let x_hi = (input.w + pad_w - kx).min(w_out);
                    if x_lo < x_hi {
                        let src0 = x_lo + kx - pad_w;
                        for (o, i) in out_row[x_lo..x_hi]
                            .iter_mut()
                            .zip(&in_row[src0..src0 + (x_hi - x_lo)])
                        {
                            *o += w * *i;
                        }
                    }
                } else {
                    for (x, o) in out_row.iter_mut().enumerate() {
                        let ix =
                            (x * stride + kx) as isize - pad_w as isize;
                        if ix >= 0 && (ix as usize) < input.w {
                            *o += w * in_row[ix as usize];
                        }
                    }
                }
            }
        }
        if relu {
            for v in plane.iter_mut() {
                *v = v.max(0.0);
            }
        }
    });
}

/// Batched [`conv2d_into`]: per-image loop behind the same
/// `[N][C][H][W]` signature as the fused engines (the CSR ablation is
/// not a hot serving path, so it pays the per-image index decode).
pub fn conv2d_batch_into(input: BatchView<'_>, layer: &CsrLayer,
                         stride: usize, relu: bool, threads: usize,
                         out: &mut [f32]) {
    let (h_out, _) = same_pad(input.h, layer.kh, stride);
    let (w_out, _) = same_pad(input.w, layer.kw, stride);
    let per = layer.cout * h_out * w_out;
    assert_eq!(out.len(), input.n * per, "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(per).enumerate() {
        conv2d_into(input.image(img), layer, stride, relu, threads,
                    chunk);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{CsrLayer, DenseLayer};
    use crate::exec::naive;
    use crate::patterns::connectivity::prune_unstructured;
    use crate::util::prop;

    #[test]
    fn matches_naive_on_pruned_weights() {
        prop::check("csr-vs-naive", 20, |g| {
            let cin = g.usize(1, 6);
            let cout = g.usize(1, 8);
            let h = g.usize(3, 12);
            let w = g.usize(3, 12);
            let stride = *g.pick(&[1usize, 2]);
            let keep = g.f64(0.1, 0.9);
            let mut rng = g.rng().clone();
            let input = Tensor::random(cin, h, w, &mut rng);
            let mut dense = DenseLayer {
                cout,
                cin,
                kh: 3,
                kw: 3,
                weights: (0..cout * cin * 9)
                    .map(|_| rng.normal_f32())
                    .collect(),
                bias: (0..cout).map(|_| rng.normal_f32()).collect(),
            };
            let mask = prune_unstructured(&dense.weights, keep);
            for (wv, m) in dense.weights.iter_mut().zip(&mask) {
                if !m {
                    *wv = 0.0;
                }
            }
            let csr = CsrLayer::from_dense(&dense, None);
            let got = conv2d(&input, &csr, stride, false, g.usize(1, 4));
            let want = naive::conv2d(&input, &dense, stride, false, 1);
            if got.max_abs_diff(&want) > 1e-4 {
                return Err(format!("diff {}", got.max_abs_diff(&want)));
            }
            Ok(())
        });
    }
}
