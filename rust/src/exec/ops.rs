//! Shared non-conv ops: depthwise conv, max-pool, global average pool,
//! fully connected, residual add.

use crate::exec::tensor::{same_pad, Tensor};

/// Depthwise 3x3 conv, SAME padding; weights w[c][ky][kx], bias[c].
pub fn depthwise3x3(input: &Tensor, weights: &[f32], bias: &[f32],
                    stride: usize, relu: bool) -> Tensor {
    assert_eq!(weights.len(), 9 * input.c);
    let (h_out, pad_h) = same_pad(input.h, 3, stride);
    let (w_out, pad_w) = same_pad(input.w, 3, stride);
    let mut out = Tensor::zeros(input.c, h_out, w_out);
    for c in 0..input.c {
        let in_plane = input.plane(c);
        let w9 = &weights[c * 9..c * 9 + 9];
        let b = bias[c];
        let plane = out.plane_mut(c);
        plane.fill(b);
        for ky in 0..3 {
            for kx in 0..3 {
                let w = w9[ky * 3 + kx];
                if w == 0.0 {
                    continue;
                }
                for y in 0..h_out {
                    let iy = (y * stride + ky) as isize - pad_h as isize;
                    if iy < 0 || iy >= input.h as isize {
                        continue;
                    }
                    let in_row = &in_plane[iy as usize * input.w
                        ..(iy as usize + 1) * input.w];
                    let out_row = &mut plane[y * w_out..(y + 1) * w_out];
                    for (x, o) in out_row.iter_mut().enumerate() {
                        let ix =
                            (x * stride + kx) as isize - pad_w as isize;
                        if ix >= 0 && (ix as usize) < input.w {
                            *o += w * in_row[ix as usize];
                        }
                    }
                }
            }
        }
        if relu {
            for v in plane.iter_mut() {
                *v = v.max(0.0);
            }
        }
    }
    out
}

/// 2x2 max pool, stride 2, SAME (ceil) semantics.
pub fn maxpool2(input: &Tensor) -> Tensor {
    let h_out = input.h.div_ceil(2);
    let w_out = input.w.div_ceil(2);
    let mut out = Tensor::zeros(input.c, h_out, w_out);
    for c in 0..input.c {
        let in_plane = input.plane(c);
        let plane = out.plane_mut(c);
        for y in 0..h_out {
            for x in 0..w_out {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let iy = y * 2 + dy;
                        let ix = x * 2 + dx;
                        if iy < input.h && ix < input.w {
                            m = m.max(in_plane[iy * input.w + ix]);
                        }
                    }
                }
                plane[y * w_out + x] = m;
            }
        }
    }
    out
}

/// Global average pool -> [C,1,1].
pub fn gap(input: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(input.c, 1, 1);
    let hw = (input.h * input.w) as f32;
    for c in 0..input.c {
        out.data[c] = input.plane(c).iter().sum::<f32>() / hw;
    }
    out
}

/// Fully connected over the flattened input; w[cout][cin_flat].
pub fn dense(input: &Tensor, weights: &[f32], bias: &[f32], cout: usize,
             relu: bool) -> Tensor {
    let cin = input.data.len();
    assert_eq!(weights.len(), cout * cin);
    let mut out = Tensor::zeros(cout, 1, 1);
    for co in 0..cout {
        let row = &weights[co * cin..(co + 1) * cin];
        let mut acc = bias[co];
        for (w, x) in row.iter().zip(&input.data) {
            acc += w * x;
        }
        out.data[co] = if relu { acc.max(0.0) } else { acc };
    }
    out
}

/// Elementwise residual add (+ optional ReLU).
pub fn add(a: &Tensor, b: &Tensor, relu: bool) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let mut out = a.clone();
    for (o, v) in out.data.iter_mut().zip(&b.data) {
        *o += *v;
        if relu {
            *o = o.max(0.0);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn maxpool_basics() {
        let t = Tensor {
            c: 1,
            h: 2,
            w: 2,
            data: vec![1.0, 2.0, 3.0, 4.0],
        };
        let p = maxpool2(&t);
        assert_eq!((p.h, p.w), (1, 1));
        assert_eq!(p.data[0], 4.0);
        // odd size: ceil semantics
        let t = Tensor::zeros(1, 3, 3);
        assert_eq!(maxpool2(&t).h, 2);
    }

    #[test]
    fn gap_means() {
        let t = Tensor {
            c: 2,
            h: 1,
            w: 2,
            data: vec![1.0, 3.0, 10.0, 20.0],
        };
        let g = gap(&t);
        assert_eq!(g.data, vec![2.0, 15.0]);
    }

    #[test]
    fn dense_known_values() {
        let t = Tensor {
            c: 2,
            h: 1,
            w: 1,
            data: vec![1.0, 2.0],
        };
        let out = dense(&t, &[1.0, 1.0, 0.5, -1.0], &[0.0, 1.0], 2, false);
        assert_eq!(out.data, vec![3.0, -0.5]);
        let out = dense(&t, &[1.0, 1.0, 0.5, -1.0], &[0.0, 1.0], 2, true);
        assert_eq!(out.data, vec![3.0, 0.0]);
    }

    #[test]
    fn add_and_relu() {
        let mut rng = Rng::seed_from(2);
        let a = Tensor::random(2, 3, 3, &mut rng);
        let b = Tensor::random(2, 3, 3, &mut rng);
        let s = add(&a, &b, false);
        assert!((s.data[5] - (a.data[5] + b.data[5])).abs() < 1e-6);
        let r = add(&a, &b, true);
        assert!(r.data.iter().all(|v| *v >= 0.0));
    }

    #[test]
    fn depthwise_identity() {
        let mut rng = Rng::seed_from(3);
        let input = Tensor::random(3, 5, 5, &mut rng);
        // centre-tap-only kernel = identity
        let mut w = vec![0f32; 27];
        for c in 0..3 {
            w[c * 9 + 4] = 1.0;
        }
        let out = depthwise3x3(&input, &w, &[0.0; 3], 1, false);
        assert!(out.max_abs_diff(&input) < 1e-6);
    }
}
