//! Shared non-conv ops: depthwise conv, max-pool, global average pool,
//! fully connected, residual add.
//!
//! Each op has two entry points: a one-shot form returning a fresh
//! [`Tensor`] (benchmarks, oracle tests) and a `*_into` form writing
//! into a preassigned buffer — what the compiled-op pipeline calls so
//! steady-state inference allocates nothing beyond its arena.

use crate::exec::tensor::{same_pad, BatchView, Tensor, TensorView};

/// Depthwise 3x3 conv, SAME padding; weights `w[c][ky][kx]`, `bias[c]`.
pub fn depthwise3x3(input: &Tensor, weights: &[f32], bias: &[f32],
                    stride: usize, relu: bool) -> Tensor {
    let (h_out, _) = same_pad(input.h, 3, stride);
    let (w_out, _) = same_pad(input.w, 3, stride);
    let mut out = Tensor::zeros(input.c, h_out, w_out);
    depthwise3x3_into(input.view(), weights, bias, stride, relu,
                      &mut out.data);
    out
}

/// [`depthwise3x3`] writing into a preassigned output buffer.
pub fn depthwise3x3_into(input: TensorView<'_>, weights: &[f32],
                         bias: &[f32], stride: usize, relu: bool,
                         out: &mut [f32]) {
    assert_eq!(weights.len(), 9 * input.c);
    let (h_out, pad_h) = same_pad(input.h, 3, stride);
    let (w_out, pad_w) = same_pad(input.w, 3, stride);
    let hw = h_out * w_out;
    assert_eq!(out.len(), input.c * hw, "output buffer size mismatch");
    for c in 0..input.c {
        let in_plane = input.plane(c);
        let w9 = &weights[c * 9..c * 9 + 9];
        let b = bias[c];
        let plane = &mut out[c * hw..(c + 1) * hw];
        plane.fill(b);
        for ky in 0..3 {
            for kx in 0..3 {
                let w = w9[ky * 3 + kx];
                if w == 0.0 {
                    continue;
                }
                for y in 0..h_out {
                    let iy = (y * stride + ky) as isize - pad_h as isize;
                    if iy < 0 || iy >= input.h as isize {
                        continue;
                    }
                    let in_row = &in_plane[iy as usize * input.w
                        ..(iy as usize + 1) * input.w];
                    let out_row = &mut plane[y * w_out..(y + 1) * w_out];
                    for (x, o) in out_row.iter_mut().enumerate() {
                        let ix =
                            (x * stride + kx) as isize - pad_w as isize;
                        if ix >= 0 && (ix as usize) < input.w {
                            *o += w * in_row[ix as usize];
                        }
                    }
                }
            }
        }
        if relu {
            for v in plane.iter_mut() {
                *v = v.max(0.0);
            }
        }
    }
}

/// 2x2 max pool, stride 2, SAME (ceil) semantics.
pub fn maxpool2(input: &Tensor) -> Tensor {
    let h_out = input.h.div_ceil(2);
    let w_out = input.w.div_ceil(2);
    let mut out = Tensor::zeros(input.c, h_out, w_out);
    maxpool2_into(input.view(), &mut out.data);
    out
}

/// [`maxpool2`] writing into a preassigned output buffer.
pub fn maxpool2_into(input: TensorView<'_>, out: &mut [f32]) {
    let h_out = input.h.div_ceil(2);
    let w_out = input.w.div_ceil(2);
    let hw = h_out * w_out;
    assert_eq!(out.len(), input.c * hw, "output buffer size mismatch");
    for c in 0..input.c {
        let in_plane = input.plane(c);
        let plane = &mut out[c * hw..(c + 1) * hw];
        for y in 0..h_out {
            for x in 0..w_out {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let iy = y * 2 + dy;
                        let ix = x * 2 + dx;
                        if iy < input.h && ix < input.w {
                            m = m.max(in_plane[iy * input.w + ix]);
                        }
                    }
                }
                plane[y * w_out + x] = m;
            }
        }
    }
}

/// Global average pool -> [C,1,1].
pub fn gap(input: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(input.c, 1, 1);
    gap_into(input.view(), &mut out.data);
    out
}

/// [`gap`] writing into a preassigned output buffer of `c` elements.
pub fn gap_into(input: TensorView<'_>, out: &mut [f32]) {
    assert_eq!(out.len(), input.c, "output buffer size mismatch");
    let hw = (input.h * input.w) as f32;
    for c in 0..input.c {
        out[c] = input.plane(c).iter().sum::<f32>() / hw;
    }
}

/// Fully connected over the flattened input; `w[cout][cin_flat]`.
pub fn dense(input: &Tensor, weights: &[f32], bias: &[f32], cout: usize,
             relu: bool) -> Tensor {
    let mut out = Tensor::zeros(cout, 1, 1);
    dense_into(&input.data, weights, bias, cout, relu, &mut out.data);
    out
}

/// [`dense`] over a flat input slice, writing into a preassigned output
/// buffer of `cout` elements.
pub fn dense_into(input: &[f32], weights: &[f32], bias: &[f32],
                  cout: usize, relu: bool, out: &mut [f32]) {
    let cin = input.len();
    assert_eq!(weights.len(), cout * cin);
    assert_eq!(out.len(), cout, "output buffer size mismatch");
    for (co, o) in out.iter_mut().enumerate() {
        let row = &weights[co * cin..(co + 1) * cin];
        let mut acc = bias[co];
        for (w, x) in row.iter().zip(input) {
            acc += w * x;
        }
        *o = if relu { acc.max(0.0) } else { acc };
    }
}

/// Elementwise residual add (+ optional ReLU).
pub fn add(a: &Tensor, b: &Tensor, relu: bool) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let mut out = Tensor::zeros(a.c, a.h, a.w);
    add_into(&a.data, &b.data, relu, &mut out.data);
    out
}

/// [`add`] over flat slices, writing into a preassigned output buffer.
/// `out` may not alias the inputs (the memory plan guarantees this).
pub fn add_into(a: &[f32], b: &[f32], relu: bool, out: &mut [f32]) {
    assert_eq!(a.len(), b.len(), "add operand length mismatch");
    assert_eq!(out.len(), a.len(), "output buffer size mismatch");
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        let v = x + y;
        *o = if relu { v.max(0.0) } else { v };
    }
}

/// Batched [`depthwise3x3_into`]: per-image loop behind the same
/// `[N][C][H][W]` signature as the fused conv engines.
pub fn depthwise3x3_batch_into(input: BatchView<'_>, weights: &[f32],
                               bias: &[f32], stride: usize, relu: bool,
                               out: &mut [f32]) {
    let (h_out, _) = same_pad(input.h, 3, stride);
    let (w_out, _) = same_pad(input.w, 3, stride);
    let per = input.c * h_out * w_out;
    assert_eq!(out.len(), input.n * per, "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(per).enumerate() {
        depthwise3x3_into(input.image(img), weights, bias, stride, relu,
                          chunk);
    }
}

/// Batched [`maxpool2_into`].
pub fn maxpool2_batch_into(input: BatchView<'_>, out: &mut [f32]) {
    let per = input.c * input.h.div_ceil(2) * input.w.div_ceil(2);
    assert_eq!(out.len(), input.n * per, "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(per).enumerate() {
        maxpool2_into(input.image(img), chunk);
    }
}

/// Batched [`gap_into`]: `out` is `[n][c]`.
pub fn gap_batch_into(input: BatchView<'_>, out: &mut [f32]) {
    assert_eq!(out.len(), input.n * input.c,
               "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(input.c).enumerate() {
        gap_into(input.image(img), chunk);
    }
}

/// Batched [`dense_into`] over `n` flattened input rows of `cin`
/// elements each; `out` is `[n][cout]`.
pub fn dense_batch_into(input: &[f32], n: usize, weights: &[f32],
                        bias: &[f32], cout: usize, relu: bool,
                        out: &mut [f32]) {
    assert_eq!(input.len() % n.max(1), 0, "ragged batched FC input");
    let cin = input.len() / n.max(1);
    assert_eq!(out.len(), n * cout, "output buffer size mismatch");
    for img in 0..n {
        dense_into(&input[img * cin..(img + 1) * cin], weights, bias,
                   cout, relu, &mut out[img * cout..(img + 1) * cout]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn maxpool_basics() {
        let t = Tensor {
            c: 1,
            h: 2,
            w: 2,
            data: vec![1.0, 2.0, 3.0, 4.0],
        };
        let p = maxpool2(&t);
        assert_eq!((p.h, p.w), (1, 1));
        assert_eq!(p.data[0], 4.0);
        // odd size: ceil semantics
        let t = Tensor::zeros(1, 3, 3);
        assert_eq!(maxpool2(&t).h, 2);
    }

    #[test]
    fn gap_means() {
        let t = Tensor {
            c: 2,
            h: 1,
            w: 2,
            data: vec![1.0, 3.0, 10.0, 20.0],
        };
        let g = gap(&t);
        assert_eq!(g.data, vec![2.0, 15.0]);
    }

    #[test]
    fn dense_known_values() {
        let t = Tensor {
            c: 2,
            h: 1,
            w: 1,
            data: vec![1.0, 2.0],
        };
        let out = dense(&t, &[1.0, 1.0, 0.5, -1.0], &[0.0, 1.0], 2, false);
        assert_eq!(out.data, vec![3.0, -0.5]);
        let out = dense(&t, &[1.0, 1.0, 0.5, -1.0], &[0.0, 1.0], 2, true);
        assert_eq!(out.data, vec![3.0, 0.0]);
    }

    #[test]
    fn add_and_relu() {
        let mut rng = Rng::seed_from(2);
        let a = Tensor::random(2, 3, 3, &mut rng);
        let b = Tensor::random(2, 3, 3, &mut rng);
        let s = add(&a, &b, false);
        assert!((s.data[5] - (a.data[5] + b.data[5])).abs() < 1e-6);
        let r = add(&a, &b, true);
        assert!(r.data.iter().all(|v| *v >= 0.0));
    }

    #[test]
    fn into_forms_overwrite_stale_buffers() {
        // Arena slots arrive dirty; every *_into must fully overwrite.
        let mut rng = Rng::seed_from(7);
        let input = Tensor::random(3, 6, 6, &mut rng);
        let want = maxpool2(&input);
        let mut buf = vec![f32::NAN; want.data.len()];
        maxpool2_into(input.view(), &mut buf);
        assert_eq!(buf, want.data);

        let w: Vec<f32> = (0..27).map(|_| rng.normal_f32()).collect();
        let b = vec![0.1f32; 3];
        let want = depthwise3x3(&input, &w, &b, 1, true);
        let mut buf = vec![f32::NAN; want.data.len()];
        depthwise3x3_into(input.view(), &w, &b, 1, true, &mut buf);
        assert_eq!(buf, want.data);
    }

    #[test]
    fn depthwise_identity() {
        let mut rng = Rng::seed_from(3);
        let input = Tensor::random(3, 5, 5, &mut rng);
        // centre-tap-only kernel = identity
        let mut w = vec![0f32; 27];
        for c in 0..3 {
            w[c * 9 + 4] = 1.0;
        }
        let out = depthwise3x3(&input, &w, &[0.0; 3], 1, false);
        assert!(out.max_abs_diff(&input) < 1e-6);
    }
}
