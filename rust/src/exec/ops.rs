//! Shared non-conv ops: depthwise conv, max-pool, global average pool,
//! fully connected, residual add — plus the sequence-tier kernels
//! (per-token projection over any [`ProjStore`] format, layer norm,
//! multi-head self-attention, sequence mean-pool).
//!
//! Each op has a `*_into` form writing into a preassigned buffer — what
//! the compiled-op pipeline calls so steady-state inference allocates
//! nothing beyond its arena. The sequence projections keep the same
//! accumulation order across their dense/CSR/int8 variants (one
//! sequential dot per output, bias added last), so the pruned and
//! dequant-on-load paths are bit-identical to the dense kernel run on
//! their materialized f32 twins — the property `tests/seq_pipeline.rs`
//! asserts, mirroring the conv engines.

use crate::compress::{AttnWeights, CsrLayer, FlatWeights, ProjStore};
use crate::exec::gemm;
use crate::exec::micro;
use crate::exec::tensor::{same_pad, BatchView, Tensor, TensorView};
use crate::quant::QuantDense;

/// Depthwise 3x3 conv, SAME padding; weights `w[c][ky][kx]`, `bias[c]`.
pub fn depthwise3x3(input: &Tensor, weights: &[f32], bias: &[f32],
                    stride: usize, relu: bool) -> Tensor {
    let (h_out, _) = same_pad(input.h, 3, stride);
    let (w_out, _) = same_pad(input.w, 3, stride);
    let mut out = Tensor::zeros(input.c, h_out, w_out);
    depthwise3x3_into(input.view(), weights, bias, stride, relu,
                      &mut out.data);
    out
}

/// [`depthwise3x3`] writing into a preassigned output buffer.
pub fn depthwise3x3_into(input: TensorView<'_>, weights: &[f32],
                         bias: &[f32], stride: usize, relu: bool,
                         out: &mut [f32]) {
    assert_eq!(weights.len(), 9 * input.c);
    let (h_out, pad_h) = same_pad(input.h, 3, stride);
    let (w_out, pad_w) = same_pad(input.w, 3, stride);
    let hw = h_out * w_out;
    assert_eq!(out.len(), input.c * hw, "output buffer size mismatch");
    for c in 0..input.c {
        let in_plane = input.plane(c);
        let w9 = &weights[c * 9..c * 9 + 9];
        let b = bias[c];
        let plane = &mut out[c * hw..(c + 1) * hw];
        plane.fill(b);
        for ky in 0..3 {
            for kx in 0..3 {
                let w = w9[ky * 3 + kx];
                if w == 0.0 {
                    continue;
                }
                for y in 0..h_out {
                    let iy = (y * stride + ky) as isize - pad_h as isize;
                    if iy < 0 || iy >= input.h as isize {
                        continue;
                    }
                    let in_row = &in_plane[iy as usize * input.w
                        ..(iy as usize + 1) * input.w];
                    let out_row = &mut plane[y * w_out..(y + 1) * w_out];
                    for (x, o) in out_row.iter_mut().enumerate() {
                        let ix =
                            (x * stride + kx) as isize - pad_w as isize;
                        if ix >= 0 && (ix as usize) < input.w {
                            *o += w * in_row[ix as usize];
                        }
                    }
                }
            }
        }
        if relu {
            for v in plane.iter_mut() {
                *v = v.max(0.0);
            }
        }
    }
}

/// 2x2 max pool, stride 2, SAME (ceil) semantics.
pub fn maxpool2(input: &Tensor) -> Tensor {
    let h_out = input.h.div_ceil(2);
    let w_out = input.w.div_ceil(2);
    let mut out = Tensor::zeros(input.c, h_out, w_out);
    maxpool2_into(input.view(), &mut out.data);
    out
}

/// [`maxpool2`] writing into a preassigned output buffer.
pub fn maxpool2_into(input: TensorView<'_>, out: &mut [f32]) {
    let h_out = input.h.div_ceil(2);
    let w_out = input.w.div_ceil(2);
    let hw = h_out * w_out;
    assert_eq!(out.len(), input.c * hw, "output buffer size mismatch");
    for c in 0..input.c {
        let in_plane = input.plane(c);
        let plane = &mut out[c * hw..(c + 1) * hw];
        for y in 0..h_out {
            for x in 0..w_out {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let iy = y * 2 + dy;
                        let ix = x * 2 + dx;
                        if iy < input.h && ix < input.w {
                            m = m.max(in_plane[iy * input.w + ix]);
                        }
                    }
                }
                plane[y * w_out + x] = m;
            }
        }
    }
}

/// Global average pool -> [C,1,1].
pub fn gap(input: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(input.c, 1, 1);
    gap_into(input.view(), &mut out.data);
    out
}

/// [`gap`] writing into a preassigned output buffer of `c` elements.
pub fn gap_into(input: TensorView<'_>, out: &mut [f32]) {
    assert_eq!(out.len(), input.c, "output buffer size mismatch");
    let hw = (input.h * input.w) as f32;
    for c in 0..input.c {
        out[c] = input.plane(c).iter().sum::<f32>() / hw;
    }
}

/// Fully connected over the flattened input; `w[cout][cin_flat]`.
pub fn dense(input: &Tensor, weights: &[f32], bias: &[f32], cout: usize,
             relu: bool) -> Tensor {
    let mut out = Tensor::zeros(cout, 1, 1);
    dense_into(&input.data, weights, bias, cout, relu, &mut out.data);
    out
}

/// [`dense`] over a flat input slice, writing into a preassigned output
/// buffer of `cout` elements. On the SIMD tier each output row runs
/// the vectorized [`micro::dot`]; the scalar tier keeps the seed's
/// bias-first sequential accumulation.
pub fn dense_into(input: &[f32], weights: &[f32], bias: &[f32],
                  cout: usize, relu: bool, out: &mut [f32]) {
    let cin = input.len();
    assert_eq!(weights.len(), cout * cin);
    assert_eq!(out.len(), cout, "output buffer size mismatch");
    if micro::tier().is_simd() {
        for (co, o) in out.iter_mut().enumerate() {
            let row = &weights[co * cin..(co + 1) * cin];
            let acc = bias[co] + micro::dot(row, input);
            *o = if relu { acc.max(0.0) } else { acc };
        }
        return;
    }
    for (co, o) in out.iter_mut().enumerate() {
        let row = &weights[co * cin..(co + 1) * cin];
        let mut acc = bias[co];
        for (w, x) in row.iter().zip(input) {
            acc += w * x;
        }
        *o = if relu { acc.max(0.0) } else { acc };
    }
}

/// Elementwise residual add (+ optional ReLU).
pub fn add(a: &Tensor, b: &Tensor, relu: bool) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let mut out = Tensor::zeros(a.c, a.h, a.w);
    add_into(&a.data, &b.data, relu, &mut out.data);
    out
}

/// [`add`] over flat slices, writing into a preassigned output buffer.
/// `out` may not alias the inputs (the memory plan guarantees this).
pub fn add_into(a: &[f32], b: &[f32], relu: bool, out: &mut [f32]) {
    assert_eq!(a.len(), b.len(), "add operand length mismatch");
    assert_eq!(out.len(), a.len(), "output buffer size mismatch");
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        let v = x + y;
        *o = if relu { v.max(0.0) } else { v };
    }
}

/// Batched [`depthwise3x3_into`]: per-image loop behind the same
/// `[N][C][H][W]` signature as the fused conv engines.
pub fn depthwise3x3_batch_into(input: BatchView<'_>, weights: &[f32],
                               bias: &[f32], stride: usize, relu: bool,
                               out: &mut [f32]) {
    let (h_out, _) = same_pad(input.h, 3, stride);
    let (w_out, _) = same_pad(input.w, 3, stride);
    let per = input.c * h_out * w_out;
    assert_eq!(out.len(), input.n * per, "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(per).enumerate() {
        depthwise3x3_into(input.image(img), weights, bias, stride, relu,
                          chunk);
    }
}

/// Batched [`maxpool2_into`].
pub fn maxpool2_batch_into(input: BatchView<'_>, out: &mut [f32]) {
    let per = input.c * input.h.div_ceil(2) * input.w.div_ceil(2);
    assert_eq!(out.len(), input.n * per, "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(per).enumerate() {
        maxpool2_into(input.image(img), chunk);
    }
}

/// Batched [`gap_into`]: `out` is `[n][c]`.
pub fn gap_batch_into(input: BatchView<'_>, out: &mut [f32]) {
    assert_eq!(out.len(), input.n * input.c,
               "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(input.c).enumerate() {
        gap_into(input.image(img), chunk);
    }
}

/// Batched [`dense_into`] over `n` flattened input rows of `cin`
/// elements each; `out` is `[n][cout]`.
pub fn dense_batch_into(input: &[f32], n: usize, weights: &[f32],
                        bias: &[f32], cout: usize, relu: bool,
                        out: &mut [f32]) {
    assert_eq!(input.len() % n.max(1), 0, "ragged batched FC input");
    let cin = input.len() / n.max(1);
    assert_eq!(out.len(), n * cout, "output buffer size mismatch");
    for img in 0..n {
        dense_into(&input[img * cin..(img + 1) * cin], weights, bias,
                   cout, relu, &mut out[img * cout..(img + 1) * cout]);
    }
}

/// Per-token projection `[T, d_in] -> [T, d_out]` with dense weights
/// `[d_out, d_in]` row-major: `out = x W^T + bias` (+ optional ReLU).
pub fn seq_matmul_into(input: &[f32], t: usize, d_in: usize,
                       w: &FlatWeights, relu: bool, threads: usize,
                       out: &mut [f32]) {
    let d_out = w.bias.len();
    assert_eq!(input.len(), t * d_in, "projection input size mismatch");
    assert_eq!(w.weights.len(), d_out * d_in,
               "projection weight size mismatch");
    assert_eq!(out.len(), t * d_out, "output buffer size mismatch");
    for row in out.chunks_mut(d_out) {
        row.copy_from_slice(&w.bias);
    }
    gemm::gemm_nt(input, &w.weights, out, t, d_in, d_out, threads);
    if relu {
        for v in out.iter_mut() {
            *v = v.max(0.0);
        }
    }
}

/// [`seq_matmul_into`] over CSR rows (unstructured-pruned projection).
/// Column order inside a row is ascending, so skipping the pruned zeros
/// reproduces the dense kernel's accumulation bits exactly.
pub fn seq_matmul_csr_into(input: &[f32], t: usize, d_in: usize,
                           w: &CsrLayer, relu: bool, out: &mut [f32]) {
    assert_eq!(w.cin * w.kh * w.kw, d_in, "CSR projection width mismatch");
    let d_out = w.cout;
    assert_eq!(input.len(), t * d_in, "projection input size mismatch");
    assert_eq!(out.len(), t * d_out, "output buffer size mismatch");
    for (tok, row_out) in out.chunks_mut(d_out).enumerate() {
        let x = &input[tok * d_in..(tok + 1) * d_in];
        for (o, dst) in row_out.iter_mut().enumerate() {
            let mut acc = 0f32;
            for e in w.row_ptr[o] as usize..w.row_ptr[o + 1] as usize {
                acc += w.values[e] * x[w.col_idx[e] as usize];
            }
            let v = w.bias[o] + acc;
            *dst = if relu { v.max(0.0) } else { v };
        }
    }
}

/// [`seq_matmul_into`] with weight-only int8 storage, dequantized
/// in-register (`w ~= q * scale[o]`) — same accumulation order as the
/// dense kernel on the dequantized twin.
pub fn seq_matmul_quant_into(input: &[f32], t: usize, d_in: usize,
                             w: &QuantDense, relu: bool, out: &mut [f32]) {
    assert_eq!(w.cin * w.kh * w.kw, d_in,
               "quant projection width mismatch");
    let d_out = w.cout;
    assert_eq!(input.len(), t * d_in, "projection input size mismatch");
    assert_eq!(out.len(), t * d_out, "output buffer size mismatch");
    for (tok, row_out) in out.chunks_mut(d_out).enumerate() {
        let x = &input[tok * d_in..(tok + 1) * d_in];
        for (o, dst) in row_out.iter_mut().enumerate() {
            let s = w.scales[o];
            let wrow = &w.weights[o * d_in..(o + 1) * d_in];
            let mut acc = 0f32;
            for (q, xi) in wrow.iter().zip(x) {
                acc += (*q as f32 * s) * xi;
            }
            let v = w.bias[o] + acc;
            *dst = if relu { v.max(0.0) } else { v };
        }
    }
}

/// Per-token projection over any [`ProjStore`] format — the single
/// dispatch point the compiled sequence kernels call.
pub fn proj_into(input: &[f32], t: usize, d_in: usize, w: &ProjStore,
                 relu: bool, threads: usize, out: &mut [f32]) {
    match w {
        ProjStore::Dense(f) => {
            seq_matmul_into(input, t, d_in, f, relu, threads, out)
        }
        ProjStore::Csr(c) => {
            seq_matmul_csr_into(input, t, d_in, c, relu, out)
        }
        ProjStore::Int8(q) => {
            seq_matmul_quant_into(input, t, d_in, q, relu, out)
        }
    }
}

/// Per-token layer normalization over the width `d` with learned
/// gamma (`w.weights`) and beta (`w.bias`); eps = 1e-5, fp32 statistics.
pub fn layernorm_into(input: &[f32], t: usize, d: usize, gamma: &[f32],
                      beta: &[f32], out: &mut [f32]) {
    assert_eq!(input.len(), t * d, "layernorm input size mismatch");
    assert_eq!(gamma.len(), d, "layernorm gamma size mismatch");
    assert_eq!(beta.len(), d, "layernorm beta size mismatch");
    assert_eq!(out.len(), t * d, "output buffer size mismatch");
    for (tok, row_out) in out.chunks_mut(d).enumerate() {
        let x = &input[tok * d..(tok + 1) * d];
        let mean = x.iter().sum::<f32>() / d as f32;
        let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
            / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for ((o, xi), (g, b)) in
            row_out.iter_mut().zip(x).zip(gamma.iter().zip(beta))
        {
            *o = g * (xi - mean) * inv + b;
        }
    }
}

/// Multi-head self-attention `[T, D] -> [T, D]`: Q/K/V projections,
/// per-head `softmax(Q K^T / sqrt(D/heads)) V` with max-subtracted
/// (numerically stable) row softmax, then the output projection. All
/// intermediates — Q, K, V, the context rows, and the `[heads, T, T]`
/// score buffer — live in `scratch`, whose required capacity is exactly
/// `Layer::scratch_elems()` so the arena preallocates it and steady-state
/// inference never grows it.
#[allow(clippy::too_many_arguments)]
pub fn attention_into(input: &[f32], t: usize, d: usize, w: &AttnWeights,
                      heads: usize, threads: usize,
                      scratch: &mut Vec<f32>, out: &mut [f32]) {
    assert!(heads > 0 && d % heads == 0,
            "width {d} does not divide into {heads} heads");
    let dh = d / heads;
    assert_eq!(input.len(), t * d, "attention input size mismatch");
    assert_eq!(out.len(), t * d, "output buffer size mismatch");
    let need = 4 * t * d + heads * t * t;
    if scratch.len() < need {
        scratch.resize(need, 0.0);
    }
    let (qkvc, scores) = scratch[..need].split_at_mut(4 * t * d);
    let (q, rest) = qkvc.split_at_mut(t * d);
    let (k, rest) = rest.split_at_mut(t * d);
    let (v, ctx) = rest.split_at_mut(t * d);
    proj_into(input, t, d, &w.q, false, threads, q);
    proj_into(input, t, d, &w.k, false, threads, k);
    proj_into(input, t, d, &w.v, false, threads, v);
    let scale = 1.0 / (dh as f32).sqrt();
    for h in 0..heads {
        let off = h * dh;
        let sc = &mut scores[h * t * t..(h + 1) * t * t];
        for i in 0..t {
            let qrow = &q[i * d + off..i * d + off + dh];
            let srow = &mut sc[i * t..(i + 1) * t];
            for (j, s) in srow.iter_mut().enumerate() {
                let krow = &k[j * d + off..j * d + off + dh];
                // Tier-dispatched: the scalar path is the seed's
                // sequential multiply-add over the head slice.
                *s = micro::dot(qrow, krow) * scale;
            }
            let max =
                srow.iter().fold(f32::NEG_INFINITY, |m, s| m.max(*s));
            let mut sum = 0f32;
            for s in srow.iter_mut() {
                *s = (*s - max).exp();
                sum += *s;
            }
            let inv = 1.0 / sum;
            for s in srow.iter_mut() {
                *s *= inv;
            }
        }
    }
    ctx.fill(0.0);
    for i in 0..t {
        let row = &mut ctx[i * d..(i + 1) * d];
        for h in 0..heads {
            let off = h * dh;
            let sc = &scores[h * t * t + i * t..h * t * t + (i + 1) * t];
            for (j, &p) in sc.iter().enumerate() {
                gemm::axpy(&mut row[off..off + dh],
                           &v[j * d + off..j * d + off + dh], p);
            }
        }
    }
    proj_into(ctx, t, d, &w.o, false, threads, out);
}

/// Mean-pool over the sequence positions: `[T, D] -> [D]` (the spatial
/// `[D, 1, 1]` the classifier head consumes).
pub fn seqpool_into(input: &[f32], t: usize, d: usize, out: &mut [f32]) {
    assert_eq!(input.len(), t * d, "seqpool input size mismatch");
    assert_eq!(out.len(), d, "output buffer size mismatch");
    let inv = 1.0 / t as f32;
    for (dim, o) in out.iter_mut().enumerate() {
        let mut acc = 0f32;
        for tok in 0..t {
            acc += input[tok * d + dim];
        }
        *o = acc * inv;
    }
}

/// Batched [`attention_into`]: per-image loop sharing one scratch region
/// (which is why the memory plan does not scale scratch by the batch).
#[allow(clippy::too_many_arguments)]
pub fn attention_batch_into(input: &[f32], n: usize, t: usize, d: usize,
                            w: &AttnWeights, heads: usize, threads: usize,
                            scratch: &mut Vec<f32>, out: &mut [f32]) {
    let per = t * d;
    assert_eq!(input.len(), n * per, "batched attention input mismatch");
    assert_eq!(out.len(), n * per, "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(per).enumerate() {
        attention_into(&input[img * per..(img + 1) * per], t, d, w, heads,
                       threads, scratch, chunk);
    }
}

/// Batched [`seqpool_into`]: `out` is `[n][d]`.
pub fn seqpool_batch_into(input: &[f32], n: usize, t: usize, d: usize,
                          out: &mut [f32]) {
    let per = t * d;
    assert_eq!(input.len(), n * per, "batched seqpool input mismatch");
    assert_eq!(out.len(), n * d, "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(d).enumerate() {
        seqpool_into(&input[img * per..(img + 1) * per], t, d, chunk);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn maxpool_basics() {
        let t = Tensor {
            c: 1,
            h: 2,
            w: 2,
            data: vec![1.0, 2.0, 3.0, 4.0],
        };
        let p = maxpool2(&t);
        assert_eq!((p.h, p.w), (1, 1));
        assert_eq!(p.data[0], 4.0);
        // odd size: ceil semantics
        let t = Tensor::zeros(1, 3, 3);
        assert_eq!(maxpool2(&t).h, 2);
    }

    #[test]
    fn gap_means() {
        let t = Tensor {
            c: 2,
            h: 1,
            w: 2,
            data: vec![1.0, 3.0, 10.0, 20.0],
        };
        let g = gap(&t);
        assert_eq!(g.data, vec![2.0, 15.0]);
    }

    #[test]
    fn dense_known_values() {
        let t = Tensor {
            c: 2,
            h: 1,
            w: 1,
            data: vec![1.0, 2.0],
        };
        let out = dense(&t, &[1.0, 1.0, 0.5, -1.0], &[0.0, 1.0], 2, false);
        assert_eq!(out.data, vec![3.0, -0.5]);
        let out = dense(&t, &[1.0, 1.0, 0.5, -1.0], &[0.0, 1.0], 2, true);
        assert_eq!(out.data, vec![3.0, 0.0]);
    }

    #[test]
    fn add_and_relu() {
        let mut rng = Rng::seed_from(2);
        let a = Tensor::random(2, 3, 3, &mut rng);
        let b = Tensor::random(2, 3, 3, &mut rng);
        let s = add(&a, &b, false);
        assert!((s.data[5] - (a.data[5] + b.data[5])).abs() < 1e-6);
        let r = add(&a, &b, true);
        assert!(r.data.iter().all(|v| *v >= 0.0));
    }

    #[test]
    fn into_forms_overwrite_stale_buffers() {
        // Arena slots arrive dirty; every *_into must fully overwrite.
        let mut rng = Rng::seed_from(7);
        let input = Tensor::random(3, 6, 6, &mut rng);
        let want = maxpool2(&input);
        let mut buf = vec![f32::NAN; want.data.len()];
        maxpool2_into(input.view(), &mut buf);
        assert_eq!(buf, want.data);

        let w: Vec<f32> = (0..27).map(|_| rng.normal_f32()).collect();
        let b = vec![0.1f32; 3];
        let want = depthwise3x3(&input, &w, &b, 1, true);
        let mut buf = vec![f32::NAN; want.data.len()];
        depthwise3x3_into(input.view(), &w, &b, 1, true, &mut buf);
        assert_eq!(buf, want.data);
    }

    #[test]
    fn depthwise_identity() {
        let mut rng = Rng::seed_from(3);
        let input = Tensor::random(3, 5, 5, &mut rng);
        // centre-tap-only kernel = identity
        let mut w = vec![0f32; 27];
        for c in 0..3 {
            w[c * 9 + 4] = 1.0;
        }
        let out = depthwise3x3(&input, &w, &[0.0; 3], 1, false);
        assert!(out.max_abs_diff(&input) < 1e-6);
    }

    use std::sync::Arc;

    fn random_proj(rng: &mut Rng, d_in: usize, d_out: usize)
                   -> FlatWeights {
        FlatWeights::new(
            (0..d_in * d_out).map(|_| rng.normal_f32() * 0.3).collect(),
            (0..d_out).map(|_| rng.normal_f32() * 0.01).collect(),
        )
    }

    #[test]
    fn seq_matmul_matches_manual_dot() {
        // [T=2, d_in=3] x W[2][3]^T + bias
        let x = [1.0f32, 2.0, 3.0, -1.0, 0.5, 0.0];
        let w = FlatWeights::new(vec![1.0, 0.0, -1.0, 0.5, 0.5, 0.5],
                                 vec![0.1, -0.2]);
        let mut out = vec![0f32; 4];
        seq_matmul_into(&x, 2, 3, &w, false, 1, &mut out);
        assert!((out[0] - (0.1 + 1.0 - 3.0)).abs() < 1e-6);
        assert!((out[1] - (-0.2 + 0.5 * (1.0 + 2.0 + 3.0))).abs() < 1e-6);
        assert!((out[2] - (0.1 + (-1.0) - 0.0)).abs() < 1e-6);
        let mut relu = vec![0f32; 4];
        seq_matmul_into(&x, 2, 3, &w, true, 1, &mut relu);
        assert!(relu.iter().all(|v| *v >= 0.0));
    }

    #[test]
    fn csr_and_quant_projections_bit_match_their_f32_twins() {
        let mut rng = Rng::seed_from(11);
        let (t, d_in, d_out) = (7, 24, 16);
        let x: Vec<f32> =
            (0..t * d_in).map(|_| rng.normal_f32()).collect();
        let mut w = random_proj(&mut rng, d_in, d_out);
        // prune ~60% and keep a dense twin of the pruned weights
        for v in w.weights.iter_mut() {
            if rng.f64() < 0.6 {
                *v = 0.0;
            }
        }
        let csr = CsrLayer::from_dense(&w.to_proj_dense(d_in), None);
        let mut dense_out = vec![0f32; t * d_out];
        seq_matmul_into(&x, t, d_in, &w, true, 1, &mut dense_out);
        let mut csr_out = vec![f32::NAN; t * d_out];
        seq_matmul_csr_into(&x, t, d_in, &csr, true, &mut csr_out);
        assert_eq!(dense_out, csr_out,
                   "CSR projection diverged from its dense twin");

        let q = QuantDense::quantize(&w.to_proj_dense(d_in));
        let deq = q.dequantize();
        let deq_flat = FlatWeights::new(deq.weights, deq.bias);
        let mut quant_out = vec![f32::NAN; t * d_out];
        seq_matmul_quant_into(&x, t, d_in, &q, true, &mut quant_out);
        let mut twin_out = vec![0f32; t * d_out];
        seq_matmul_into(&x, t, d_in, &deq_flat, true, 1, &mut twin_out);
        assert_eq!(quant_out, twin_out,
                   "dequant-on-load projection diverged from its twin");
    }

    #[test]
    fn layernorm_normalizes_each_token() {
        let mut rng = Rng::seed_from(4);
        let (t, d) = (5, 32);
        let x: Vec<f32> =
            (0..t * d).map(|_| rng.normal_f32() * 3.0 + 1.0).collect();
        let gamma = vec![1.0f32; d];
        let beta = vec![0.0f32; d];
        let mut out = vec![f32::NAN; t * d];
        layernorm_into(&x, t, d, &gamma, &beta, &mut out);
        for row in out.chunks(d) {
            let mean = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|v| (v - mean) * (v - mean))
                .sum::<f32>() / d as f32;
            assert!(mean.abs() < 1e-4, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
        // gamma/beta shift the normalized values affinely
        let gamma2 = vec![2.0f32; d];
        let beta2 = vec![0.5f32; d];
        let mut out2 = vec![0f32; t * d];
        layernorm_into(&x, t, d, &gamma2, &beta2, &mut out2);
        for (a, b) in out.iter().zip(&out2) {
            assert!((2.0 * a + 0.5 - b).abs() < 1e-5);
        }
    }

    #[test]
    fn seqpool_means_over_tokens() {
        let x = [1.0f32, 10.0, 3.0, 20.0];
        let mut out = vec![0f32; 2];
        seqpool_into(&x, 2, 2, &mut out);
        assert_eq!(out, vec![2.0, 15.0]);
    }

    #[test]
    fn attention_single_token_is_value_times_output_proj() {
        // With T = 1 the softmax is the identity weight 1.0, so the op
        // reduces to o_proj(v_proj(x)) regardless of Q/K.
        let mut rng = Rng::seed_from(9);
        let d = 16;
        let w = AttnWeights {
            q: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
            k: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
            v: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
            o: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
        };
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut scratch = Vec::new();
        let mut out = vec![f32::NAN; d];
        attention_into(&x, 1, d, &w, 4, 1, &mut scratch, &mut out);
        let mut v = vec![0f32; d];
        proj_into(&x, 1, d, &w.v, false, 1, &mut v);
        let mut want = vec![0f32; d];
        proj_into(&v, 1, d, &w.o, false, 1, &mut want);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        assert_eq!(scratch.len(), 4 * d + 4 * 1 * 1,
                   "scratch must match Layer::scratch_elems()");
    }

    #[test]
    fn attention_rows_sum_to_probability_weighted_values() {
        // Identical tokens -> identical attention outputs per row.
        let mut rng = Rng::seed_from(21);
        let (t, d, heads) = (6, 8, 2);
        let w = AttnWeights {
            q: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
            k: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
            v: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
            o: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
        };
        let token: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let x: Vec<f32> =
            (0..t).flat_map(|_| token.iter().copied()).collect();
        let mut scratch = Vec::new();
        let mut out = vec![0f32; t * d];
        attention_into(&x, t, d, &w, heads, 1, &mut scratch, &mut out);
        for row in out.chunks(d).skip(1) {
            for (a, b) in row.iter().zip(&out[..d]) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn batched_seq_ops_match_per_image_runs() {
        let mut rng = Rng::seed_from(33);
        let (n, t, d, heads) = (3, 5, 8, 2);
        let w = AttnWeights {
            q: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
            k: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
            v: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
            o: ProjStore::Dense(Arc::new(random_proj(&mut rng, d, d))),
        };
        let x: Vec<f32> =
            (0..n * t * d).map(|_| rng.normal_f32()).collect();
        let mut scratch = Vec::new();
        let mut fused = vec![0f32; n * t * d];
        attention_batch_into(&x, n, t, d, &w, heads, 1, &mut scratch,
                             &mut fused);
        for img in 0..n {
            let mut one = vec![0f32; t * d];
            attention_into(&x[img * t * d..(img + 1) * t * d], t, d, &w,
                           heads, 1, &mut scratch, &mut one);
            assert_eq!(&fused[img * t * d..(img + 1) * t * d], &one[..]);
        }
        let mut pooled = vec![0f32; n * d];
        seqpool_batch_into(&x, n, t, d, &mut pooled);
        for img in 0..n {
            let mut one = vec![0f32; d];
            seqpool_into(&x[img * t * d..(img + 1) * t * d], t, d,
                         &mut one);
            assert_eq!(&pooled[img * d..(img + 1) * d], &one[..]);
        }
    }
}
