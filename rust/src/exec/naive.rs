//! Dense direct convolution — the un-co-designed baseline (stands in for
//! an interpreter-style mobile runtime, cf. TFLite CPU reference kernels
//! in Fig. 5). Straightforward loop nest, no tiling, no load reuse beyond
//! what the compiler finds on its own.

use crate::compress::DenseLayer;
use crate::exec::tensor::{same_pad, BatchView, Tensor, TensorView};
use crate::quant::QuantDense;
use crate::util::threadpool;

/// Dense conv2d, SAME padding, optional fused ReLU.
pub fn conv2d(input: &Tensor, layer: &DenseLayer, stride: usize,
              relu: bool, threads: usize) -> Tensor {
    let (h_out, _) = same_pad(input.h, layer.kh, stride);
    let (w_out, _) = same_pad(input.w, layer.kw, stride);
    let mut out = Tensor::zeros(layer.cout, h_out, w_out);
    conv2d_into(input.view(), layer, stride, relu, threads, &mut out.data);
    out
}

/// [`conv2d`] writing into a preassigned output buffer (arena slot) of
/// exactly `cout * h_out * w_out` elements — the compiled-pipeline entry
/// point; performs no allocation.
pub fn conv2d_into(input: TensorView<'_>, layer: &DenseLayer,
                   stride: usize, relu: bool, threads: usize,
                   out: &mut [f32]) {
    let (h_out, pad_h) = same_pad(input.h, layer.kh, stride);
    let (w_out, pad_w) = same_pad(input.w, layer.kw, stride);
    let hw = h_out * w_out;
    assert_eq!(out.len(), layer.cout * hw, "output buffer size mismatch");
    threadpool::parallel_chunks_mut(out, hw, threads, |co, plane| {
        for y in 0..h_out {
            for x in 0..w_out {
                let mut acc = layer.bias[co];
                for ci in 0..layer.cin {
                    for ky in 0..layer.kh {
                        let iy = (y * stride + ky) as isize - pad_h as isize;
                        if iy < 0 || iy >= input.h as isize {
                            continue;
                        }
                        for kx in 0..layer.kw {
                            let ix =
                                (x * stride + kx) as isize - pad_w as isize;
                            if ix < 0 || ix >= input.w as isize {
                                continue;
                            }
                            acc += layer.at(co, ci, ky, kx)
                                * input.at(ci, iy as usize, ix as usize);
                        }
                    }
                }
                plane[y * w_out + x] = if relu { acc.max(0.0) } else { acc };
            }
        }
    });
}

/// Batched [`conv2d_into`]: the direct-loop baseline has no weight
/// stream to amortize, so the batch is a plain per-image loop behind
/// the same `[N][C][H][W]` signature as the fused engines.
pub fn conv2d_batch_into(input: BatchView<'_>, layer: &DenseLayer,
                         stride: usize, relu: bool, threads: usize,
                         out: &mut [f32]) {
    let (h_out, _) = same_pad(input.h, layer.kh, stride);
    let (w_out, _) = same_pad(input.w, layer.kw, stride);
    let per = layer.cout * h_out * w_out;
    assert_eq!(out.len(), input.n * per, "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(per).enumerate() {
        conv2d_into(input.image(img), layer, stride, relu, threads,
                    chunk);
    }
}

/// Batched [`conv2d_quant_into`]: per-image loop, same signature as the
/// fused engines.
pub fn conv2d_quant_batch_into(input: BatchView<'_>, layer: &QuantDense,
                               stride: usize, relu: bool, threads: usize,
                               out: &mut [f32]) {
    let (h_out, _) = same_pad(input.h, layer.kh, stride);
    let (w_out, _) = same_pad(input.w, layer.kw, stride);
    let per = layer.cout * h_out * w_out;
    assert_eq!(out.len(), input.n * per, "output buffer size mismatch");
    for (img, chunk) in out.chunks_mut(per).enumerate() {
        conv2d_quant_into(input.image(img), layer, stride, relu, threads,
                          chunk);
    }
}

/// Weight-only int8 dense conv, SAME padding, optional fused ReLU.
///
/// The i8 weights stream through the same loop nest as [`conv2d`] and are
/// dequantized in-register: the integer taps accumulate in f32 and the
/// per-channel scale is fused once per output pixel — no f32 weight
/// materialization, no allocation beyond the output tensor.
pub fn conv2d_quant(input: &Tensor, layer: &QuantDense, stride: usize,
                    relu: bool, threads: usize) -> Tensor {
    let (h_out, _) = same_pad(input.h, layer.kh, stride);
    let (w_out, _) = same_pad(input.w, layer.kw, stride);
    let mut out = Tensor::zeros(layer.cout, h_out, w_out);
    conv2d_quant_into(input.view(), layer, stride, relu, threads,
                      &mut out.data);
    out
}

/// [`conv2d_quant`] writing into a preassigned output buffer.
pub fn conv2d_quant_into(input: TensorView<'_>, layer: &QuantDense,
                         stride: usize, relu: bool, threads: usize,
                         out: &mut [f32]) {
    let (h_out, pad_h) = same_pad(input.h, layer.kh, stride);
    let (w_out, pad_w) = same_pad(input.w, layer.kw, stride);
    let hw = h_out * w_out;
    assert_eq!(out.len(), layer.cout * hw, "output buffer size mismatch");
    let per = layer.cin * layer.kh * layer.kw;
    threadpool::parallel_chunks_mut(out, hw, threads, |co, plane| {
        let wrow = &layer.weights[co * per..(co + 1) * per];
        let scale = layer.scales[co];
        let bias = layer.bias[co];
        for y in 0..h_out {
            for x in 0..w_out {
                let mut acc = 0f32;
                for ci in 0..layer.cin {
                    for ky in 0..layer.kh {
                        let iy = (y * stride + ky) as isize - pad_h as isize;
                        if iy < 0 || iy >= input.h as isize {
                            continue;
                        }
                        for kx in 0..layer.kw {
                            let ix =
                                (x * stride + kx) as isize - pad_w as isize;
                            if ix < 0 || ix >= input.w as isize {
                                continue;
                            }
                            let w = wrow
                                [(ci * layer.kh + ky) * layer.kw + kx];
                            acc += w as f32
                                * input.at(ci, iy as usize, ix as usize);
                        }
                    }
                }
                let v = scale * acc + bias;
                plane[y * w_out + x] = if relu { v.max(0.0) } else { v };
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_kernel_passes_through() {
        // 1x1 conv with identity weights = copy.
        let mut rng = Rng::seed_from(1);
        let input = Tensor::random(2, 5, 5, &mut rng);
        let layer = DenseLayer {
            cout: 2,
            cin: 2,
            kh: 1,
            kw: 1,
            weights: vec![1.0, 0.0, 0.0, 1.0],
            bias: vec![0.0, 0.0],
        };
        let out = conv2d(&input, &layer, 1, false, 1);
        assert!(out.max_abs_diff(&input) < 1e-6);
    }

    #[test]
    fn all_ones_interior_sum() {
        let input = Tensor {
            c: 1,
            h: 5,
            w: 5,
            data: vec![1.0; 25],
        };
        let layer = DenseLayer {
            cout: 1,
            cin: 1,
            kh: 3,
            kw: 3,
            weights: vec![1.0; 9],
            bias: vec![0.0],
        };
        let out = conv2d(&input, &layer, 1, false, 1);
        assert_eq!(out.at(0, 2, 2), 9.0); // interior
        assert_eq!(out.at(0, 0, 0), 4.0); // corner
        assert_eq!(out.at(0, 0, 2), 6.0); // edge
    }

    #[test]
    fn stride_two_shape() {
        let input = Tensor::zeros(3, 15, 16);
        let layer = DenseLayer {
            cout: 4,
            cin: 3,
            kh: 3,
            kw: 3,
            weights: vec![0.0; 3 * 4 * 9],
            bias: vec![1.0; 4],
        };
        let out = conv2d(&input, &layer, 2, false, 2);
        assert_eq!((out.h, out.w, out.c), (8, 8, 4));
        assert!(out.data.iter().all(|v| *v == 1.0));
    }

    #[test]
    fn relu_clamps() {
        let input = Tensor {
            c: 1,
            h: 2,
            w: 2,
            data: vec![1.0; 4],
        };
        let layer = DenseLayer {
            cout: 1,
            cin: 1,
            kh: 1,
            kw: 1,
            weights: vec![-1.0],
            bias: vec![0.0],
        };
        let out = conv2d(&input, &layer, 1, true, 1);
        assert!(out.data.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn threads_match_single() {
        let mut rng = Rng::seed_from(3);
        let input = Tensor::random(4, 9, 11, &mut rng);
        let layer = DenseLayer {
            cout: 6,
            cin: 4,
            kh: 3,
            kw: 3,
            weights: (0..6 * 4 * 9).map(|_| rng.normal_f32()).collect(),
            bias: (0..6).map(|_| rng.normal_f32()).collect(),
        };
        let a = conv2d(&input, &layer, 1, false, 1);
        let b = conv2d(&input, &layer, 1, false, 8);
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn quant_matches_dequantized_oracle() {
        // The scale-fused path computes s*sum(q*x)+b; the oracle runs the
        // dequantized f32 weights sum((q*s)*x)+b — same value up to f32
        // association, so only a tiny tolerance is allowed.
        let mut rng = Rng::seed_from(17);
        let input = Tensor::random(5, 9, 9, &mut rng);
        let layer = DenseLayer {
            cout: 7,
            cin: 5,
            kh: 3,
            kw: 3,
            weights: (0..7 * 5 * 9).map(|_| rng.normal_f32()).collect(),
            bias: (0..7).map(|_| rng.normal_f32()).collect(),
        };
        let q = QuantDense::quantize(&layer);
        for stride in [1usize, 2] {
            for relu in [false, true] {
                let want = conv2d(&input, &q.dequantize(), stride, relu, 1);
                let got = conv2d_quant(&input, &q, stride, relu, 3);
                let scale = want
                    .data
                    .iter()
                    .fold(0f32, |m, v| m.max(v.abs()));
                assert!(
                    got.max_abs_diff(&want) < 1e-4 * scale.max(1.0),
                    "stride {stride} relu {relu}: diff {}",
                    got.max_abs_diff(&want)
                );
            }
        }
    }
}
