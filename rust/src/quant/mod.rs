//! Weight quantization (paper §1: "compression techniques fall into two
//! categories, pruning and quantization"). CoCo-Gen's evaluation runs
//! fp32 (the paper notes Fig. 7's comparison does NOT apply quantization
//! while Eyeriss/ESE use 12-bit fixed point) — this module supplies the
//! quantization axis so the framework covers both halves of compression:
//!
//! * symmetric per-output-channel int8 quantization of conv/FC weights
//!   ([`QuantDense`]) and of pattern-compact FKW weights ([`QuantFkw`]:
//!   pruning x quantization composed);
//! * storage only — the structs here hold i8 weights and scales, never a
//!   retained f32 copy, so the 4x weight shrink is real resident memory;
//! * execution lives in `exec`: `exec::naive::conv2d_quant`,
//!   `exec::im2col::conv2d_quant` and `exec::pattern::conv2d_quant(_auto)`
//!   load i8 weights and dequantize in-register (scale-fused AXPY), with
//!   no per-call f32 weight materialization and no allocation beyond the
//!   output tensor. `codegen::Scheme::CocoGenQuant` builds plans on these
//!   formats end-to-end, `codegen::lower` compiles them to the quant
//!   kernels' write-into-arena entry points, and `Scheme::CocoAuto`'s
//!   per-layer tuner offers the int8 variants as measured candidates
//!   next to their f32 twins.
//!
//! `dequantize()` on both structs reconstructs an f32 layer for error
//! analysis and oracle tests only; it is never on the inference path.

use crate::compress::{DenseLayer, FkwKernel, FkwLayer};

/// Per-output-channel symmetric int8 quantized weights.
#[derive(Debug, Clone)]
pub struct QuantDense {
    pub cout: usize,
    pub cin: usize,
    pub kh: usize,
    pub kw: usize,
    /// `w_q[co][ci][ky][kx]` (OIHW), values in `[-127, 127]`.
    pub weights: Vec<i8>,
    /// Per-output-channel scale: `w ~= w_q * scale[co]`.
    pub scales: Vec<f32>,
    pub bias: Vec<f32>,
}

impl QuantDense {
    /// Quantize a dense layer (per-channel absmax).
    pub fn quantize(d: &DenseLayer) -> QuantDense {
        let per = d.cin * d.kh * d.kw;
        let mut scales = vec![0f32; d.cout];
        for co in 0..d.cout {
            let absmax = d.weights[co * per..(co + 1) * per]
                .iter()
                .fold(0f32, |m, w| m.max(w.abs()));
            scales[co] = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
        }
        let weights = d
            .weights
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let s = scales[i / per];
                (w / s).round().clamp(-127.0, 127.0) as i8
            })
            .collect();
        QuantDense {
            cout: d.cout,
            cin: d.cin,
            kh: d.kh,
            kw: d.kw,
            weights,
            scales,
            bias: d.bias.clone(),
        }
    }

    /// Dequantize back to f32 (for error analysis / oracle tests only —
    /// the executors in `exec` consume the i8 weights directly).
    pub fn dequantize(&self) -> DenseLayer {
        let per = self.cin * self.kh * self.kw;
        DenseLayer {
            cout: self.cout,
            cin: self.cin,
            kh: self.kh,
            kw: self.kw,
            weights: self
                .weights
                .iter()
                .enumerate()
                .map(|(i, q)| *q as f32 * self.scales[i / per])
                .collect(),
            bias: self.bias.clone(),
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.weights.len() + self.scales.len() * 4 + self.bias.len() * 4
    }

    /// Max relative quantization error over the weights (w.r.t. channel
    /// absmax) — bounded by 0.5/127 per symmetric-absmax construction.
    pub fn max_rel_error(&self, original: &DenseLayer) -> f32 {
        let per = self.cin * self.kh * self.kw;
        let deq = self.dequantize();
        let mut worst = 0f32;
        for co in 0..self.cout {
            let absmax = original.weights[co * per..(co + 1) * per]
                .iter()
                .fold(0f32, |m, w| m.max(w.abs()));
            if absmax == 0.0 {
                continue;
            }
            for i in co * per..(co + 1) * per {
                worst = worst
                    .max((deq.weights[i] - original.weights[i]).abs()
                        / absmax);
            }
        }
        worst
    }
}

/// int8 FKW: pattern-compact weights quantized per output channel —
/// pruning x quantization composed (the full CoCoPIE compression stack).
///
/// Holds the FKW *structure* (filter order, offsets, kernel descriptors,
/// f32 bias) plus i8 weights and per-channel scales; there is no retained
/// f32 weight copy, so `size_bytes()` is the real resident footprint.
#[derive(Debug, Clone)]
pub struct QuantFkw {
    pub cout: usize,
    pub cin: usize,
    /// Physical filter order (after filter-kernel reorder); maps physical
    /// position -> original output-channel index.
    pub filter_order: Vec<u32>,
    /// Per physical filter: `[offsets[f], offsets[f+1])` indexes
    /// kernels/weights.
    pub offsets: Vec<u32>,
    /// Per surviving kernel: input channel + pattern id.
    pub kernels: Vec<FkwKernel>,
    /// 4 int8 weights per kernel (pattern tap order), same indexing as
    /// `FkwLayer::weights`.
    pub weights_q: Vec<i8>,
    /// Per *original* output-channel scale: `w ~= w_q * scales[co]`.
    pub scales: Vec<f32>,
    pub bias: Vec<f32>,
}

impl QuantFkw {
    /// Quantize an FKW layer (per-channel absmax over its surviving
    /// weights). The f32 weights are left behind; only the structure is
    /// carried over.
    pub fn quantize(f: &FkwLayer) -> QuantFkw {
        let mut scales = vec![1f32; f.cout];
        for phys in 0..f.cout {
            let co = f.filter_order[phys] as usize;
            let lo = f.offsets[phys] as usize * 4;
            let hi = f.offsets[phys + 1] as usize * 4;
            let absmax = f.weights[lo..hi]
                .iter()
                .fold(0f32, |m, w| m.max(w.abs()));
            scales[co] = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
        }
        let mut weights_q = vec![0i8; f.weights.len()];
        for phys in 0..f.cout {
            let co = f.filter_order[phys] as usize;
            let lo = f.offsets[phys] as usize * 4;
            let hi = f.offsets[phys + 1] as usize * 4;
            for i in lo..hi {
                weights_q[i] = (f.weights[i] / scales[co])
                    .round()
                    .clamp(-127.0, 127.0) as i8;
            }
        }
        QuantFkw {
            cout: f.cout,
            cin: f.cin,
            filter_order: f.filter_order.clone(),
            offsets: f.offsets.clone(),
            kernels: f.kernels.clone(),
            weights_q,
            scales,
            bias: f.bias.clone(),
        }
    }

    /// Reconstruct the f32 FKW layer (error analysis / oracle tests; the
    /// pattern executor runs the i8 weights directly).
    pub fn dequantize(&self) -> FkwLayer {
        let mut weights = vec![0f32; self.weights_q.len()];
        for phys in 0..self.cout {
            let co = self.filter_order[phys] as usize;
            let lo = self.offsets[phys] as usize * 4;
            let hi = self.offsets[phys + 1] as usize * 4;
            for i in lo..hi {
                weights[i] = self.weights_q[i] as f32 * self.scales[co];
            }
        }
        FkwLayer {
            cout: self.cout,
            cin: self.cin,
            filter_order: self.filter_order.clone(),
            offsets: self.offsets.clone(),
            kernels: self.kernels.clone(),
            weights,
            bias: self.bias.clone(),
        }
    }

    /// Surviving weight count (4 per kernel).
    pub fn nnz(&self) -> usize {
        self.weights_q.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.filter_order.len() * 4
            + self.offsets.len() * 4
            + self.kernels.len() * 3 // u16 ci + u8 pattern
            + self.weights_q.len() // 1 byte each
            + self.scales.len() * 4
            + self.bias.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::TileConfig;
    use crate::exec::tensor::Tensor;
    use crate::exec::{naive, pattern};
    use crate::patterns::connectivity::ConnectivityMask;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_dense(seed: u64, cout: usize, cin: usize) -> DenseLayer {
        let mut rng = Rng::seed_from(seed);
        DenseLayer {
            cout,
            cin,
            kh: 3,
            kw: 3,
            weights: (0..cout * cin * 9).map(|_| rng.normal_f32()).collect(),
            bias: (0..cout).map(|_| rng.normal_f32()).collect(),
        }
    }

    #[test]
    fn quantization_error_bounded() {
        prop::check("quant-error-bound", 30, |g| {
            let cout = g.usize(1, 8);
            let cin = g.usize(1, 8);
            let d = random_dense(g.usize(0, 1 << 30) as u64, cout, cin);
            let q = QuantDense::quantize(&d);
            let err = q.max_rel_error(&d);
            // symmetric absmax rounding: error <= 0.5 step = 0.5/127
            if err > 0.5 / 127.0 + 1e-6 {
                return Err(format!("rel error {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn int8_storage_is_4x_smaller() {
        let d = random_dense(3, 32, 32);
        let q = QuantDense::quantize(&d);
        let ratio = d.size_bytes() as f64 / q.size_bytes() as f64;
        assert!(ratio > 3.5, "ratio {ratio}");
    }

    #[test]
    fn quant_conv_close_to_fp32() {
        let mut rng = Rng::seed_from(9);
        let d = random_dense(4, 8, 8);
        let q = QuantDense::quantize(&d);
        let x = Tensor::random(8, 10, 10, &mut rng);
        let a = naive::conv2d(&x, &d, 1, false, 1);
        let b = naive::conv2d_quant(&x, &q, 1, false, 1);
        // error accumulates over cin*9 MACs; stays small relative to
        // activation magnitude
        let scale = a.data.iter().fold(0f32, |m, v| m.max(v.abs()));
        assert!(b.max_abs_diff(&a) < 0.02 * scale.max(1.0));
    }

    #[test]
    fn fkw_quant_composes_pruning_and_quantization() {
        let mut rng = Rng::seed_from(5);
        let d = random_dense(6, 16, 16);
        let conn = ConnectivityMask::all_alive(16, 16);
        let f = FkwLayer::from_dense(&d, &conn);
        let qf = QuantFkw::quantize(&f);
        // int8 FKW smaller than f32 FKW...
        assert!(qf.size_bytes() < f.size_bytes());
        // ...and the weight store itself is the full 4x (1 byte vs 4).
        assert_eq!(qf.weights_q.len(), f.weights.len());
        // dequant-on-load executor is exactly the dequantized layer run
        // through the same engine (identical f32 values, identical loop)
        let x = Tensor::random(16, 8, 8, &mut rng);
        let a = pattern::conv2d_quant(&x, &qf, 1, true, 2,
                                      TileConfig::default());
        let b = pattern::conv2d(&x, &qf.dequantize(), 1, true, 1,
                                TileConfig::default());
        assert_eq!(a.data, b.data, "dequant-on-load diverged from oracle");
    }

    #[test]
    fn fkw_quant_round_trip_is_stable() {
        let d = random_dense(11, 8, 8);
        let conn = crate::codegen::prune_conn_oihw(&d, 0.5);
        let f = FkwLayer::from_dense(&d, &conn);
        let qf = QuantFkw::quantize(&f);
        // quantize(dequantize(q)) reproduces q exactly: values are on
        // the grid already
        let back = QuantFkw::quantize(&qf.dequantize());
        assert_eq!(qf.weights_q, back.weights_q);
        // structure survives untouched
        assert_eq!(qf.filter_order, f.filter_order);
        assert_eq!(qf.offsets, f.offsets);
        assert_eq!(qf.kernels.len(), f.kernels.len());
    }

    #[test]
    fn round_trip_identity_for_exact_values() {
        // weights already on the quantization grid survive exactly
        let mut d = random_dense(7, 2, 2);
        let per = 2 * 9;
        for co in 0..2 {
            for i in 0..per {
                d.weights[co * per + i] =
                    ((i % 11) as f32 - 5.0) / 127.0;
            }
        }
        let q = QuantDense::quantize(&d);
        let back = QuantDense::quantize(&q.dequantize());
        assert_eq!(q.weights, back.weights);
    }
}
