//! Synthetic fine-grained classification datasets — the Rust twin of
//! python/compile/data.py (parameters read from the manifest so the two
//! sides share one source of truth). Class-conditional oriented gratings
//! with per-dataset noise/frequency difficulty; NHWC f32 batches shaped
//! for the AOT train/infer artifacts.

use crate::runtime::manifest::DatasetSpec;
use crate::util::rng::Rng;

/// A generated batch: x is NHWC `[n, size, size, 3]` flattened, y is `[n]`.
pub struct Batch {
    pub n: usize,
    pub size: usize,
    pub x: Vec<f32>,
    pub y: Vec<i32>,
}

/// Deterministic per-class parameters (mirror of data.py::class_params).
pub fn class_params(spec: &DatasetSpec, c: usize) -> (f64, f64, [f32; 3]) {
    let classes = spec.classes as f64;
    let angle = std::f64::consts::PI * c as f64 / classes;
    let freq = spec.freq_base * (1.0 + 0.5 * (c % 4) as f64 / 4.0);
    let t = 2.0 * std::f64::consts::PI * c as f64 / classes;
    let tint = [
        (0.5 + 0.5 * t.sin()) as f32,
        (0.5 + 0.5 * (t + 2.1).sin()) as f32,
        (0.5 + 0.5 * (t + 4.2).sin()) as f32,
    ];
    (angle, freq, tint)
}

/// Generate a batch of `n` images at `size`x`size` for dataset `spec`.
pub fn make_batch(spec: &DatasetSpec, size: usize, n: usize, seed: u64)
                  -> Batch {
    let mut rng = Rng::seed_from(seed ^ fx(spec.name.as_bytes()));
    let mut x = vec![0f32; n * size * size * 3];
    let mut y = vec![0i32; n];
    for i in 0..n {
        let c = rng.below(spec.classes);
        y[i] = c as i32;
        let (angle, freq, tint) = class_params(spec, c);
        let a = angle + rng.normal() * spec.angle_jitter;
        let phase = rng.range_f64(0.0, 2.0 * std::f64::consts::PI);
        let (ca, sa) = (a.cos(), a.sin());
        for yy in 0..size {
            for xx in 0..size {
                let u = xx as f64 / size as f64;
                let v = yy as f64 / size as f64;
                let g = (2.0 * std::f64::consts::PI * freq
                    * (u * ca + v * sa)
                    + phase)
                    .sin();
                for ch in 0..3 {
                    let base =
                        0.5 + 0.35 * g as f32 * tint[ch];
                    let noisy = base
                        + (rng.normal() * spec.noise) as f32;
                    x[((i * size + yy) * size + xx) * 3 + ch] =
                        noisy.clamp(0.0, 1.0);
                }
            }
        }
    }
    Batch { n, size, x, y }
}

/// Epoch iterator: yields `steps` batches with distinct derived seeds.
pub fn batches(spec: &DatasetSpec, size: usize, batch: usize, steps: usize,
               seed: u64) -> Vec<Batch> {
    (0..steps)
        .map(|s| make_batch(spec, size, batch, seed.wrapping_add(s as u64 * 7919)))
        .collect()
}

fn fx(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> DatasetSpec {
        DatasetSpec {
            name: "synflowers".into(),
            classes: 16,
            noise: 0.1,
            freq_base: 1.5,
            angle_jitter: 0.05,
            train: 2048,
            test: 512,
        }
    }

    #[test]
    fn batch_shapes_and_ranges() {
        let b = make_batch(&spec(), 16, 32, 0);
        assert_eq!(b.x.len(), 32 * 16 * 16 * 3);
        assert_eq!(b.y.len(), 32);
        assert!(b.x.iter().all(|v| (0.0..=1.0).contains(v)));
        assert!(b.y.iter().all(|c| (0..16).contains(c)));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = make_batch(&spec(), 16, 8, 42);
        let b = make_batch(&spec(), 16, 8, 42);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = make_batch(&spec(), 16, 8, 43);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn classes_have_distinct_signatures() {
        // Mean image of two different classes must differ substantially.
        let mut s = spec();
        s.noise = 0.0;
        s.angle_jitter = 0.0;
        let b = make_batch(&s, 16, 256, 1);
        let mut means = vec![vec![0f64; 16 * 16 * 3]; 16];
        let mut counts = vec![0usize; 16];
        for i in 0..b.n {
            let c = b.y[i] as usize;
            counts[c] += 1;
            for j in 0..16 * 16 * 3 {
                means[c][j] += b.x[i * 16 * 16 * 3 + j] as f64;
            }
        }
        let c0 = (0..16).find(|&c| counts[c] > 3).unwrap();
        let c1 = (c0 + 1..16).find(|&c| counts[c] > 3).unwrap();
        let d: f64 = means[c0]
            .iter()
            .zip(&means[c1])
            .map(|(a, b)| (a / counts[c0] as f64 - b / counts[c1] as f64).abs())
            .sum::<f64>()
            / (16.0 * 16.0 * 3.0);
        assert!(d > 0.02, "class means too similar: {d}");
    }
}
