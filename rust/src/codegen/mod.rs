//! Pattern-aware execution-plan generation (the "compilation" half of the
//! co-design, paper §2.1.3): filter-kernel reorder, per-layer scheme
//! selection, tile/engine auto-tuning, and lowering to a compiled op
//! pipeline. The output `ExecPlan` is compiled once by `lower` into the
//! `CompiledPipeline` the executors run.

pub mod lower;
pub mod reorder;
pub mod tuner;
pub mod verify;

use std::sync::Arc;

use crate::compress::{AttnWeights, CsrLayer, DenseLayer, FkwLayer,
                      FlatWeights, ProjStore};
use crate::ir::{LayerKind, ModelIR};
use crate::patterns::connectivity::{prune_connectivity, ConnectivityMask};
use crate::quant::{QuantDense, QuantFkw};
use crate::util::rng::Rng;

pub use lower::{lower, lower_batched, Arena, BufId, CompiledKernel,
                CompiledOp, CompiledPipeline};
pub use tuner::{observed_tune_batch, TileConfig};
pub use verify::{kernel_label, verify_pipeline, VerifyError};

/// Which lowering a *dense* conv layer compiles to. Fixed by the scheme
/// for the `Dense*` baselines; measured per layer (at the layer's real
/// shape) under `Scheme::CocoAuto`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenseEngine {
    /// Direct loops (TFLite-CPU stand-in).
    Naive,
    /// im2col + GEMM (TVM stand-in).
    Im2col,
    /// Winograd F(2x2,3x3) — legal for 3x3 stride-1 only; the lowering
    /// falls back to im2col elsewhere.
    Winograd,
    /// im2col with the weight panel packed once at lowering into the
    /// register-tiled SIMD microkernel layout. Autotuner-selected only;
    /// the kernel falls back to plain im2col on the scalar dispatch
    /// tier, so outputs are bit-identical to `Im2col` on every tier.
    Im2colPacked,
}

/// Which executor strategy a layer uses. Weight payloads are `Arc`-shared
/// so the lowered `CompiledPipeline` binds them without copying and a
/// serving pool holds each tensor exactly once per process.
#[derive(Debug, Clone)]
pub enum LayerPlan {
    /// Dense conv weights plus the engine that lowers them.
    Dense {
        layer: Arc<DenseLayer>,
        engine: DenseEngine,
    },
    /// Non-structured sparse (CSR) conv.
    Csr(Arc<CsrLayer>),
    /// Pattern + connectivity pruned, reordered, tuned (CoCo-Gen).
    Fkw {
        layer: Arc<FkwLayer>,
        tile: TileConfig,
    },
    /// Weight-only per-channel int8 dense conv (i8 weights resident, no
    /// f32 copy); runs on the im2col quant kernel.
    QuantDense(Arc<QuantDense>),
    /// Pattern + connectivity pruned AND int8-quantized (CocoGenQuant):
    /// both halves of the paper's compression, dequantized on load.
    QuantFkw {
        layer: Arc<QuantFkw>,
        tile: TileConfig,
    },
    /// Depthwise conv weights: `w[c][ky][kx]`.
    Depthwise(Arc<FlatWeights>),
    /// Dense FC: `w[cout][cin]` + bias.
    Fc(Arc<FlatWeights>),
    /// Sequence projection (`LayerKind::MatMul`) in any of the
    /// compression formats — dense f32, unstructured CSR, or int8.
    Proj(ProjStore),
    /// LayerNorm gamma (`weights`) + beta (`bias`); always f32 (a 2*D
    /// parameter vector compresses nothing worth the error).
    Norm(Arc<FlatWeights>),
    /// Self-attention Q/K/V/output projections, each independently
    /// carried in a [`ProjStore`] format.
    Attn(Arc<AttnWeights>),
    /// No weights (pool/add/gap/seqpool).
    None,
}

impl LayerPlan {
    /// (surviving weights, dense weight count) for the pruned conv
    /// formats — the shared helper behind `ExecPlan::flop_keep_ratio`.
    pub fn conv_nnz(&self) -> Option<(usize, usize)> {
        match self {
            LayerPlan::Fkw { layer, .. } => {
                Some((layer.nnz(), 9 * layer.cin * layer.cout))
            }
            LayerPlan::QuantFkw { layer, .. } => {
                Some((layer.nnz(), 9 * layer.cin * layer.cout))
            }
            LayerPlan::Csr(c) => {
                Some((c.nnz(), c.kh * c.kw * c.cin * c.cout))
            }
            LayerPlan::Proj(p) => p.nnz(),
            // Attention FLOPs are dominated by the score/softmax walk,
            // which pruning never touches — claim no analytic reduction
            // (conservative; the wall-clock benches measure the truth).
            LayerPlan::Attn(_) => None,
            _ => None,
        }
    }

    /// Resident weight bytes of this layer's store.
    pub fn weight_bytes(&self) -> usize {
        match self {
            LayerPlan::Dense { layer, .. } => layer.size_bytes(),
            LayerPlan::Csr(c) => c.size_bytes(),
            LayerPlan::Fkw { layer, .. } => layer.size_bytes(),
            LayerPlan::QuantDense(q) => q.size_bytes(),
            LayerPlan::QuantFkw { layer, .. } => layer.size_bytes(),
            LayerPlan::Depthwise(w)
            | LayerPlan::Fc(w)
            | LayerPlan::Norm(w) => w.size_bytes(),
            LayerPlan::Proj(p) => p.size_bytes(),
            LayerPlan::Attn(a) => a.size_bytes(),
            LayerPlan::None => 0,
        }
    }
}

/// A fully planned model: IR + per-layer weights/strategies.
pub struct ExecPlan {
    pub ir: ModelIR,
    pub layers: Vec<LayerPlan>,
    pub scheme: Scheme,
}

/// Global pruning/compilation scheme (the Fig. 5 "framework" axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Dense weights, direct loops (TFLite-CPU stand-in).
    DenseNaive,
    /// Dense weights, im2col+GEMM (TVM stand-in).
    DenseIm2col,
    /// Dense weights, Winograd F(2x2,3x3) for 3x3/s1 convs (MNN stand-in).
    DenseWinograd,
    /// Non-structured pruning + CSR execution.
    SparseCsr,
    /// CoCo-Gen: pattern + connectivity pruning, reorder, LRE, tuning.
    CocoGen,
    /// CoCo-Gen composed with weight-only per-channel int8: the pruned
    /// pattern layers store i8 weights (`QuantFkw`), the remaining dense
    /// convs become `QuantDense` — both halves of compression (§1
    /// "pruning and quantization") pushed through the same compiler
    /// passes and executors.
    CocoGenQuant,
    /// CoCo-Gen compression plus *per-layer engine selection*: the
    /// auto-tuner measures every legal lowering for each conv layer at
    /// its real shape (pattern AXPY tile sweep vs pattern GEMM vs their
    /// int8 dequant-on-load variants for the pruned layers; naive vs
    /// im2col vs int8 im2col for the dense remainder — under this
    /// scheme's pruning the remainder is the non-3x3 convs, so the
    /// Winograd candidate only enters the sweep if a dense 3x3/s1
    /// layer is present) and the compiled pipeline binds the per-layer
    /// winner — the paper's §2.1.3 auto-tuning claim. Run
    /// `autotune_plan` after `build_plan` to perform the measurement;
    /// untuned plans behave like CoCo-Gen.
    CocoAuto,
}

impl Scheme {
    /// Every scheme, in the Fig. 5 column order.
    pub const ALL: [Scheme; 7] = [
        Scheme::DenseNaive,
        Scheme::DenseIm2col,
        Scheme::DenseWinograd,
        Scheme::SparseCsr,
        Scheme::CocoGen,
        Scheme::CocoGenQuant,
        Scheme::CocoAuto,
    ];

    /// Parse a CLI-style scheme name (the `--scheme`/`--variants`
    /// vocabulary, aliases included).
    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "dense-naive" | "naive" => Some(Scheme::DenseNaive),
            "dense" | "dense-im2col" | "im2col" => {
                Some(Scheme::DenseIm2col)
            }
            "dense-winograd" | "winograd" => Some(Scheme::DenseWinograd),
            "sparse-csr" | "csr" => Some(Scheme::SparseCsr),
            "cocogen" => Some(Scheme::CocoGen),
            "cocogen-quant" | "quant" | "int8" => {
                Some(Scheme::CocoGenQuant)
            }
            "coco-auto" | "cocoauto" | "auto" => Some(Scheme::CocoAuto),
            _ => None,
        }
    }

    /// Stable lowercase label: the canonical name [`Scheme::parse`]
    /// accepts, used for deployment/variant naming.
    pub fn label(&self) -> &'static str {
        match self {
            Scheme::DenseNaive => "dense-naive",
            Scheme::DenseIm2col => "dense",
            Scheme::DenseWinograd => "dense-winograd",
            Scheme::SparseCsr => "sparse-csr",
            Scheme::CocoGen => "cocogen",
            Scheme::CocoGenQuant => "cocogen-quant",
            Scheme::CocoAuto => "coco-auto",
        }
    }
}

/// Pruning hyper-parameters for plan building.
#[derive(Debug, Clone, Copy)]
pub struct PruneConfig {
    /// Fraction of (cin,cout) kernels kept by connectivity pruning.
    pub connectivity_keep: f64,
    /// Fraction of weights kept by non-structured pruning (CSR scheme).
    pub unstructured_keep: f64,
}

impl Default for PruneConfig {
    fn default() -> Self {
        // 4/9 pattern keep * 0.55 connectivity ~= 4x conv weight reduction,
        // the mid-range of the paper's pattern+connectivity operating points.
        PruneConfig {
            connectivity_keep: 0.55,
            unstructured_keep: 0.25,
        }
    }
}

/// Deterministic random weights for a model IR (timing experiments are
/// weight-value independent; accuracy experiments use PJRT-trained
/// models). Dense conv layers default to the im2col engine; `build_plan`
/// rewrites the engine per scheme.
pub fn random_dense_weights(ir: &ModelIR, seed: u64) -> Vec<LayerPlan> {
    let mut rng = Rng::seed_from(seed);
    ir.layers
        .iter()
        .map(|l| match &l.kind {
            LayerKind::Conv { kh, kw, cout, .. } => {
                let n = kh * kw * l.input.c * cout;
                let scale = (2.0 / (kh * kw * l.input.c) as f64).sqrt();
                LayerPlan::Dense {
                    layer: Arc::new(DenseLayer {
                        cout: *cout,
                        cin: l.input.c,
                        kh: *kh,
                        kw: *kw,
                        weights: (0..n)
                            .map(|_| (rng.normal() * scale) as f32)
                            .collect(),
                        bias: (0..*cout).map(|_| rng.normal_f32() * 0.01)
                            .collect(),
                    }),
                    engine: DenseEngine::Im2col,
                }
            }
            LayerKind::DwConv { .. } => {
                LayerPlan::Depthwise(Arc::new(FlatWeights::new(
                    (0..9 * l.input.c)
                        .map(|_| rng.normal_f32() * 0.3)
                        .collect(),
                    (0..l.input.c).map(|_| rng.normal_f32() * 0.01)
                        .collect(),
                )))
            }
            LayerKind::Dense { cout, .. } => {
                let cin = l.input.elements();
                let scale = (2.0 / cin as f64).sqrt();
                LayerPlan::Fc(Arc::new(FlatWeights::new(
                    (0..cin * cout)
                        .map(|_| (rng.normal() * scale) as f32)
                        .collect(),
                    (0..*cout).map(|_| rng.normal_f32() * 0.01).collect(),
                )))
            }
            LayerKind::MatMul { d_out, .. } => {
                let d_in = l.input.d();
                let scale = (2.0 / d_in as f64).sqrt();
                LayerPlan::Proj(ProjStore::Dense(Arc::new(
                    FlatWeights::new(
                        (0..d_in * d_out)
                            .map(|_| (rng.normal() * scale) as f32)
                            .collect(),
                        (0..*d_out)
                            .map(|_| rng.normal_f32() * 0.01)
                            .collect(),
                    ),
                )))
            }
            LayerKind::LayerNorm => {
                let d = l.input.d();
                LayerPlan::Norm(Arc::new(FlatWeights::new(
                    vec![1.0; d],
                    vec![0.0; d],
                )))
            }
            LayerKind::SelfAttention { .. } => {
                let d = l.input.d();
                let scale = (1.0 / d as f64).sqrt();
                let mut mk = || {
                    ProjStore::Dense(Arc::new(FlatWeights::new(
                        (0..d * d)
                            .map(|_| (rng.normal() * scale) as f32)
                            .collect(),
                        (0..d).map(|_| rng.normal_f32() * 0.01).collect(),
                    )))
                };
                let (q, k, v) = (mk(), mk(), mk());
                let o = mk();
                LayerPlan::Attn(Arc::new(AttnWeights { q, k, v, o }))
            }
            _ => LayerPlan::None,
        })
        .collect()
}

/// Build an execution plan for (model, scheme): applies the scheme's
/// pruning to every 3x3 conv and fixes each dense layer's engine, then
/// the codegen passes (reorder + static tile heuristic) for the CoCo-Gen
/// family. Use `autotune_plan` after this to replace the heuristics with
/// measured choices (tiles for `CocoGen`/`CocoGenQuant`, full per-layer
/// engine selection for `CocoAuto`).
pub fn build_plan(ir: &ModelIR, scheme: Scheme, prune: PruneConfig,
                  seed: u64) -> ExecPlan {
    let dense = random_dense_weights(ir, seed);
    let layers = dense
        .into_iter()
        .zip(&ir.layers)
        .map(|(plan, l)| {
            let conv_stride = match l.kind {
                LayerKind::Conv { stride, .. } => stride,
                _ => 1,
            };
            match (scheme, plan) {
                (Scheme::DenseNaive, LayerPlan::Dense { layer, .. }) => {
                    LayerPlan::Dense {
                        layer,
                        engine: DenseEngine::Naive,
                    }
                }
                (Scheme::DenseWinograd, LayerPlan::Dense { layer, .. })
                    if l.is_conv3x3() && conv_stride == 1 =>
                {
                    LayerPlan::Dense {
                        layer,
                        engine: DenseEngine::Winograd,
                    }
                }
                (
                    Scheme::DenseNaive
                    | Scheme::DenseIm2col
                    | Scheme::DenseWinograd,
                    p,
                ) => p,
                // Sequence projections: unstructured pruning + CSR under
                // the sparse schemes (pattern/FKW pruning is 3x3-kernel
                // specific and does not apply to [d_out, d_in] matrices),
                // weight-only per-channel int8 under CocoGenQuant.
                // LayerNorm parameters always stay f32.
                (
                    Scheme::SparseCsr | Scheme::CocoGen | Scheme::CocoAuto,
                    LayerPlan::Proj(ProjStore::Dense(w)),
                ) => LayerPlan::Proj(prune_proj(&w,
                                                prune.unstructured_keep)),
                (
                    Scheme::CocoGenQuant,
                    LayerPlan::Proj(ProjStore::Dense(w)),
                ) => LayerPlan::Proj(quant_proj(&w)),
                (
                    Scheme::SparseCsr | Scheme::CocoGen | Scheme::CocoAuto,
                    LayerPlan::Attn(a),
                ) => LayerPlan::Attn(Arc::new(map_attn(&a, &|w| {
                    prune_proj(w, prune.unstructured_keep)
                }))),
                (Scheme::CocoGenQuant, LayerPlan::Attn(a)) => {
                    LayerPlan::Attn(Arc::new(map_attn(&a, &quant_proj)))
                }
                (Scheme::SparseCsr, LayerPlan::Dense { layer, .. })
                    if l.is_conv3x3() =>
                {
                    // Non-structured magnitude pruning, then CSR.
                    let mask =
                        crate::patterns::connectivity::prune_unstructured(
                            &layer.weights,
                            prune.unstructured_keep,
                        );
                    LayerPlan::Csr(Arc::new(CsrLayer::from_dense(
                        &layer,
                        Some(&mask),
                    )))
                }
                (Scheme::SparseCsr, p) => p,
                (
                    Scheme::CocoGen | Scheme::CocoAuto,
                    LayerPlan::Dense { layer, .. },
                ) if l.is_conv3x3() => {
                    let conn =
                        prune_conn_oihw(&layer, prune.connectivity_keep);
                    let mut fkw = FkwLayer::from_dense(&layer, &conn);
                    reorder::filter_kernel_reorder(&mut fkw);
                    let tile = tuner::default_tile(l.output.h, l.output.w);
                    LayerPlan::Fkw {
                        layer: Arc::new(fkw),
                        tile,
                    }
                }
                (Scheme::CocoGen | Scheme::CocoAuto, p) => p,
                (Scheme::CocoGenQuant, LayerPlan::Dense { layer, .. })
                    if l.is_conv3x3() =>
                {
                    // Same pruning + codegen passes as CoCo-Gen, then the
                    // weights (and only the weights) drop to int8.
                    let conn =
                        prune_conn_oihw(&layer, prune.connectivity_keep);
                    let mut fkw = FkwLayer::from_dense(&layer, &conn);
                    reorder::filter_kernel_reorder(&mut fkw);
                    let tile = tuner::default_tile(l.output.h, l.output.w);
                    LayerPlan::QuantFkw {
                        layer: Arc::new(QuantFkw::quantize(&fkw)),
                        tile,
                    }
                }
                (Scheme::CocoGenQuant, LayerPlan::Dense { layer, .. }) => {
                    // Convs the pattern pass leaves dense (e.g. 1x1): still
                    // weight-only int8.
                    LayerPlan::QuantDense(Arc::new(QuantDense::quantize(
                        &layer,
                    )))
                }
                (Scheme::CocoGenQuant, p) => p,
            }
        })
        .collect();
    ExecPlan {
        ir: ir.clone(),
        layers,
        scheme,
    }
}

/// Connectivity pruning over OIHW dense weights (helper: the pruning
/// primitives take HWIO).
pub fn prune_conn_oihw(d: &DenseLayer, keep: f64) -> ConnectivityMask {
    let mut hwio = vec![0f32; d.weights.len()];
    for co in 0..d.cout {
        for ci in 0..d.cin {
            for ky in 0..d.kh {
                for kx in 0..d.kw {
                    hwio[((ky * d.kw + kx) * d.cin + ci) * d.cout + co] =
                        d.at(co, ci, ky, kx);
                }
            }
        }
    }
    prune_connectivity(&hwio, d.kh, d.kw, d.cin, d.cout, keep)
}

/// Unstructured pruning of a sequence projection `[d_out, d_in]`,
/// stored CSR. Projections go through the generic magnitude pass only:
/// pattern/FKW pruning is defined over 3x3 spatial kernels and has no
/// analogue for flat matmul weights.
fn prune_proj(w: &FlatWeights, keep: f64) -> ProjStore {
    let d_in = w.weights.len() / w.bias.len();
    let dense = w.to_proj_dense(d_in);
    let mask = crate::patterns::connectivity::prune_unstructured(
        &dense.weights, keep);
    ProjStore::Csr(Arc::new(CsrLayer::from_dense(&dense, Some(&mask))))
}

/// Weight-only per-channel int8 for a sequence projection (biases and
/// activations stay f32, mirroring the conv quant path).
fn quant_proj(w: &FlatWeights) -> ProjStore {
    let d_in = w.weights.len() / w.bias.len();
    ProjStore::Int8(Arc::new(QuantDense::quantize(&w.to_proj_dense(d_in))))
}

/// Apply a projection transform to every still-dense store of an
/// attention layer (Q/K/V/output); already-compressed stores pass
/// through unchanged so re-planning is idempotent.
fn map_attn(a: &AttnWeights, f: &dyn Fn(&FlatWeights) -> ProjStore)
            -> AttnWeights {
    let m = |s: &ProjStore| match s {
        ProjStore::Dense(w) => f(w),
        other => other.clone(),
    };
    AttnWeights {
        q: m(&a.q),
        k: m(&a.k),
        v: m(&a.v),
        o: m(&a.o),
    }
}

/// Parameter auto-tuning (paper §2.1.3) at the single-image regime.
/// For the fixed-engine schemes this sweeps execution-path x tile-shape
/// candidates per pattern conv layer; for `Scheme::CocoAuto` it
/// additionally measures every legal engine per layer (including the
/// int8 dequant-on-load variants) and rewrites the plan to the
/// per-layer winner. Equivalent to [`autotune_plan_batched`] with
/// `batch = 1`.
pub fn autotune_plan(plan: &mut ExecPlan, threads: usize) {
    autotune_plan_batched(plan, threads, 1);
}

/// [`autotune_plan`] measured at the *serving batch regime*: every
/// candidate runs through its fused `*_batch_into` entry point on a
/// synthetic batch of `batch` images of the layer's real shape. The
/// best kernel at n = 1 is often not the best at n = 8 — batched GEMM
/// amortizes its patch-matrix build and weight streaming across the
/// batch, while the AXPY path's relative advantage shrinks — so a plan
/// that will serve fused batches should be tuned with the batch it
/// serves (`BatchPolicy::max_batch`).
pub fn autotune_plan_batched(plan: &mut ExecPlan, threads: usize,
                             batch: usize) {
    let batch = batch.max(1);
    if plan.scheme == Scheme::CocoAuto {
        autotune_engines(plan, threads, batch);
    } else {
        autotune_tiles(plan, threads, batch);
    }
}

/// Synthetic `[N][C][H][W]` input for candidate measurement.
fn random_batch(c: usize, h: usize, w: usize, n: usize, rng: &mut Rng)
                -> Vec<f32> {
    (0..n * c * h * w).map(|_| rng.normal_f32()).collect()
}

/// Run one pattern-layer candidate through the fused batch kernels
/// (AXPY or GEMM path per the tile's `use_gemm`).
#[allow(clippy::too_many_arguments)]
fn run_pattern_candidate(view: crate::exec::BatchView<'_>,
                         fkw: &crate::compress::FkwLayer,
                         gp: &crate::exec::pattern::PatternGemmPlan,
                         stride: usize, relu: bool, threads: usize,
                         cand: TileConfig, u_buf: &mut Vec<f32>,
                         out: &mut [f32]) {
    if cand.use_gemm {
        crate::exec::pattern::conv2d_gemm_batch_into(
            view, fkw, stride, relu, threads, gp, u_buf, out);
    } else {
        crate::exec::pattern::conv2d_batch_into(
            view, fkw, stride, relu, threads, cand, out);
    }
    std::hint::black_box(&mut *out);
}

/// Int8 edition of [`run_pattern_candidate`].
#[allow(clippy::too_many_arguments)]
fn run_quant_pattern_candidate(view: crate::exec::BatchView<'_>,
                               qf: &QuantFkw,
                               gp: &crate::exec::pattern::PatternGemmPlan,
                               stride: usize, relu: bool, threads: usize,
                               cand: TileConfig, u_buf: &mut Vec<f32>,
                               out: &mut [f32]) {
    if cand.use_gemm {
        crate::exec::pattern::conv2d_gemm_quant_batch_into(
            view, qf, stride, relu, threads, gp, u_buf, out);
    } else {
        crate::exec::pattern::conv2d_quant_batch_into(
            view, qf, stride, relu, threads, cand, out);
    }
    std::hint::black_box(&mut *out);
}

/// Tile-only sweep for `CocoGen`/`CocoGenQuant` pattern layers, measured
/// on fused batches of `batch` images.
fn autotune_tiles(plan: &mut ExecPlan, threads: usize, batch: usize) {
    let mut rng = Rng::seed_from(0xA070);
    let layers: Vec<_> = plan
        .ir
        .layers
        .iter()
        .cloned()
        .zip(plan.layers.iter_mut())
        .collect();
    for (lir, lp) in layers {
        let LayerKind::Conv { stride, relu, .. } = lir.kind else {
            continue;
        };
        let (c, h, w) = (lir.input.c, lir.input.h, lir.input.w);
        match lp {
            LayerPlan::Fkw { layer, tile } => {
                let data = random_batch(c, h, w, batch, &mut rng);
                let fkw = layer.clone();
                let gp = crate::exec::pattern::PatternGemmPlan::build(
                    fkw.cin, &fkw.kernels);
                let mut u_buf = Vec::new();
                let mut out =
                    vec![0f32; batch * lir.output.elements()];
                (*tile, _) = tune_tile(*tile, lir.output.h, &mut |cand| {
                    let view = crate::exec::BatchView::new(
                        batch, c, h, w, &data);
                    run_pattern_candidate(view, &fkw, &gp, stride, relu,
                                          threads, cand, &mut u_buf,
                                          &mut out);
                });
            }
            LayerPlan::QuantFkw { layer, tile } => {
                let data = random_batch(c, h, w, batch, &mut rng);
                let qf = layer.clone();
                let gp = crate::exec::pattern::PatternGemmPlan::build(
                    qf.cin, &qf.kernels);
                let mut u_buf = Vec::new();
                let mut out =
                    vec![0f32; batch * lir.output.elements()];
                (*tile, _) = tune_tile(*tile, lir.output.h, &mut |cand| {
                    let view = crate::exec::BatchView::new(
                        batch, c, h, w, &data);
                    run_quant_pattern_candidate(view, &qf, &gp, stride,
                                                relu, threads, cand,
                                                &mut u_buf, &mut out);
                });
            }
            _ => continue,
        }
    }
}

/// Per-layer engine selection for `Scheme::CocoAuto`: measure every
/// legal lowering of each conv layer on a synthetic fused batch of
/// `batch` images of the layer's real shape, and rewrite the
/// `LayerPlan` (engine tag, tile config, or weight format for the int8
/// variants) to the winner. The compiled pipeline then binds that
/// choice — zero per-request dispatch.
fn autotune_engines(plan: &mut ExecPlan, threads: usize, batch: usize) {
    let mut rng = Rng::seed_from(0xC0C0);
    let layers: Vec<_> = plan
        .ir
        .layers
        .iter()
        .cloned()
        .zip(plan.layers.iter_mut())
        .collect();
    for (lir, lp) in layers {
        if let LayerKind::MatMul { relu, .. } = lir.kind {
            // Sequence projections get their own engine axis (dense
            // gemm_nt vs CSR vs int8 dequant-on-load). Attention layers
            // keep the scheme-chosen stores: their four projections run
            // inside one fused kernel, so there is no per-projection
            // dispatch to bind.
            if let LayerPlan::Proj(store) = lp {
                tune_proj_engine(store, &lir, relu, threads, batch,
                                 &mut rng);
            }
            continue;
        }
        let LayerKind::Conv { stride, relu, .. } = lir.kind else {
            continue;
        };
        let (c, h, w) = (lir.input.c, lir.input.h, lir.input.w);
        let data = random_batch(c, h, w, batch, &mut rng);
        let mut out = vec![0f32; batch * lir.output.elements()];
        match lp {
            LayerPlan::Fkw { layer, tile } => {
                // Pattern layer: AXPY tile sweep + GEMM path (all in
                // quick_candidates), then the int8 dequant-on-load
                // variant at the winning config.
                let fkw = layer.clone();
                let gp = crate::exec::pattern::PatternGemmPlan::build(
                    fkw.cin, &fkw.kernels);
                let mut u_buf = Vec::new();
                let (best_tile, best_t) =
                    tune_tile(*tile, lir.output.h, &mut |cand| {
                        let view = crate::exec::BatchView::new(
                            batch, c, h, w, &data);
                        run_pattern_candidate(view, &fkw, &gp, stride,
                                              relu, threads, cand,
                                              &mut u_buf, &mut out);
                    });
                let qf = Arc::new(QuantFkw::quantize(&fkw));
                let t_quant = measure(&mut || {
                    let view = crate::exec::BatchView::new(
                        batch, c, h, w, &data);
                    run_quant_pattern_candidate(view, &qf, &gp, stride,
                                                relu, threads, best_tile,
                                                &mut u_buf, &mut out);
                });
                *lp = if t_quant < best_t {
                    LayerPlan::QuantFkw {
                        layer: qf,
                        tile: best_tile,
                    }
                } else {
                    LayerPlan::Fkw {
                        layer: fkw,
                        tile: best_tile,
                    }
                };
            }
            LayerPlan::Dense { layer, .. } => {
                // Dense remainder (1x1 convs, non-pattern shapes):
                // naive vs im2col vs int8 im2col. The Winograd
                // candidate below is guarded on 3x3/s1 — under
                // CocoAuto's pruning every 3x3 conv became Fkw above,
                // so it only fires for plans where a 3x3 layer was
                // deliberately left dense.
                let d = layer.clone();
                let mut scratch =
                    crate::exec::im2col::Im2colScratch::default();
                let mut best_eng = DenseEngine::Im2col;
                let mut best_t = measure(&mut || {
                    let view = crate::exec::BatchView::new(
                        batch, c, h, w, &data);
                    crate::exec::im2col::conv2d_batch_into(
                        view, &d, stride, relu, threads, &mut scratch,
                        &mut out);
                    std::hint::black_box(&mut out);
                });
                let t_naive = measure(&mut || {
                    let view = crate::exec::BatchView::new(
                        batch, c, h, w, &data);
                    crate::exec::naive::conv2d_batch_into(
                        view, &d, stride, relu, threads, &mut out);
                    std::hint::black_box(&mut out);
                });
                if t_naive < best_t {
                    best_t = t_naive;
                    best_eng = DenseEngine::Naive;
                }
                if lir.is_conv3x3() && stride == 1 {
                    let ww = Arc::new(
                        crate::exec::winograd::WinogradWeights::transform(
                            &d));
                    let (mut wu, mut wm) = (Vec::new(), Vec::new());
                    let t_wino = measure(&mut || {
                        let view = crate::exec::BatchView::new(
                            batch, c, h, w, &data);
                        crate::exec::winograd::conv2d_pre_batch_into(
                            view, &ww, relu, threads, &mut wu, &mut wm,
                            &mut out);
                        std::hint::black_box(&mut out);
                    });
                    if t_wino < best_t {
                        best_t = t_wino;
                        best_eng = DenseEngine::Winograd;
                    }
                }
                // Packed-microkernel candidate: A panel packed once
                // here (as the lowering will), B packed per batch
                // inside the kernel. On the scalar tier this runs the
                // plain im2col path, so the measurement simply ties.
                let pack = Arc::new(crate::exec::micro::PackedA::pack(
                    &d.weights,
                    d.cout,
                    d.cin * d.kh * d.kw,
                ));
                let t_packed = measure(&mut || {
                    let view = crate::exec::BatchView::new(
                        batch, c, h, w, &data);
                    crate::exec::im2col::conv2d_packed_batch_into(
                        view, &d, &pack, stride, relu, threads,
                        &mut scratch, &mut out);
                    std::hint::black_box(&mut out);
                });
                if t_packed < best_t {
                    best_t = t_packed;
                    best_eng = DenseEngine::Im2colPacked;
                }
                let qd = Arc::new(QuantDense::quantize(&d));
                let t_quant = measure(&mut || {
                    let view = crate::exec::BatchView::new(
                        batch, c, h, w, &data);
                    crate::exec::im2col::conv2d_quant_batch_into(
                        view, &qd, stride, relu, threads, &mut scratch,
                        &mut out);
                    std::hint::black_box(&mut out);
                });
                *lp = if t_quant < best_t {
                    LayerPlan::QuantDense(qd)
                } else {
                    LayerPlan::Dense {
                        layer: d,
                        engine: best_eng,
                    }
                };
            }
            _ => continue,
        }
    }
}

/// Engine sweep for one sequence projection under `CocoAuto`: the
/// pruned matrix's dense-f32 twin (zeros resident — identical output
/// bits, different traversal), its CSR form, and the int8
/// dequant-on-load variant, each measured through `ops::proj_into` on a
/// synthetic `[batch * T, d_in]` token matrix — the fused-batch regime
/// the compiled pipeline actually runs.
fn tune_proj_engine(store: &mut ProjStore, lir: &crate::ir::Layer,
                    relu: bool, threads: usize, batch: usize,
                    rng: &mut Rng) {
    let (t, d_in) = (lir.input.t(), lir.input.d());
    let rows = batch * t;
    let data: Vec<f32> =
        (0..rows * d_in).map(|_| rng.normal_f32()).collect();
    let dense = match &*store {
        ProjStore::Dense(w) => w.to_proj_dense(d_in),
        ProjStore::Csr(c) => c.to_dense(),
        ProjStore::Int8(q) => q.dequantize(),
    };
    let mut out = vec![0f32; rows * dense.cout];
    let candidates = [
        ProjStore::Dense(Arc::new(FlatWeights::new(
            dense.weights.clone(),
            dense.bias.clone(),
        ))),
        ProjStore::Csr(Arc::new(CsrLayer::from_dense(&dense, None))),
        ProjStore::Int8(Arc::new(QuantDense::quantize(&dense))),
    ];
    let mut best = 0;
    let mut best_t = f64::INFINITY;
    for (i, cand) in candidates.iter().enumerate() {
        let tm = measure(&mut || {
            crate::exec::ops::proj_into(&data, rows, d_in, cand, relu,
                                        threads, &mut out);
            std::hint::black_box(&mut out);
        });
        if tm < best_t {
            best_t = tm;
            best = i;
        }
    }
    let mut it = candidates.into_iter();
    *store = it.nth(best).expect("candidate index in range");
}

/// Warm + best-of-2 wall-clock for one candidate.
fn measure(run: &mut dyn FnMut()) -> f64 {
    run(); // warm
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let s = std::time::Instant::now();
        run();
        best = best.min(s.elapsed().as_secs_f64());
    }
    best
}

/// One layer's sweep: warm + best-of-2 per candidate; returns the
/// fastest config and its time (so `autotune_engines` can compare the
/// winner against other engines without re-running the sweep).
fn tune_tile(current: TileConfig, h_out: usize,
             run: &mut dyn FnMut(TileConfig)) -> (TileConfig, f64) {
    let mut best = current;
    let mut best_t = f64::INFINITY;
    for cand in tuner::quick_candidates(h_out) {
        let t = measure(&mut || run(cand));
        if t < best_t {
            best_t = t;
            best = cand;
        }
    }
    (best, best_t)
}

impl ExecPlan {
    /// Wrap the plan for sharing: one `Arc<ExecPlan>` feeds every
    /// executor in an `exec::ExecutorPool` (and the serving
    /// `coordinator::NativeBackend` built on it), so the compressed
    /// weights exist once per process no matter how many workers serve
    /// them.
    pub fn into_shared(self) -> Arc<ExecPlan> {
        Arc::new(self)
    }

    /// Compile this plan into its op pipeline (see `lower`): per-layer
    /// kernel choice, bound weights, and arena slot assignment, all
    /// resolved ahead of serving. The lowered pipeline is checked by
    /// the static verifier (`codegen::verify`); a plan that fails
    /// verification panics here rather than executing with corrupt
    /// metadata. Use [`ExecPlan::verify_batched`] for the non-panicking
    /// typed-error path.
    pub fn compile(&self) -> CompiledPipeline {
        self.compile_batched(1)
    }

    /// Compile with a leading batch dimension (see `lower_batched`):
    /// the pipeline's arena slots carry `batch` images each, and
    /// `CompiledPipeline::execute_batched` runs a fused walk whose
    /// per-layer weight traffic is paid once per batch. Weights stay
    /// `Arc`-shared with this plan and any other pipeline compiled from
    /// it. Panics if the lowered pipeline fails static verification.
    pub fn compile_batched(&self, batch: usize) -> CompiledPipeline {
        match self.verify_batched(batch) {
            Ok(p) => p,
            Err(e) => panic!("plan '{}' failed static verification: {e}",
                             self.ir.name),
        }
    }

    /// Lower this plan at the given batch and run the static verifier
    /// over the result, returning the pipeline only if every dataflow,
    /// arena-aliasing, metadata-bounds, and legality proof holds. This
    /// is the typed-error path used by `Deployment::builder` and the
    /// `verify` CLI subcommand; `compile`/`compile_batched` wrap it
    /// with a panic.
    pub fn verify_batched(&self, batch: usize)
                          -> Result<CompiledPipeline, VerifyError> {
        let p = lower_batched(self, batch.max(1));
        verify::verify_pipeline(&p, self.scheme)?;
        Ok(p)
    }

    /// Surviving-FLOP ratio vs dense (the analytic speedup bound).
    pub fn flop_keep_ratio(&self) -> f64 {
        let mut dense = 0f64;
        let mut kept = 0f64;
        for (l, p) in self.ir.layers.iter().zip(&self.layers) {
            let f = l.flops() as f64;
            dense += f;
            kept += match p.conv_nnz() {
                Some((nnz, total)) => f * nnz as f64 / total as f64,
                None => f,
            };
        }
        if dense == 0.0 {
            1.0
        } else {
            kept / dense
        }
    }

    /// Total weight storage of the plan in bytes.
    pub fn weight_bytes(&self) -> usize {
        self.layers.iter().map(LayerPlan::weight_bytes).sum()
    }

    /// Arena footprint of the plan's static activation-memory plan (see
    /// `crate::ir::liveness`): what a `ModelExecutor` keeps resident for
    /// activations, reported alongside `weight_bytes`.
    pub fn peak_activation_bytes(&self) -> usize {
        crate::ir::liveness::MemoryPlan::build(&self.ir).peak_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Chw, IrBuilder};

    fn tiny_ir() -> ModelIR {
        let mut b = IrBuilder::new("t", Chw::new(3, 16, 16));
        b.conv("c1", 3, 8, 1, true)
            .conv("c2", 3, 16, 2, true)
            .gap("g")
            .dense("fc", 10, false);
        b.build().unwrap()
    }

    #[test]
    fn plans_for_all_schemes() {
        let ir = tiny_ir();
        for scheme in [
            Scheme::DenseNaive,
            Scheme::DenseIm2col,
            Scheme::DenseWinograd,
            Scheme::SparseCsr,
            Scheme::CocoGen,
            Scheme::CocoGenQuant,
            Scheme::CocoAuto,
        ] {
            let plan = build_plan(&ir, scheme, PruneConfig::default(), 1);
            assert_eq!(plan.layers.len(), ir.layers.len());
        }
    }

    #[test]
    fn schemes_fix_dense_engines() {
        let ir = tiny_ir();
        let naive = build_plan(&ir, Scheme::DenseNaive,
                               PruneConfig::default(), 1);
        let wino = build_plan(&ir, Scheme::DenseWinograd,
                              PruneConfig::default(), 1);
        match &naive.layers[0] {
            LayerPlan::Dense { engine, .. } => {
                assert_eq!(*engine, DenseEngine::Naive)
            }
            p => panic!("expected dense, got {p:?}"),
        }
        // c1 is 3x3 stride 1 -> winograd; c2 is stride 2 -> im2col
        match (&wino.layers[0], &wino.layers[1]) {
            (
                LayerPlan::Dense { engine: e1, .. },
                LayerPlan::Dense { engine: e2, .. },
            ) => {
                assert_eq!(*e1, DenseEngine::Winograd);
                assert_eq!(*e2, DenseEngine::Im2col);
            }
            p => panic!("expected dense pair, got {p:?}"),
        }
    }

    #[test]
    fn coco_auto_builds_like_cocogen_before_tuning() {
        let ir = tiny_ir();
        let auto = build_plan(&ir, Scheme::CocoAuto,
                              PruneConfig::default(), 1);
        for (l, p) in auto.ir.layers.iter().zip(&auto.layers) {
            if l.is_conv3x3() {
                assert!(matches!(p, LayerPlan::Fkw { .. }));
            }
        }
        let coco = build_plan(&ir, Scheme::CocoGen,
                              PruneConfig::default(), 1);
        assert_eq!(auto.weight_bytes(), coco.weight_bytes());
    }

    #[test]
    fn cocogen_reduces_flops_and_bytes() {
        let ir = tiny_ir();
        let dense = build_plan(&ir, Scheme::DenseNaive,
                               PruneConfig::default(), 1);
        let coco = build_plan(&ir, Scheme::CocoGen,
                              PruneConfig::default(), 1);
        assert!(coco.flop_keep_ratio() < 0.5);
        assert!(dense.flop_keep_ratio() == 1.0);
        assert!(coco.weight_bytes() < dense.weight_bytes());
    }

    #[test]
    fn cocogen_quant_shrinks_bytes_further() {
        let ir = tiny_ir();
        let dense = build_plan(&ir, Scheme::DenseNaive,
                               PruneConfig::default(), 1);
        let coco = build_plan(&ir, Scheme::CocoGen,
                              PruneConfig::default(), 1);
        let quant = build_plan(&ir, Scheme::CocoGenQuant,
                               PruneConfig::default(), 1);
        // int8 on top of pruning strictly shrinks the plan, and the
        // FLOP reduction of pruning is preserved (weight-only quant
        // does not change the op count).
        assert!(quant.weight_bytes() < coco.weight_bytes());
        assert!(quant.weight_bytes() < dense.weight_bytes());
        assert!((quant.flop_keep_ratio() - coco.flop_keep_ratio()).abs()
            < 1e-12);
        // every 3x3 conv became QuantFkw, remaining convs QuantDense
        for (l, p) in quant.ir.layers.iter().zip(&quant.layers) {
            if l.is_conv3x3() {
                assert!(matches!(p, LayerPlan::QuantFkw { .. }));
            }
        }
    }

    #[test]
    fn peak_activation_is_scheme_independent_and_positive() {
        let ir = tiny_ir();
        let a = build_plan(&ir, Scheme::DenseNaive,
                           PruneConfig::default(), 1);
        let b = build_plan(&ir, Scheme::CocoGenQuant,
                           PruneConfig::default(), 1);
        assert_eq!(a.peak_activation_bytes(), b.peak_activation_bytes());
        assert!(a.peak_activation_bytes() > 0);
        // bounded by the sum of all layer outputs
        let total: usize = ir
            .layers
            .iter()
            .map(|l| l.output.elements() * 4)
            .sum();
        assert!(a.peak_activation_bytes() <= total);
    }

    #[test]
    fn scheme_labels_round_trip_through_parse() {
        for s in Scheme::ALL {
            assert_eq!(Scheme::parse(s.label()), Some(s),
                       "label '{}' must parse back", s.label());
        }
        assert_eq!(Scheme::parse("int8"), Some(Scheme::CocoGenQuant));
        assert_eq!(Scheme::parse("no-such-scheme"), None);
    }

    #[test]
    fn deterministic_weights() {
        let ir = tiny_ir();
        let a = random_dense_weights(&ir, 7);
        let b = random_dense_weights(&ir, 7);
        match (&a[0], &b[0]) {
            (
                LayerPlan::Dense { layer: x, .. },
                LayerPlan::Dense { layer: y, .. },
            ) => {
                assert_eq!(x.weights, y.weights);
            }
            _ => panic!("expected dense"),
        }
    }

    fn seq_ir() -> ModelIR {
        let mut b =
            IrBuilder::new("seq", crate::ir::Shape::seq(8, 16));
        b.matmul("embed", 16, false);
        let skip = b.last();
        b.attention("attn", 4)
            .add("res", skip, false)
            .layernorm("ln")
            .seqpool("pool")
            .dense("cls", 4, false);
        b.build().unwrap()
    }

    #[test]
    fn seq_plans_for_all_schemes() {
        let ir = seq_ir();
        for scheme in Scheme::ALL {
            let plan = build_plan(&ir, scheme, PruneConfig::default(), 1);
            assert_eq!(plan.layers.len(), ir.layers.len());
            // LayerNorm parameters are never compressed.
            assert!(matches!(plan.layers[3], LayerPlan::Norm(_)),
                    "{scheme:?}");
        }
    }

    #[test]
    fn seq_pruning_and_quant_shrink_projection_bytes() {
        let ir = seq_ir();
        let dense = build_plan(&ir, Scheme::DenseIm2col,
                               PruneConfig::default(), 3);
        let pruned = build_plan(&ir, Scheme::SparseCsr,
                                PruneConfig::default(), 3);
        let quant = build_plan(&ir, Scheme::CocoGenQuant,
                               PruneConfig::default(), 3);
        // MatMul projection: dense f32 -> CSR (25% keep) -> int8
        assert!(matches!(dense.layers[0], LayerPlan::Proj(
            ProjStore::Dense(_))));
        assert!(matches!(pruned.layers[0], LayerPlan::Proj(
            ProjStore::Csr(_))));
        assert!(matches!(quant.layers[0], LayerPlan::Proj(
            ProjStore::Int8(_))));
        assert!(pruned.layers[0].weight_bytes()
                < dense.layers[0].weight_bytes());
        assert!(quant.layers[0].weight_bytes()
                < dense.layers[0].weight_bytes());
        // Attention: all four stores follow the scheme.
        match (&pruned.layers[1], &quant.layers[1]) {
            (LayerPlan::Attn(p), LayerPlan::Attn(q)) => {
                assert!(p.stores().iter().all(|s| matches!(
                    s, ProjStore::Csr(_))));
                assert!(q.stores().iter().all(|s| matches!(
                    s, ProjStore::Int8(_))));
                assert!(p.nnz().is_some());
            }
            p => panic!("expected attn plans, got {p:?}"),
        }
        // pruning keeps <50% of projection FLOPs alive
        assert!(pruned.flop_keep_ratio() < dense.flop_keep_ratio());
        assert!(pruned.weight_bytes() < dense.weight_bytes());
        assert!(quant.weight_bytes() < dense.weight_bytes());
    }

    #[test]
    fn seq_peak_activation_counts_attention_scratch() {
        let ir = seq_ir();
        let plan = build_plan(&ir, Scheme::DenseIm2col,
                              PruneConfig::default(), 1);
        // scratch = 4*T*D + heads*T*T elements, f32
        let scratch = (4 * 8 * 16 + 4 * 8 * 8) * 4;
        assert!(plan.peak_activation_bytes() >= scratch);
    }
}
