//! Pattern-aware execution-plan generation (the "compilation" half of the
//! co-design, paper §2.1.3): filter-kernel reorder, per-layer scheme
//! selection, tile auto-tuning. The output `ExecPlan` is what the exec
//! engines consume.

pub mod reorder;
pub mod tuner;

use std::sync::Arc;

use crate::compress::{CsrLayer, DenseLayer, FkwLayer};
use crate::ir::{LayerKind, ModelIR};
use crate::patterns::connectivity::{prune_connectivity, ConnectivityMask};
use crate::quant::{QuantDense, QuantFkw};
use crate::util::rng::Rng;

pub use tuner::TileConfig;

/// Which executor strategy a conv layer uses.
#[derive(Debug, Clone)]
pub enum LayerPlan {
    /// Dense direct conv (naive engine) or im2col (chosen by engine).
    Dense(DenseLayer),
    /// Non-structured sparse (CSR) conv.
    Csr(CsrLayer),
    /// Pattern + connectivity pruned, reordered, tuned (CoCo-Gen).
    Fkw { layer: FkwLayer, tile: TileConfig },
    /// Weight-only per-channel int8 dense conv (i8 weights resident, no
    /// f32 copy); runs on the im2col quant kernel.
    QuantDense(QuantDense),
    /// Pattern + connectivity pruned AND int8-quantized (CoCoGenQuant):
    /// both halves of the paper's compression, dequantized on load.
    QuantFkw { layer: QuantFkw, tile: TileConfig },
    /// Depthwise conv weights: w[c][ky][kx].
    Depthwise { weights: Vec<f32>, bias: Vec<f32> },
    /// Dense FC: w[cout][cin] + bias.
    Fc { weights: Vec<f32>, bias: Vec<f32> },
    /// No weights (pool/add/gap).
    None,
}

/// A fully planned model: IR + per-layer weights/strategies.
pub struct ExecPlan {
    pub ir: ModelIR,
    pub layers: Vec<LayerPlan>,
    pub scheme: Scheme,
}

/// Global pruning/compilation scheme (the Fig. 5 "framework" axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Dense weights, direct loops (TFLite-CPU stand-in).
    DenseNaive,
    /// Dense weights, im2col+GEMM (TVM stand-in).
    DenseIm2col,
    /// Dense weights, Winograd F(2x2,3x3) for 3x3/s1 convs (MNN stand-in).
    DenseWinograd,
    /// Non-structured pruning + CSR execution.
    SparseCsr,
    /// CoCo-Gen: pattern + connectivity pruning, reorder, LRE, tuning.
    CocoGen,
    /// CoCo-Gen composed with weight-only per-channel int8: the pruned
    /// pattern layers store i8 weights (`QuantFkw`), the remaining dense
    /// convs become `QuantDense` — both halves of compression (§1
    /// "pruning and quantization") pushed through the same compiler
    /// passes and executors.
    CocoGenQuant,
}

/// Pruning hyper-parameters for plan building.
#[derive(Debug, Clone, Copy)]
pub struct PruneConfig {
    /// Fraction of (cin,cout) kernels kept by connectivity pruning.
    pub connectivity_keep: f64,
    /// Fraction of weights kept by non-structured pruning (CSR scheme).
    pub unstructured_keep: f64,
}

impl Default for PruneConfig {
    fn default() -> Self {
        // 4/9 pattern keep * 0.55 connectivity ~= 4x conv weight reduction,
        // the mid-range of the paper's pattern+connectivity operating points.
        PruneConfig {
            connectivity_keep: 0.55,
            unstructured_keep: 0.25,
        }
    }
}

/// Deterministic random weights for a model IR (timing experiments are
/// weight-value independent; accuracy experiments use PJRT-trained models).
pub fn random_dense_weights(ir: &ModelIR, seed: u64) -> Vec<LayerPlan> {
    let mut rng = Rng::seed_from(seed);
    ir.layers
        .iter()
        .map(|l| match &l.kind {
            LayerKind::Conv { kh, kw, cout, .. } => {
                let n = kh * kw * l.input.c * cout;
                let scale = (2.0 / (kh * kw * l.input.c) as f64).sqrt();
                LayerPlan::Dense(DenseLayer {
                    cout: *cout,
                    cin: l.input.c,
                    kh: *kh,
                    kw: *kw,
                    weights: (0..n)
                        .map(|_| (rng.normal() * scale) as f32)
                        .collect(),
                    bias: (0..*cout).map(|_| rng.normal_f32() * 0.01)
                        .collect(),
                })
            }
            LayerKind::DwConv { .. } => LayerPlan::Depthwise {
                weights: (0..9 * l.input.c)
                    .map(|_| rng.normal_f32() * 0.3)
                    .collect(),
                bias: (0..l.input.c).map(|_| rng.normal_f32() * 0.01)
                    .collect(),
            },
            LayerKind::Dense { cout, .. } => {
                let cin = l.input.elements();
                let scale = (2.0 / cin as f64).sqrt();
                LayerPlan::Fc {
                    weights: (0..cin * cout)
                        .map(|_| (rng.normal() * scale) as f32)
                        .collect(),
                    bias: (0..*cout).map(|_| rng.normal_f32() * 0.01)
                        .collect(),
                }
            }
            _ => LayerPlan::None,
        })
        .collect()
}

/// Build an execution plan for (model, scheme): applies the scheme's
/// pruning to every 3x3 conv, then the codegen passes (reorder + static
/// tile heuristic) for the CoCo-Gen scheme. Use `autotune_plan` after
/// this to replace the heuristic tiles with measured ones.
pub fn build_plan(ir: &ModelIR, scheme: Scheme, prune: PruneConfig,
                  seed: u64) -> ExecPlan {
    let dense = random_dense_weights(ir, seed);
    let layers = dense
        .into_iter()
        .zip(&ir.layers)
        .map(|(plan, l)| match (&scheme, plan) {
            (
                Scheme::DenseNaive
                | Scheme::DenseIm2col
                | Scheme::DenseWinograd,
                p,
            ) => p,
            (Scheme::SparseCsr, LayerPlan::Dense(d))
                if l.is_conv3x3() =>
            {
                // Non-structured magnitude pruning, then CSR.
                let mask = crate::patterns::connectivity::prune_unstructured(
                    &d.weights,
                    prune.unstructured_keep,
                );
                LayerPlan::Csr(CsrLayer::from_dense(&d, Some(&mask)))
            }
            (Scheme::SparseCsr, p) => p,
            (Scheme::CocoGen, LayerPlan::Dense(d)) if l.is_conv3x3() => {
                let conn = prune_conn_oihw(&d, prune.connectivity_keep);
                let mut fkw = FkwLayer::from_dense(&d, &conn);
                reorder::filter_kernel_reorder(&mut fkw);
                let tile = tuner::default_tile(l.output.h, l.output.w);
                LayerPlan::Fkw { layer: fkw, tile }
            }
            (Scheme::CocoGen, p) => p,
            (Scheme::CocoGenQuant, LayerPlan::Dense(d))
                if l.is_conv3x3() =>
            {
                // Same pruning + codegen passes as CoCo-Gen, then the
                // weights (and only the weights) drop to int8.
                let conn = prune_conn_oihw(&d, prune.connectivity_keep);
                let mut fkw = FkwLayer::from_dense(&d, &conn);
                reorder::filter_kernel_reorder(&mut fkw);
                let tile = tuner::default_tile(l.output.h, l.output.w);
                LayerPlan::QuantFkw {
                    layer: QuantFkw::quantize(&fkw),
                    tile,
                }
            }
            (Scheme::CocoGenQuant, LayerPlan::Dense(d)) => {
                // Convs the pattern pass leaves dense (e.g. 1x1): still
                // weight-only int8.
                LayerPlan::QuantDense(QuantDense::quantize(&d))
            }
            (Scheme::CocoGenQuant, p) => p,
        })
        .collect();
    ExecPlan {
        ir: ir.clone(),
        layers,
        scheme,
    }
}

/// Connectivity pruning over OIHW dense weights (helper: the pruning
/// primitives take HWIO).
pub fn prune_conn_oihw(d: &DenseLayer, keep: f64) -> ConnectivityMask {
    let mut hwio = vec![0f32; d.weights.len()];
    for co in 0..d.cout {
        for ci in 0..d.cin {
            for ky in 0..d.kh {
                for kx in 0..d.kw {
                    hwio[((ky * d.kw + kx) * d.cin + ci) * d.cout + co] =
                        d.at(co, ci, ky, kx);
                }
            }
        }
    }
    prune_connectivity(&hwio, d.kh, d.kw, d.cin, d.cout, keep)
}

/// Parameter auto-tuning (paper §2.1.3): per pattern conv layer (f32
/// `Fkw` or int8 `QuantFkw`), sweep the reduced candidate set (both
/// execution paths x tile shapes) on a synthetic input of the layer's
/// real shape and keep the fastest.
pub fn autotune_plan(plan: &mut ExecPlan, threads: usize) {
    let mut rng = Rng::seed_from(0xA070);
    let layers: Vec<_> = plan
        .ir
        .layers
        .iter()
        .cloned()
        .zip(plan.layers.iter_mut())
        .collect();
    for (lir, lp) in layers {
        let LayerKind::Conv { stride, relu, .. } = lir.kind else {
            continue;
        };
        match lp {
            LayerPlan::Fkw { layer, tile } => {
                let input = crate::exec::Tensor::random(
                    lir.input.c, lir.input.h, lir.input.w, &mut rng);
                *tile = tune_tile(*tile, lir.output.h, &mut |cand| {
                    std::hint::black_box(
                        crate::exec::pattern::conv2d_auto(
                            &input, layer, stride, relu, threads, cand,
                        ),
                    );
                });
            }
            LayerPlan::QuantFkw { layer, tile } => {
                let input = crate::exec::Tensor::random(
                    lir.input.c, lir.input.h, lir.input.w, &mut rng);
                *tile = tune_tile(*tile, lir.output.h, &mut |cand| {
                    std::hint::black_box(
                        crate::exec::pattern::conv2d_quant_auto(
                            &input, layer, stride, relu, threads, cand,
                        ),
                    );
                });
            }
            _ => continue,
        }
    }
}

/// One layer's sweep: warm + best-of-2 per candidate, keep the fastest.
fn tune_tile(current: TileConfig, h_out: usize,
             run: &mut dyn FnMut(TileConfig)) -> TileConfig {
    let mut best = current;
    let mut best_t = f64::INFINITY;
    for cand in tuner::quick_candidates(h_out) {
        run(cand); // warm
        let mut t = f64::INFINITY;
        for _ in 0..2 {
            let s = std::time::Instant::now();
            run(cand);
            t = t.min(s.elapsed().as_secs_f64());
        }
        if t < best_t {
            best_t = t;
            best = cand;
        }
    }
    best
}

impl ExecPlan {
    /// Wrap the plan for sharing: one `Arc<ExecPlan>` feeds every
    /// executor in an `exec::ExecutorPool` (and the serving
    /// `coordinator::NativeBackend` built on it), so the compressed
    /// weights exist once per process no matter how many workers serve
    /// them.
    pub fn into_shared(self) -> Arc<ExecPlan> {
        Arc::new(self)
    }

    /// Surviving-FLOP ratio vs dense (the analytic speedup bound).
    pub fn flop_keep_ratio(&self) -> f64 {
        let mut dense = 0f64;
        let mut kept = 0f64;
        for (l, p) in self.ir.layers.iter().zip(&self.layers) {
            let f = l.flops() as f64;
            dense += f;
            kept += match p {
                LayerPlan::Fkw { layer, .. } => {
                    f * layer.nnz() as f64
                        / (9 * layer.cin * layer.cout) as f64
                }
                LayerPlan::QuantFkw { layer, .. } => {
                    f * layer.nnz() as f64
                        / (9 * layer.cin * layer.cout) as f64
                }
                LayerPlan::Csr(c) => {
                    f * c.nnz() as f64 / (9 * c.cin * c.cout) as f64
                }
                _ => f,
            };
        }
        if dense == 0.0 {
            1.0
        } else {
            kept / dense
        }
    }

    /// Total weight storage of the plan in bytes.
    pub fn weight_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|p| match p {
                LayerPlan::Dense(d) => d.size_bytes(),
                LayerPlan::Csr(c) => c.size_bytes(),
                LayerPlan::Fkw { layer, .. } => layer.size_bytes(),
                LayerPlan::QuantDense(q) => q.size_bytes(),
                LayerPlan::QuantFkw { layer, .. } => layer.size_bytes(),
                LayerPlan::Depthwise { weights, bias } => {
                    (weights.len() + bias.len()) * 4
                }
                LayerPlan::Fc { weights, bias } => {
                    (weights.len() + bias.len()) * 4
                }
                LayerPlan::None => 0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Chw, IrBuilder};

    fn tiny_ir() -> ModelIR {
        let mut b = IrBuilder::new("t", Chw::new(3, 16, 16));
        b.conv("c1", 3, 8, 1, true)
            .conv("c2", 3, 16, 2, true)
            .gap("g")
            .dense("fc", 10, false);
        b.build().unwrap()
    }

    #[test]
    fn plans_for_all_schemes() {
        let ir = tiny_ir();
        for scheme in [
            Scheme::DenseNaive,
            Scheme::DenseIm2col,
            Scheme::DenseWinograd,
            Scheme::SparseCsr,
            Scheme::CocoGen,
            Scheme::CocoGenQuant,
        ] {
            let plan = build_plan(&ir, scheme, PruneConfig::default(), 1);
            assert_eq!(plan.layers.len(), ir.layers.len());
        }
    }

    #[test]
    fn cocogen_reduces_flops_and_bytes() {
        let ir = tiny_ir();
        let dense = build_plan(&ir, Scheme::DenseNaive,
                               PruneConfig::default(), 1);
        let coco = build_plan(&ir, Scheme::CocoGen,
                              PruneConfig::default(), 1);
        assert!(coco.flop_keep_ratio() < 0.5);
        assert!(dense.flop_keep_ratio() == 1.0);
        assert!(coco.weight_bytes() < dense.weight_bytes());
    }

    #[test]
    fn cocogen_quant_shrinks_bytes_further() {
        let ir = tiny_ir();
        let dense = build_plan(&ir, Scheme::DenseNaive,
                               PruneConfig::default(), 1);
        let coco = build_plan(&ir, Scheme::CocoGen,
                              PruneConfig::default(), 1);
        let quant = build_plan(&ir, Scheme::CocoGenQuant,
                               PruneConfig::default(), 1);
        // int8 on top of pruning strictly shrinks the plan, and the
        // FLOP reduction of pruning is preserved (weight-only quant
        // does not change the op count).
        assert!(quant.weight_bytes() < coco.weight_bytes());
        assert!(quant.weight_bytes() < dense.weight_bytes());
        assert!((quant.flop_keep_ratio() - coco.flop_keep_ratio()).abs()
            < 1e-12);
        // every 3x3 conv became QuantFkw, remaining convs QuantDense
        for (l, p) in quant.ir.layers.iter().zip(&quant.layers) {
            if l.is_conv3x3() {
                assert!(matches!(p, LayerPlan::QuantFkw { .. }));
            }
        }
    }

    #[test]
    fn deterministic_weights() {
        let ir = tiny_ir();
        let a = random_dense_weights(&ir, 7);
        let b = random_dense_weights(&ir, 7);
        match (&a[0], &b[0]) {
            (LayerPlan::Dense(x), LayerPlan::Dense(y)) => {
                assert_eq!(x.weights, y.weights);
            }
            _ => panic!("expected dense"),
        }
    }
}
