//! Filter-kernel reorder (paper §2.1.3): group filters with similar
//! lengths and patterns so generated code has (a) minimal control-flow
//! divergence — consecutive kernels share a pattern, so the same unrolled
//! tap sequence serves long runs — and (b) balanced per-thread work, since
//! adjacent filters have similar surviving-kernel counts.

use crate::compress::{FkwKernel, FkwLayer};

/// Sort key for a filter: (kernel count, dominant pattern, pattern
/// histogram signature). Filters that compute alike become neighbours.
fn filter_key(kernels: &[FkwKernel]) -> (usize, u8, u64) {
    let mut hist = [0usize; 8];
    for k in kernels {
        hist[k.pattern as usize] += 1;
    }
    let dominant = hist
        .iter()
        .enumerate()
        .max_by_key(|(_, c)| **c)
        .map(|(p, _)| p as u8)
        .unwrap_or(0);
    // pack the histogram into a u64 signature (8 bits per bucket, capped)
    let mut sig = 0u64;
    for (i, c) in hist.iter().enumerate() {
        sig |= ((*c).min(255) as u64) << (8 * i);
    }
    (kernels.len(), dominant, sig)
}

/// In-place filter-kernel reorder on an FKW layer:
/// 1. within each filter, sort kernels by (pattern, ci) — consecutive
///    kernels then share tap offsets (instruction-level parallelism);
/// 2. across filters, sort by the filter key — thread-level load balance
///    and pattern-run locality.
pub fn filter_kernel_reorder(layer: &mut FkwLayer) {
    let cout = layer.cout;
    // Decompose into per-filter (original co, kernels, weights).
    let mut filters: Vec<(u32, Vec<FkwKernel>, Vec<f32>)> =
        Vec::with_capacity(cout);
    for phys in 0..cout {
        let lo = layer.offsets[phys] as usize;
        let hi = layer.offsets[phys + 1] as usize;
        let mut idx: Vec<usize> = (lo..hi).collect();
        // kernel reorder within the filter
        idx.sort_by_key(|&e| (layer.kernels[e].pattern, layer.kernels[e].ci));
        let kernels: Vec<FkwKernel> =
            idx.iter().map(|&e| layer.kernels[e]).collect();
        let mut weights = Vec::with_capacity(kernels.len() * 4);
        for &e in &idx {
            weights.extend_from_slice(&layer.weights[e * 4..e * 4 + 4]);
        }
        filters.push((layer.filter_order[phys], kernels, weights));
    }
    // filter reorder
    filters.sort_by_key(|(_, kernels, _)| filter_key(kernels));
    // Re-assemble.
    let mut order = Vec::with_capacity(cout);
    let mut offsets = vec![0u32];
    let mut kernels = Vec::with_capacity(layer.kernels.len());
    let mut weights = Vec::with_capacity(layer.weights.len());
    for (co, ks, ws) in filters {
        order.push(co);
        kernels.extend_from_slice(&ks);
        weights.extend_from_slice(&ws);
        offsets.push(kernels.len() as u32);
    }
    layer.filter_order = order;
    layer.offsets = offsets;
    layer.kernels = kernels;
    layer.weights = weights;
}

/// Divergence metric: number of pattern switches while walking all kernels
/// in execution order (lower = fewer control-flow transitions; the metric
/// the reorder pass minimizes).
pub fn pattern_switches(layer: &FkwLayer) -> usize {
    let mut switches = 0;
    let mut last: Option<u8> = None;
    for f in 0..layer.cout {
        for e in layer.offsets[f] as usize..layer.offsets[f + 1] as usize {
            let p = layer.kernels[e].pattern;
            if last != Some(p) {
                switches += 1;
                last = Some(p);
            }
        }
    }
    switches
}

/// Load-imbalance metric under the dynamic work-stealing scheduler: the
/// mean within-task spread of per-filter kernel counts over `co_block`-
/// sized task groups. When similar-cost filters are adjacent, each task's
/// cost is uniform and the scheduler balances perfectly; a high spread
/// means a task mixes cheap and expensive filters (divergent work).
pub fn load_imbalance(layer: &FkwLayer, co_block: usize) -> f64 {
    if layer.cout == 0 || co_block == 0 {
        return 0.0;
    }
    let counts: Vec<f64> = (0..layer.cout)
        .map(|f| (layer.offsets[f + 1] - layer.offsets[f]) as f64)
        .collect();
    let mut spreads = Vec::new();
    for group in counts.chunks(co_block) {
        let max = group.iter().cloned().fold(f64::MIN, f64::max);
        let min = group.iter().cloned().fold(f64::MAX, f64::min);
        spreads.push(max - min);
    }
    spreads.iter().sum::<f64>() / spreads.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{DenseLayer, FkwLayer};
    use crate::patterns::connectivity::ConnectivityMask;
    use crate::util::rng::Rng;

    fn random_fkw(seed: u64, cout: usize, cin: usize, keep: f64) -> (DenseLayer, FkwLayer) {
        let mut rng = Rng::seed_from(seed);
        let d = DenseLayer {
            cout,
            cin,
            kh: 3,
            kw: 3,
            weights: (0..cout * cin * 9).map(|_| rng.normal_f32()).collect(),
            bias: (0..cout).map(|_| rng.normal_f32()).collect(),
        };
        let conn = crate::codegen::prune_conn_oihw(&d, keep);
        let _ = ConnectivityMask::all_alive(1, 1);
        (d.clone(), FkwLayer::from_dense(&d, &conn))
    }

    #[test]
    fn reorder_preserves_semantics() {
        let (_, mut fkw) = random_fkw(11, 16, 12, 0.6);
        let before = fkw.to_dense();
        filter_kernel_reorder(&mut fkw);
        let after = fkw.to_dense();
        assert_eq!(before.weights, after.weights);
        assert_eq!(before.bias, after.bias);
    }

    #[test]
    fn reorder_reduces_pattern_switches() {
        let (_, mut fkw) = random_fkw(13, 32, 32, 1.0);
        let before = pattern_switches(&fkw);
        filter_kernel_reorder(&mut fkw);
        let after = pattern_switches(&fkw);
        assert!(
            after < before,
            "switches before {before} after {after}"
        );
    }

    #[test]
    fn reorder_improves_or_keeps_balance() {
        let (_, mut fkw) = random_fkw(17, 64, 16, 0.4);
        let before = load_imbalance(&fkw, 8);
        filter_kernel_reorder(&mut fkw);
        let after = load_imbalance(&fkw, 8);
        assert!(after <= before + 1e-9, "before {before} after {after}");
    }

    #[test]
    fn kernels_sorted_by_pattern_within_filters() {
        let (_, mut fkw) = random_fkw(19, 8, 24, 0.8);
        filter_kernel_reorder(&mut fkw);
        for f in 0..fkw.cout {
            let ks = &fkw.kernels
                [fkw.offsets[f] as usize..fkw.offsets[f + 1] as usize];
            for w in ks.windows(2) {
                assert!(w[0].pattern <= w[1].pattern);
            }
        }
    }
}
