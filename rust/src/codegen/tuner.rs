//! Parameter auto-tuning (paper §2.1.3): per-layer sweep over execution
//! tile shapes. On mobile GPUs the paper tunes memory placement, tiling
//! and loop permutation; the CPU analogue here is (output-row tile height,
//! filter block) for the pattern executor, chosen by microbenchmark.

use std::time::Instant;

/// Tile configuration for the pattern conv executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    /// Output rows processed per (filter, row-tile) step; bounds the input
    /// rows resident in cache (the LRE working set).
    pub h_tile: usize,
    /// Filters processed per parallel task (thread granularity).
    pub co_block: usize,
    /// Execution path: row-AXPY with LRE tiling (false) or the shared
    /// shifted-input GEMM lowering (true). Chosen by the auto-tuner;
    /// the static default uses the measured regime split (deep layers
    /// with small spatial dims favour the GEMM path).
    pub use_gemm: bool,
}

impl Default for TileConfig {
    fn default() -> Self {
        TileConfig {
            h_tile: 8,
            co_block: 4,
            use_gemm: false,
        }
    }
}

/// Static heuristic used when no microbenchmark has run: keep the row
/// tile's input working set under ~L1/2.
pub fn default_tile(h_out: usize, w_out: usize) -> TileConfig {
    TileConfig {
        h_tile: h_out.clamp(1, 8),
        co_block: 4,
        // measured regime split (see EXPERIMENTS.md §Perf): short rows
        // amortize the shared-U build; long rows favour row-AXPY LRE
        use_gemm: h_out * w_out <= 512,
    }
}

/// Candidate grid for the sweep.
pub fn candidates(h_out: usize) -> Vec<TileConfig> {
    let mut out = Vec::new();
    for h in [1usize, 2, 4, 8, 16] {
        if h > h_out.max(1) {
            continue;
        }
        for co in [1usize, 2, 4, 8] {
            out.push(TileConfig {
                h_tile: h,
                co_block: co,
                use_gemm: false,
            });
        }
    }
    // the GEMM path has no tile parameters — one candidate
    out.push(TileConfig {
        h_tile: 1,
        co_block: 1,
        use_gemm: true,
    });
    if out.is_empty() {
        out.push(TileConfig::default());
    }
    out
}

/// Reduced sweep used at plan-build time (keeps deployment compile fast):
/// the GEMM path + the 6 strongest AXPY tiles from the full sweep.
pub fn quick_candidates(h_out: usize) -> Vec<TileConfig> {
    let mut out = vec![TileConfig {
        h_tile: 1,
        co_block: 1,
        use_gemm: true,
    }];
    for h in [4usize, 8, 16] {
        if h > h_out.max(1) && h != 4 {
            continue;
        }
        for co in [2usize, 4] {
            out.push(TileConfig {
                h_tile: h.min(h_out.max(1)),
                co_block: co,
                use_gemm: false,
            });
        }
    }
    out.dedup();
    out
}

/// The batch size a live re-tune should target, from the observed
/// mean batch of the serving window (`Summary::mean_batch`): the
/// nearest integer, clamped to `[1, max_batch]`. An empty window (NaN
/// mean) or a sub-unit mean tunes at batch 1 — never at a batch the
/// coordinator would not actually form.
pub fn observed_tune_batch(mean_batch: f64, max_batch: usize)
                           -> usize {
    if !mean_batch.is_finite() || mean_batch < 1.0 {
        return 1;
    }
    (mean_batch.round() as usize).clamp(1, max_batch.max(1))
}

/// Auto-tune: run `run(cfg)` for each candidate (each candidate measured
/// `reps` times, best-of), return the fastest config and the measured
/// table for reporting.
pub fn autotune<F>(h_out: usize, reps: usize, mut run: F)
                   -> (TileConfig, Vec<(TileConfig, f64)>)
where
    F: FnMut(TileConfig),
{
    let mut results = Vec::new();
    for cfg in candidates(h_out) {
        run(cfg); // warm
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t = Instant::now();
            run(cfg);
            best = best.min(t.elapsed().as_secs_f64());
        }
        results.push((cfg, best));
    }
    let best = results
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(c, _)| *c)
        .unwrap_or_default();
    (best, results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_respect_bounds() {
        for c in candidates(4) {
            assert!(c.h_tile <= 4);
            assert!(c.co_block >= 1);
        }
        assert!(!candidates(0).is_empty());
    }

    #[test]
    fn observed_tune_batch_clamps_and_survives_empty_windows() {
        assert_eq!(observed_tune_batch(f64::NAN, 8), 1);
        assert_eq!(observed_tune_batch(0.2, 8), 1);
        assert_eq!(observed_tune_batch(3.4, 8), 3);
        assert_eq!(observed_tune_batch(3.6, 8), 4);
        assert_eq!(observed_tune_batch(100.0, 8), 8);
        assert_eq!(observed_tune_batch(2.0, 0), 1);
    }

    #[test]
    fn autotune_picks_fastest() {
        // Synthetic cost: h_tile=4, co_block=2 is fastest.
        let (best, table) = autotune(16, 3, |cfg| {
            let cost = (cfg.h_tile as i64 - 4).unsigned_abs() as u64
                + (cfg.co_block as i64 - 2).unsigned_abs() as u64;
            std::thread::sleep(std::time::Duration::from_micros(
                50 + 300 * cost,
            ));
        });
        assert!(!table.is_empty());
        assert_eq!(best.h_tile, 4, "{table:?}");
        assert_eq!(best.co_block, 2, "{table:?}");
    }
}
