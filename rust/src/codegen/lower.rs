//! Lowering: compile an `ExecPlan` into a flat op pipeline.
//!
//! The paper's §2.1.3 claim is that the *compiler* picks the execution
//! strategy per layer. This pass is where that happens for the native
//! path: every `(LayerKind, LayerPlan)` pair is resolved ONCE into a
//! [`CompiledOp`] that binds
//!
//! * the kernel choice (which engine entry point runs the layer),
//! * the weights (`Arc`-shared with the plan — no copy),
//! * the geometry (input/output shapes, stride, fused ReLU),
//! * compile-time derived data (Winograd-domain weights, the pattern-GEMM
//!   row map),
//! * preassigned input/output arena slots from the IR liveness pass
//!   (`crate::ir::liveness`), including `Add` skip-link sources.
//!
//! `ModelExecutor::run` then degenerates into a straight walk over
//! `CompiledPipeline::ops` — no per-layer `match` on `LayerPlan` or
//! `Scheme`, no activation allocation beyond the [`Arena`], no
//! `saved`/`clone` bookkeeping for residual inputs.

use std::sync::Arc;

use crate::compress::{AttnWeights, CsrLayer, DenseLayer, FkwLayer,
                      FlatWeights, ProjStore};
use crate::exec::pattern::PatternGemmPlan;
use crate::exec::tensor::{BatchView, TensorView};
use crate::exec::winograd::WinogradWeights;
use crate::exec::{csr, im2col, micro, naive, ops, pattern, winograd,
                  ExecScratch, Tensor};
use crate::ir::liveness::MemoryPlan;
use crate::ir::{Chw, LayerKind};
use crate::quant::{QuantDense, QuantFkw};

use super::{DenseEngine, ExecPlan, LayerPlan, TileConfig};

/// Where an op reads an activation from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufId {
    /// The caller-provided model input.
    Input,
    /// An arena slot.
    Slot(usize),
}

/// The kernel an op was lowered to, weights bound. Selection happened at
/// lowering; executing an op is a direct call into the chosen engine's
/// write-into-output entry point.
#[derive(Debug, Clone)]
pub enum CompiledKernel {
    ConvNaive {
        w: Arc<DenseLayer>,
        stride: usize,
        relu: bool,
    },
    ConvIm2col {
        w: Arc<DenseLayer>,
        stride: usize,
        relu: bool,
    },
    /// im2col with the weight panel packed at lowering into the
    /// register-tiled microkernel layout — every inference skips the
    /// A-pack. Selected by the autotuner where it wins; falls back to
    /// the plain im2col path on the scalar dispatch tier.
    ConvIm2colPacked {
        w: Arc<DenseLayer>,
        pack: Arc<micro::PackedA>,
        stride: usize,
        relu: bool,
    },
    /// Weights pre-transformed into the Winograd domain at lowering.
    ConvWinograd {
        w: Arc<WinogradWeights>,
        relu: bool,
    },
    ConvCsr {
        w: Arc<CsrLayer>,
        stride: usize,
        relu: bool,
    },
    /// Pattern row-AXPY path with its tuned tile.
    ConvPattern {
        w: Arc<FkwLayer>,
        stride: usize,
        relu: bool,
        tile: TileConfig,
    },
    /// Pattern GEMM path with its row map precomputed at lowering.
    ConvPatternGemm {
        w: Arc<FkwLayer>,
        stride: usize,
        relu: bool,
        gp: PatternGemmPlan,
    },
    ConvQuantDense {
        w: Arc<QuantDense>,
        stride: usize,
        relu: bool,
    },
    ConvQuantPattern {
        w: Arc<QuantFkw>,
        stride: usize,
        relu: bool,
        tile: TileConfig,
    },
    ConvQuantPatternGemm {
        w: Arc<QuantFkw>,
        stride: usize,
        relu: bool,
        gp: PatternGemmPlan,
    },
    Depthwise {
        w: Arc<FlatWeights>,
        stride: usize,
        relu: bool,
    },
    MaxPool2,
    GlobalAvgPool,
    Fc {
        w: Arc<FlatWeights>,
        relu: bool,
    },
    /// Residual add; the skip operand is `CompiledOp::src2`.
    Add { relu: bool },
    /// Sequence projection `[T, Din] -> [T, Dout]`; the store fixes the
    /// engine (dense `gemm_nt`, CSR, or int8 dequant-on-load).
    SeqMatMul { w: ProjStore, relu: bool },
    /// Per-token layer normalization (gamma in `weights`, beta in
    /// `bias`).
    SeqNorm { w: Arc<FlatWeights> },
    /// Multi-head self-attention; runs out of the arena's shared
    /// sequence scratch region.
    SeqAttn {
        w: Arc<AttnWeights>,
        heads: usize,
    },
    /// Mean over tokens, `[T, D] -> [D, 1, 1]` (the seq -> spatial
    /// bridge feeding the classifier head).
    SeqPool,
}

/// One fully resolved pipeline step.
#[derive(Debug, Clone)]
pub struct CompiledOp {
    pub kernel: CompiledKernel,
    /// Main input buffer.
    pub src: BufId,
    /// Second input (the `Add` skip source).
    pub src2: Option<BufId>,
    /// Output arena slot.
    pub dst: usize,
    pub in_shape: Chw,
    pub out_shape: Chw,
}

/// A compiled model: ops in execution order plus the arena layout they
/// were planned against. Immutable and `Send + Sync` (weights are `Arc`),
/// so one pipeline is shared by every executor in a pool — compile once,
/// serve everywhere.
#[derive(Debug, Clone)]
pub struct CompiledPipeline {
    pub ops: Vec<CompiledOp>,
    /// Model input shape.
    pub input: Chw,
    /// The arena layout (slot assignment + slot capacities) the ops'
    /// `src`/`dst` fields index into.
    pub mem: MemoryPlan,
}

impl CompiledPipeline {
    /// Arena footprint in bytes (what [`Arena::for_pipeline`] allocates).
    /// Includes the leading batch dimension for batch-compiled pipelines.
    pub fn peak_activation_bytes(&self) -> usize {
        self.mem.peak_bytes()
    }

    /// Largest fused batch this pipeline's arena was planned for
    /// (1 for single-image pipelines).
    pub fn max_batch(&self) -> usize {
        self.mem.batch
    }

    /// Run the pipeline: a flat walk over the ops, each reading and
    /// writing preassigned arena slots. The only allocation is the
    /// returned output tensor; every intermediate activation lives in
    /// `arena` and every engine scratch buffer in `scratch` (both warm
    /// after the first call).
    pub fn execute(&self, input: &Tensor, arena: &mut Arena,
                   scratch: &mut ExecScratch, threads: usize) -> Tensor {
        assert_eq!(input.shape(), self.input, "input shape mismatch");
        let Some(last_op) = self.ops.last() else {
            return input.clone();
        };
        // Detach the sequence scratch so attention can write it while
        // the arena's slots are borrowed for reading.
        let mut sbuf = std::mem::take(&mut arena.seq_scratch);
        for op in &self.ops {
            let in_elems = op.in_shape.elements();
            let out_elems = op.out_shape.elements();
            // Move the destination buffer out of the arena so the
            // sources can be borrowed from it simultaneously; the
            // memory plan guarantees dst never aliases a live source.
            let mut dstbuf = std::mem::take(&mut arena.bufs[op.dst]);
            let dst = &mut dstbuf[..out_elems];
            {
                let src_all = arena.read(&input.data, op.src);
                let view = TensorView::new(
                    op.in_shape.c,
                    op.in_shape.h,
                    op.in_shape.w,
                    &src_all[..in_elems],
                );
                match &op.kernel {
                    CompiledKernel::ConvNaive { w, stride, relu } => {
                        naive::conv2d_into(view, w, *stride, *relu,
                                           threads, dst);
                    }
                    CompiledKernel::ConvIm2col { w, stride, relu } => {
                        im2col::conv2d_into(view, w, *stride, *relu,
                                            threads, &mut scratch.im2col,
                                            dst);
                    }
                    CompiledKernel::ConvIm2colPacked {
                        w, pack, stride, relu,
                    } => {
                        im2col::conv2d_packed_into(
                            view, w, pack, *stride, *relu, threads,
                            &mut scratch.im2col, dst,
                        );
                    }
                    CompiledKernel::ConvWinograd { w, relu } => {
                        winograd::conv2d_pre_into(
                            view, w, *relu, threads, &mut scratch.wino_u,
                            &mut scratch.wino_m, dst,
                        );
                    }
                    CompiledKernel::ConvCsr { w, stride, relu } => {
                        csr::conv2d_into(view, w, *stride, *relu, threads,
                                         dst);
                    }
                    CompiledKernel::ConvPattern {
                        w, stride, relu, tile,
                    } => {
                        pattern::conv2d_into(view, w, *stride, *relu,
                                             threads, *tile, dst);
                    }
                    CompiledKernel::ConvPatternGemm {
                        w, stride, relu, gp,
                    } => {
                        pattern::conv2d_gemm_into(
                            view, w, *stride, *relu, threads, gp,
                            &mut scratch.gemm_u, dst,
                        );
                    }
                    CompiledKernel::ConvQuantDense { w, stride, relu } => {
                        im2col::conv2d_quant_into(
                            view, w, *stride, *relu, threads,
                            &mut scratch.im2col, dst,
                        );
                    }
                    CompiledKernel::ConvQuantPattern {
                        w, stride, relu, tile,
                    } => {
                        pattern::conv2d_quant_into(view, w, *stride,
                                                   *relu, threads, *tile,
                                                   dst);
                    }
                    CompiledKernel::ConvQuantPatternGemm {
                        w, stride, relu, gp,
                    } => {
                        pattern::conv2d_gemm_quant_into(
                            view, w, *stride, *relu, threads, gp,
                            &mut scratch.gemm_u, dst,
                        );
                    }
                    CompiledKernel::Depthwise { w, stride, relu } => {
                        ops::depthwise3x3_into(view, &w.weights, &w.bias,
                                               *stride, *relu, dst);
                    }
                    CompiledKernel::MaxPool2 => {
                        ops::maxpool2_into(view, dst);
                    }
                    CompiledKernel::GlobalAvgPool => {
                        ops::gap_into(view, dst);
                    }
                    CompiledKernel::Fc { w, relu } => {
                        ops::dense_into(view.data, &w.weights, &w.bias,
                                        op.out_shape.c, *relu, dst);
                    }
                    CompiledKernel::Add { relu } => {
                        let skip = arena.read(
                            &input.data,
                            op.src2.expect("Add op without skip source"),
                        );
                        ops::add_into(view.data, &skip[..out_elems],
                                      *relu, dst);
                    }
                    CompiledKernel::SeqMatMul { w, relu } => {
                        ops::proj_into(view.data, op.in_shape.t(),
                                       op.in_shape.d(), w, *relu, threads,
                                       dst);
                    }
                    CompiledKernel::SeqNorm { w } => {
                        ops::layernorm_into(view.data, op.in_shape.t(),
                                            op.in_shape.d(), &w.weights,
                                            &w.bias, dst);
                    }
                    CompiledKernel::SeqAttn { w, heads } => {
                        ops::attention_into(view.data, op.in_shape.t(),
                                            op.in_shape.d(), w, *heads,
                                            threads, &mut sbuf, dst);
                    }
                    CompiledKernel::SeqPool => {
                        ops::seqpool_into(view.data, op.in_shape.t(),
                                          op.in_shape.d(), dst);
                    }
                }
            }
            arena.bufs[op.dst] = dstbuf;
        }
        arena.seq_scratch = sbuf;
        let shape = last_op.out_shape;
        let mut out = Tensor::from_shape(shape);
        out.data
            .copy_from_slice(&arena.bufs[last_op.dst][..shape.elements()]);
        out
    }

    /// Run the pipeline over a fused batch of `n` images packed
    /// contiguously (`[N][C][H][W]`, `n <= max_batch()`): one walk over
    /// the ops for the whole batch, each op serving every image through
    /// its engine's `*_batch_into` entry point — the compressed weight
    /// stream of each layer is decoded/streamed once per *batch*, not
    /// once per image. Per-image results are bit-identical to
    /// [`CompiledPipeline::execute`] on that image alone (the batched
    /// kernels preserve each image's accumulation order exactly).
    pub fn execute_batched(&self, n: usize, input: &[f32],
                           arena: &mut Arena, scratch: &mut ExecScratch,
                           threads: usize) -> Vec<Tensor> {
        assert!(n >= 1, "empty batch");
        assert!(
            n <= self.max_batch(),
            "batch of {n} exceeds the pipeline's planned batch {}",
            self.max_batch()
        );
        let per_in = self.input.elements();
        assert_eq!(input.len(), n * per_in, "batched input length \
                                             mismatch");
        let Some(last_op) = self.ops.last() else {
            return (0..n)
                .map(|i| {
                    let mut t = Tensor::from_shape(self.input);
                    t.data.copy_from_slice(
                        &input[i * per_in..(i + 1) * per_in],
                    );
                    t
                })
                .collect();
        };
        let mut sbuf = std::mem::take(&mut arena.seq_scratch);
        for op in &self.ops {
            let in_elems = n * op.in_shape.elements();
            let out_elems = n * op.out_shape.elements();
            let mut dstbuf = std::mem::take(&mut arena.bufs[op.dst]);
            let dst = &mut dstbuf[..out_elems];
            {
                let src_all = arena.read(input, op.src);
                let view = BatchView::new(
                    n,
                    op.in_shape.c,
                    op.in_shape.h,
                    op.in_shape.w,
                    &src_all[..in_elems],
                );
                match &op.kernel {
                    CompiledKernel::ConvNaive { w, stride, relu } => {
                        naive::conv2d_batch_into(view, w, *stride, *relu,
                                                 threads, dst);
                    }
                    CompiledKernel::ConvIm2col { w, stride, relu } => {
                        im2col::conv2d_batch_into(
                            view, w, *stride, *relu, threads,
                            &mut scratch.im2col, dst,
                        );
                    }
                    CompiledKernel::ConvIm2colPacked {
                        w, pack, stride, relu,
                    } => {
                        im2col::conv2d_packed_batch_into(
                            view, w, pack, *stride, *relu, threads,
                            &mut scratch.im2col, dst,
                        );
                    }
                    CompiledKernel::ConvWinograd { w, relu } => {
                        winograd::conv2d_pre_batch_into(
                            view, w, *relu, threads, &mut scratch.wino_u,
                            &mut scratch.wino_m, dst,
                        );
                    }
                    CompiledKernel::ConvCsr { w, stride, relu } => {
                        csr::conv2d_batch_into(view, w, *stride, *relu,
                                               threads, dst);
                    }
                    CompiledKernel::ConvPattern {
                        w, stride, relu, tile,
                    } => {
                        pattern::conv2d_batch_into(view, w, *stride,
                                                   *relu, threads, *tile,
                                                   dst);
                    }
                    CompiledKernel::ConvPatternGemm {
                        w, stride, relu, gp,
                    } => {
                        pattern::conv2d_gemm_batch_into(
                            view, w, *stride, *relu, threads, gp,
                            &mut scratch.gemm_u, dst,
                        );
                    }
                    CompiledKernel::ConvQuantDense { w, stride, relu } => {
                        im2col::conv2d_quant_batch_into(
                            view, w, *stride, *relu, threads,
                            &mut scratch.im2col, dst,
                        );
                    }
                    CompiledKernel::ConvQuantPattern {
                        w, stride, relu, tile,
                    } => {
                        pattern::conv2d_quant_batch_into(
                            view, w, *stride, *relu, threads, *tile, dst,
                        );
                    }
                    CompiledKernel::ConvQuantPatternGemm {
                        w, stride, relu, gp,
                    } => {
                        pattern::conv2d_gemm_quant_batch_into(
                            view, w, *stride, *relu, threads, gp,
                            &mut scratch.gemm_u, dst,
                        );
                    }
                    CompiledKernel::Depthwise { w, stride, relu } => {
                        ops::depthwise3x3_batch_into(
                            view, &w.weights, &w.bias, *stride, *relu,
                            dst,
                        );
                    }
                    CompiledKernel::MaxPool2 => {
                        ops::maxpool2_batch_into(view, dst);
                    }
                    CompiledKernel::GlobalAvgPool => {
                        ops::gap_batch_into(view, dst);
                    }
                    CompiledKernel::Fc { w, relu } => {
                        ops::dense_batch_into(view.data, n, &w.weights,
                                              &w.bias, op.out_shape.c,
                                              *relu, dst);
                    }
                    CompiledKernel::Add { relu } => {
                        let skip = arena.read(
                            input,
                            op.src2.expect("Add op without skip source"),
                        );
                        ops::add_into(view.data, &skip[..out_elems],
                                      *relu, dst);
                    }
                    // Projections and layernorm are row-independent, so
                    // a batch fuses as `n * T` rows of one call — each
                    // image's accumulation order is untouched.
                    CompiledKernel::SeqMatMul { w, relu } => {
                        ops::proj_into(view.data, n * op.in_shape.t(),
                                       op.in_shape.d(), w, *relu, threads,
                                       dst);
                    }
                    CompiledKernel::SeqNorm { w } => {
                        ops::layernorm_into(view.data,
                                            n * op.in_shape.t(),
                                            op.in_shape.d(), &w.weights,
                                            &w.bias, dst);
                    }
                    CompiledKernel::SeqAttn { w, heads } => {
                        ops::attention_batch_into(
                            view.data, n, op.in_shape.t(),
                            op.in_shape.d(), w, *heads, threads,
                            &mut sbuf, dst,
                        );
                    }
                    CompiledKernel::SeqPool => {
                        ops::seqpool_batch_into(view.data, n,
                                                op.in_shape.t(),
                                                op.in_shape.d(), dst);
                    }
                }
            }
            arena.bufs[op.dst] = dstbuf;
        }
        arena.seq_scratch = sbuf;
        let shape = last_op.out_shape;
        let per = shape.elements();
        let buf = &arena.bufs[last_op.dst];
        (0..n)
            .map(|i| {
                let mut t = Tensor::from_shape(shape);
                t.data.copy_from_slice(&buf[i * per..(i + 1) * per]);
                t
            })
            .collect()
    }
}

/// The reusable activation buffers one executor owns, sized by the
/// pipeline's memory plan. Allocated once; never grows at run time.
#[derive(Debug)]
pub struct Arena {
    bufs: Vec<Vec<f32>>,
    /// Shared sequence scratch (attention Q/K/V/context rows + the
    /// `[heads, T, T]` score buffer), sized by the plan's
    /// `scratch_elems`. Empty for conv-only models; never grows for
    /// batches either — the batched attention kernel loops per image.
    seq_scratch: Vec<f32>,
}

impl Arena {
    /// Allocate every slot of `p`'s memory plan up front.
    pub fn for_pipeline(p: &CompiledPipeline) -> Arena {
        Arena {
            bufs: p
                .mem
                .slot_elems
                .iter()
                .map(|&n| vec![0f32; n])
                .collect(),
            seq_scratch: vec![0f32; p.mem.scratch_elems],
        }
    }

    /// Resident arena bytes (regression guard for the no-growth
    /// property). Length-based, so it equals the memory plan's
    /// `peak_bytes` exactly regardless of allocator rounding.
    pub fn bytes(&self) -> usize {
        self.bufs.iter().map(|b| b.len() * 4).sum::<usize>()
            + self.seq_scratch.len() * 4
    }

    fn read<'a>(&'a self, input: &'a [f32], id: BufId) -> &'a [f32] {
        match id {
            BufId::Input => input,
            BufId::Slot(s) => &self.bufs[s],
        }
    }
}

/// Compile an `ExecPlan` into its op pipeline: kernel selection, weight
/// binding, compile-time weight transforms, and arena slot assignment.
/// Panics on an internally inconsistent plan (a layer kind paired with
/// an incompatible `LayerPlan`), exactly like the old interpreter did —
/// that is a plan-construction bug, not an input error.
pub fn lower(plan: &ExecPlan) -> CompiledPipeline {
    lower_batched(plan, 1)
}

/// [`lower`] with a leading batch dimension: identical kernel choices
/// and slot assignment, but every arena slot is sized for `batch`
/// images stored contiguously, so
/// [`CompiledPipeline::execute_batched`] serves fused batches of up to
/// `batch` out of the same fixed arena. Weights are the same `Arc`s as
/// any other pipeline compiled from this plan — compiling both a
/// single-image and a batched pipeline does not duplicate a single
/// weight tensor.
pub fn lower_batched(plan: &ExecPlan, batch: usize) -> CompiledPipeline {
    let ir = &plan.ir;
    let mem = MemoryPlan::build_batched(ir, batch);
    let mut ops = Vec::with_capacity(ir.layers.len());
    for (i, (layer, lplan)) in
        ir.layers.iter().zip(&plan.layers).enumerate()
    {
        let kernel = match (&layer.kind, lplan) {
            (
                LayerKind::Conv { stride, relu, .. },
                LayerPlan::Dense { layer: d, engine },
            ) => match engine {
                DenseEngine::Naive => CompiledKernel::ConvNaive {
                    w: d.clone(),
                    stride: *stride,
                    relu: *relu,
                },
                DenseEngine::Winograd
                    if d.kh == 3 && d.kw == 3 && *stride == 1 =>
                {
                    CompiledKernel::ConvWinograd {
                        w: Arc::new(WinogradWeights::transform(d)),
                        relu: *relu,
                    }
                }
                // Compile-time A-panel packing: done once per
                // pipeline, Arc-shared like any bound weight tensor.
                DenseEngine::Im2colPacked => {
                    CompiledKernel::ConvIm2colPacked {
                        w: d.clone(),
                        pack: Arc::new(micro::PackedA::pack(
                            &d.weights,
                            d.cout,
                            d.cin * d.kh * d.kw,
                        )),
                        stride: *stride,
                        relu: *relu,
                    }
                }
                // Winograd on an illegal shape falls back to im2col,
                // matching the scheme's documented behavior.
                DenseEngine::Im2col | DenseEngine::Winograd => {
                    CompiledKernel::ConvIm2col {
                        w: d.clone(),
                        stride: *stride,
                        relu: *relu,
                    }
                }
            },
            (LayerKind::Conv { stride, relu, .. }, LayerPlan::Csr(c)) => {
                CompiledKernel::ConvCsr {
                    w: c.clone(),
                    stride: *stride,
                    relu: *relu,
                }
            }
            (
                LayerKind::Conv { stride, relu, .. },
                LayerPlan::Fkw { layer: f, tile },
            ) => {
                if tile.use_gemm {
                    CompiledKernel::ConvPatternGemm {
                        w: f.clone(),
                        stride: *stride,
                        relu: *relu,
                        gp: PatternGemmPlan::build(f.cin, &f.kernels),
                    }
                } else {
                    CompiledKernel::ConvPattern {
                        w: f.clone(),
                        stride: *stride,
                        relu: *relu,
                        tile: *tile,
                    }
                }
            }
            (
                LayerKind::Conv { stride, relu, .. },
                LayerPlan::QuantDense(q),
            ) => CompiledKernel::ConvQuantDense {
                w: q.clone(),
                stride: *stride,
                relu: *relu,
            },
            (
                LayerKind::Conv { stride, relu, .. },
                LayerPlan::QuantFkw { layer: q, tile },
            ) => {
                if tile.use_gemm {
                    CompiledKernel::ConvQuantPatternGemm {
                        w: q.clone(),
                        stride: *stride,
                        relu: *relu,
                        gp: PatternGemmPlan::build(q.cin, &q.kernels),
                    }
                } else {
                    CompiledKernel::ConvQuantPattern {
                        w: q.clone(),
                        stride: *stride,
                        relu: *relu,
                        tile: *tile,
                    }
                }
            }
            (
                LayerKind::DwConv { stride, relu },
                LayerPlan::Depthwise(w),
            ) => CompiledKernel::Depthwise {
                w: w.clone(),
                stride: *stride,
                relu: *relu,
            },
            (LayerKind::MaxPool2, _) => CompiledKernel::MaxPool2,
            (LayerKind::GlobalAvgPool, _) => CompiledKernel::GlobalAvgPool,
            (LayerKind::Dense { relu, .. }, LayerPlan::Fc(w)) => {
                CompiledKernel::Fc {
                    w: w.clone(),
                    relu: *relu,
                }
            }
            (LayerKind::Add { relu, .. }, _) => {
                CompiledKernel::Add { relu: *relu }
            }
            (LayerKind::MatMul { relu, .. }, LayerPlan::Proj(p)) => {
                CompiledKernel::SeqMatMul {
                    w: p.clone(),
                    relu: *relu,
                }
            }
            (LayerKind::LayerNorm, LayerPlan::Norm(w)) => {
                CompiledKernel::SeqNorm { w: w.clone() }
            }
            (LayerKind::SelfAttention { heads }, LayerPlan::Attn(a)) => {
                CompiledKernel::SeqAttn {
                    w: a.clone(),
                    heads: *heads,
                }
            }
            (LayerKind::SeqPool, _) => CompiledKernel::SeqPool,
            (k, p) => panic!(
                "layer {} kind {:?} has incompatible plan {:?}",
                layer.name,
                k,
                std::mem::discriminant(p)
            ),
        };
        let src = if i == 0 {
            BufId::Input
        } else {
            BufId::Slot(mem.slot_of[i - 1])
        };
        let src2 = match layer.kind {
            LayerKind::Add { from, .. } => {
                Some(BufId::Slot(mem.slot_of[from]))
            }
            _ => None,
        };
        ops.push(CompiledOp {
            kernel,
            src,
            src2,
            dst: mem.slot_of[i],
            in_shape: layer.input,
            out_shape: layer.output,
        });
    }
    CompiledPipeline {
        ops,
        input: ir.input,
        mem,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{build_plan, PruneConfig, Scheme};
    use crate::ir::{Chw, IrBuilder};
    use crate::util::rng::Rng;

    fn residual_ir() -> crate::ir::ModelIR {
        let mut b = IrBuilder::new("t", Chw::new(3, 10, 10));
        b.conv("c1", 3, 8, 1, true);
        let skip = b.last();
        b.conv("c2", 3, 8, 1, false)
            .add("a", skip, true)
            .maxpool("p")
            .gap("g")
            .dense("fc", 4, false);
        b.build().unwrap()
    }

    #[test]
    fn lowering_binds_slots_and_kernels() {
        let ir = residual_ir();
        let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                              3);
        let p = lower(&plan);
        assert_eq!(p.ops.len(), ir.layers.len());
        // every op writes a slot inside the arena
        for op in &p.ops {
            assert!(op.dst < p.mem.slot_elems.len());
            assert!(op.out_shape.elements() <= p.mem.slot_elems[op.dst]);
        }
        // the Add op carries its skip source
        let add = &p.ops[2];
        assert!(matches!(add.kernel, CompiledKernel::Add { .. }));
        assert_eq!(add.src2, Some(BufId::Slot(p.ops[0].dst)));
        // pattern layers compiled to a pattern kernel, not re-dispatched
        assert!(matches!(
            p.ops[0].kernel,
            CompiledKernel::ConvPattern { .. }
                | CompiledKernel::ConvPatternGemm { .. }
        ));
        assert!(p.peak_activation_bytes() > 0);
    }

    #[test]
    fn pipeline_is_send_and_sync() {
        fn assert_ss<T: Send + Sync>(_: &T) {}
        let ir = residual_ir();
        let plan = build_plan(&ir, Scheme::CocoGenQuant,
                              PruneConfig::default(), 3);
        let p = lower(&plan);
        assert_ss(&p);
    }

    #[test]
    fn batched_lowering_scales_arena_not_weights() {
        let ir = residual_ir();
        let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                              3);
        let single = lower(&plan);
        let batched = lower_batched(&plan, 4);
        assert_eq!(single.max_batch(), 1);
        assert_eq!(batched.max_batch(), 4);
        assert_eq!(single.peak_activation_bytes() * 4,
                   batched.peak_activation_bytes());
        // identical op structure and slot assignment
        assert_eq!(single.ops.len(), batched.ops.len());
        for (a, b) in single.ops.iter().zip(&batched.ops) {
            assert_eq!(a.dst, b.dst);
            assert_eq!(a.src, b.src);
            assert_eq!(a.out_shape, b.out_shape);
        }
        // the Arc'd weights are shared, not copied
        if let (CompiledKernel::ConvPattern { w: a, .. }
                | CompiledKernel::ConvPatternGemm { w: a, .. },
                CompiledKernel::ConvPattern { w: b, .. }
                | CompiledKernel::ConvPatternGemm { w: b, .. }) =
            (&single.ops[0].kernel, &batched.ops[0].kernel)
        {
            assert!(Arc::ptr_eq(a, b), "batched lowering copied weights");
        } else {
            panic!("expected pattern kernels");
        }
    }

    #[test]
    fn batched_execute_matches_single_execute() {
        let ir = residual_ir();
        let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                              3);
        let p1 = lower(&plan);
        let pb = lower_batched(&plan, 3);
        let mut rng = Rng::seed_from(4);
        let images: Vec<Tensor> = (0..3)
            .map(|_| Tensor::random(3, 10, 10, &mut rng))
            .collect();
        let mut packed = Vec::new();
        for t in &images {
            packed.extend_from_slice(&t.data);
        }
        let mut arena_b = Arena::for_pipeline(&pb);
        let mut scratch = ExecScratch::default();
        let outs =
            pb.execute_batched(3, &packed, &mut arena_b, &mut scratch, 2);
        let mut arena_1 = Arena::for_pipeline(&p1);
        for (x, got) in images.iter().zip(&outs) {
            let want = p1.execute(x, &mut arena_1, &mut scratch, 2);
            assert_eq!(want.data, got.data,
                       "fused batch diverged from single execute");
        }
    }

    fn seq_ir() -> crate::ir::ModelIR {
        let mut b =
            IrBuilder::new("seq", crate::ir::Shape::seq(8, 16));
        b.matmul("embed", 16, false);
        let skip = b.last();
        b.attention("attn", 2)
            .add("res", skip, false)
            .layernorm("ln")
            .seqpool("pool")
            .dense("cls", 3, false);
        b.build().unwrap()
    }

    #[test]
    fn seq_lowering_binds_kernels_and_scratch() {
        let ir = seq_ir();
        let plan = build_plan(&ir, Scheme::DenseIm2col,
                              PruneConfig::default(), 5);
        let p = lower(&plan);
        assert!(matches!(p.ops[0].kernel,
                         CompiledKernel::SeqMatMul { .. }));
        assert!(matches!(p.ops[1].kernel,
                         CompiledKernel::SeqAttn { .. }));
        assert!(matches!(p.ops[3].kernel,
                         CompiledKernel::SeqNorm { .. }));
        assert!(matches!(p.ops[4].kernel, CompiledKernel::SeqPool));
        assert!(p.mem.scratch_elems > 0);
        // the allocated arena equals the reported peak, scratch included
        let arena = Arena::for_pipeline(&p);
        assert_eq!(arena.bytes(), p.peak_activation_bytes());
    }

    #[test]
    fn batched_seq_execute_matches_single() {
        let ir = seq_ir();
        for scheme in [
            Scheme::DenseIm2col,
            Scheme::SparseCsr,
            Scheme::CocoGenQuant,
        ] {
            let plan =
                build_plan(&ir, scheme, PruneConfig::default(), 9);
            let p1 = lower(&plan);
            let pb = lower_batched(&plan, 4);
            // slots scale with the batch; the attention scratch does not
            assert!(pb.peak_activation_bytes()
                    < p1.peak_activation_bytes() * 4);
            let mut rng = Rng::seed_from(11);
            let xs: Vec<Tensor> = (0..4)
                .map(|_| Tensor::random(1, 8, 16, &mut rng))
                .collect();
            let mut packed = Vec::new();
            for t in &xs {
                packed.extend_from_slice(&t.data);
            }
            let mut arena_b = Arena::for_pipeline(&pb);
            let mut scratch = ExecScratch::default();
            let outs = pb.execute_batched(4, &packed, &mut arena_b,
                                          &mut scratch, 2);
            let mut arena_1 = Arena::for_pipeline(&p1);
            for (x, got) in xs.iter().zip(&outs) {
                // different thread count on purpose: sequence kernels
                // are bit-identical across thread counts
                let want = p1.execute(x, &mut arena_1, &mut scratch, 1);
                assert_eq!(want.data, got.data,
                           "{scheme:?} fused batch diverged");
            }
        }
    }

    #[test]
    fn empty_pipeline_returns_input() {
        let ir = crate::ir::ModelIR {
            name: "empty".into(),
            input: Chw::new(2, 3, 3),
            layers: Vec::new(),
        };
        let plan = build_plan(&ir, Scheme::DenseIm2col,
                              PruneConfig::default(), 1);
        let p = lower(&plan);
        let mut arena = Arena::for_pipeline(&p);
        let mut scratch = ExecScratch::default();
        let mut rng = Rng::seed_from(1);
        let x = Tensor::random(2, 3, 3, &mut rng);
        let y = p.execute(&x, &mut arena, &mut scratch, 1);
        assert_eq!(x.data, y.data);
    }
}
