//! Static plan verification: prove a [`CompiledPipeline`] safe to run
//! before it ever executes.
//!
//! Pattern row maps, CSR indices, int8 group scales, packed GEMM
//! panels, and arena slot reuse are all compiler-fabricated metadata
//! that the kernels (including the unsafe SIMD microkernels in
//! `exec::micro`) consume without whole-plan checks — one bad index is
//! silent memory corruption, not a typed error. This pass runs once at
//! `ExecPlan::compile()` / `Deployment` registration and proves,
//! without executing anything:
//!
//! * **Dataflow** — every op reads its predecessor's output (op 0 the
//!   model input), shapes and model families agree along the chain,
//!   and each kernel's output geometry matches the engine's actual
//!   SAME-padding / pooling arithmetic.
//! * **Arena non-aliasing** — liveness is re-derived from the ops
//!   alone (not trusted from the plan): no two simultaneously-live
//!   values share a slot, every op writes only slots whose tenant is
//!   dead (which is exactly the out-of-place guarantee
//!   `CompiledPipeline::execute` relies on when it `mem::take`s the
//!   destination buffer), every slot is large enough for its tenants,
//!   and `peak_activation_bytes()` equals the independently verified
//!   arena size.
//! * **Metadata bounds** — CSR column indices < `cin*kh*kw`, FKW
//!   filter orders are permutations and offsets monotone, pattern row
//!   maps land inside the packed U panel, `PackedA` panels match the
//!   GEMM they feed (the `gemm_packed` seam), quant group sizes divide
//!   weight counts with finite/nonzero scales, and every f32 weight
//!   array is NaN/Inf-free.
//! * **Scheme legality** — the scheme×kernel matrix implied by
//!   `build_plan` + `autotune_engines` (e.g. quant kernels only under
//!   `CocoGenQuant`/`CocoAuto`; the FC head is structurally f32).
//!
//! Violations return a typed [`VerifyError`] naming the op, slot, and
//! invariant. `Deployment::builder` refuses to register an invalid
//! plan; `ExecPlan::compile` panics with the rendered error; the
//! `verify` CLI subcommand checks any scheme×model combo. The proven
//! bounds back the `// SAFETY:` contracts at the kernel seams, whose
//! `debug_assert!` twins stay as in-kernel tripwires.

use std::fmt;

use crate::compress::{CsrLayer, DenseLayer, FkwKernel, FkwLayer,
                      ProjStore};
use crate::exec::micro;
use crate::exec::pattern::PatternGemmPlan;
use crate::exec::tensor::same_pad;
use crate::exec::winograd::WinogradWeights;
use crate::ir::{Chw, Family};
use crate::patterns::PATTERN_SET_4;
use crate::quant::{QuantDense, QuantFkw};

use super::lower::{BufId, CompiledKernel, CompiledOp,
                   CompiledPipeline};
use super::Scheme;

/// A statically detected plan violation. Every variant names the op
/// (pipeline index), the slot where one is involved, and the invariant
/// that failed, so the error alone locates the corruption.
#[derive(Debug, Clone, PartialEq)]
pub enum VerifyError {
    /// An op's `src` is not its predecessor's `dst` (op 0 must read
    /// the model input).
    BrokenChain {
        op: usize,
        kernel: &'static str,
        got: BufId,
        expected: BufId,
    },
    /// A shape-valued invariant failed (chain shapes, kernel output
    /// geometry, skip operand shape).
    ShapeMismatch {
        op: usize,
        kernel: &'static str,
        invariant: &'static str,
        expected: Chw,
        got: Chw,
    },
    /// A scalar extent invariant failed (channel counts, weight/bias
    /// lengths, head divisibility, ...).
    ExtentMismatch {
        op: usize,
        kernel: &'static str,
        invariant: &'static str,
        expected: usize,
        got: usize,
    },
    /// Spatial/sequence family disagreement along the chain or with a
    /// kernel's requirement.
    FamilyMismatch {
        op: usize,
        kernel: &'static str,
        invariant: &'static str,
    },
    /// An `Add` op without a skip slot operand.
    MissingSkipOperand { op: usize },
    /// A non-`Add` op carrying a skip operand.
    UnexpectedSkipOperand { op: usize, kernel: &'static str },
    /// An op references a slot the arena does not have.
    SlotOutOfRange { op: usize, slot: usize, slots: usize },
    /// An op reads a slot no earlier op has written.
    ReadBeforeWrite { op: usize, slot: usize },
    /// An op writes a slot whose current tenant (produced by
    /// `producer`, live through `live_until`) is still live — two
    /// simultaneously-live values would share memory.
    SlotAliasesLiveValue {
        op: usize,
        slot: usize,
        producer: usize,
        live_until: usize,
    },
    /// A slot's planned capacity is below what its tenants need.
    SlotTooSmall {
        slot: usize,
        need_elems: usize,
        have_elems: usize,
    },
    /// The shared sequence scratch is smaller than attention needs.
    ScratchTooSmall { need_elems: usize, have_elems: usize },
    /// `peak_activation_bytes()` disagrees with the independently
    /// re-derived arena footprint.
    ArenaSizeMismatch {
        verified_bytes: usize,
        reported_bytes: usize,
    },
    /// CSR row pointers / value arrays are structurally inconsistent.
    CsrStructureCorrupt { op: usize, detail: &'static str },
    /// A CSR column index escapes the layer's `cin*kh*kw` extent.
    CsrColOutOfBounds {
        op: usize,
        row: usize,
        entry: usize,
        col: u32,
        extent: usize,
    },
    /// FKW filter order / offsets / kernel entries are structurally
    /// inconsistent (`index` points at the offending entry).
    PatternStructureCorrupt {
        op: usize,
        invariant: &'static str,
        index: usize,
    },
    /// A pattern tap maps outside the packed U panel (`u32::MAX`
    /// means the tap is unmapped).
    PatternRowMapOutOfBounds {
        op: usize,
        entry: usize,
        tap: usize,
        row: u32,
        n_rows: usize,
    },
    /// A compile-time `PackedA` panel disagrees with the GEMM it
    /// feeds (the `gemm_packed` seam).
    PackedPanelMismatch {
        op: usize,
        invariant: &'static str,
        expected: usize,
        got: usize,
    },
    /// Int8 weight counts do not divide into per-channel quant groups
    /// (or the scale count disagrees with the channel count).
    QuantGroupMismatch {
        op: usize,
        invariant: &'static str,
        expected: usize,
        got: usize,
    },
    /// A dequant scale is NaN, infinite, or zero.
    QuantScaleInvalid { op: usize, channel: usize, value: f32 },
    /// A NaN/Inf in an f32 weight array.
    NonFiniteWeight {
        op: usize,
        kernel: &'static str,
        array: &'static str,
        index: usize,
    },
    /// A kernel the scheme's compression pipeline cannot have
    /// produced (e.g. an int8 kernel under a dense scheme).
    IllegalKernel {
        op: usize,
        kernel: &'static str,
        scheme: Scheme,
    },
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use VerifyError as E;
        match self {
            E::BrokenChain { op, kernel, got, expected } => write!(
                f,
                "op {op} ({kernel}): reads {got:?} but the chain \
                 expects {expected:?}"
            ),
            E::ShapeMismatch { op, kernel, invariant, expected, got } => {
                write!(
                    f,
                    "op {op} ({kernel}): {invariant}: expected \
                     {expected:?}, got {got:?}"
                )
            }
            E::ExtentMismatch {
                op, kernel, invariant, expected, got,
            } => write!(
                f,
                "op {op} ({kernel}): {invariant}: expected \
                 {expected}, got {got}"
            ),
            E::FamilyMismatch { op, kernel, invariant } => {
                write!(f, "op {op} ({kernel}): {invariant}")
            }
            E::MissingSkipOperand { op } => {
                write!(f, "op {op} (add): missing skip slot operand")
            }
            E::UnexpectedSkipOperand { op, kernel } => write!(
                f,
                "op {op} ({kernel}): unexpected skip operand on a \
                 non-add kernel"
            ),
            E::SlotOutOfRange { op, slot, slots } => write!(
                f,
                "op {op}: references slot {slot} but the arena has \
                 {slots} slot(s)"
            ),
            E::ReadBeforeWrite { op, slot } => write!(
                f,
                "op {op}: reads slot {slot} before any op wrote it"
            ),
            E::SlotAliasesLiveValue {
                op, slot, producer, live_until,
            } => write!(
                f,
                "op {op}: writes slot {slot} while op {producer}'s \
                 value is still live (until op {live_until}) — \
                 simultaneously-live values would alias"
            ),
            E::SlotTooSmall { slot, need_elems, have_elems } => {
                write!(
                    f,
                    "slot {slot}: tenants need {need_elems} elems \
                     but the plan sized it {have_elems}"
                )
            }
            E::ScratchTooSmall { need_elems, have_elems } => write!(
                f,
                "sequence scratch: attention needs {need_elems} \
                 elems but the plan sized it {have_elems}"
            ),
            E::ArenaSizeMismatch { verified_bytes, reported_bytes } => {
                write!(
                    f,
                    "arena: verified footprint {verified_bytes} B != \
                     reported peak_activation_bytes {reported_bytes} B"
                )
            }
            E::CsrStructureCorrupt { op, detail } => {
                write!(f, "op {op} (csr): {detail}")
            }
            E::CsrColOutOfBounds { op, row, entry, col, extent } => {
                write!(
                    f,
                    "op {op} (csr): row {row} entry {entry} column \
                     {col} escapes input extent {extent}"
                )
            }
            E::PatternStructureCorrupt { op, invariant, index } => {
                write!(
                    f,
                    "op {op} (pattern): {invariant} (entry {index})"
                )
            }
            E::PatternRowMapOutOfBounds {
                op, entry, tap, row, n_rows,
            } => write!(
                f,
                "op {op} (pattern-gemm): kernel entry {entry} tap \
                 {tap} maps to U row {row} outside the {n_rows}-row \
                 packed panel"
            ),
            E::PackedPanelMismatch {
                op, invariant, expected, got,
            } => write!(
                f,
                "op {op} (im2col-packed): {invariant}: expected \
                 {expected}, got {got}"
            ),
            E::QuantGroupMismatch {
                op, invariant, expected, got,
            } => write!(
                f,
                "op {op} (quant): {invariant}: expected {expected}, \
                 got {got}"
            ),
            E::QuantScaleInvalid { op, channel, value } => write!(
                f,
                "op {op} (quant): scale for channel {channel} is \
                 {value} (must be finite and nonzero)"
            ),
            E::NonFiniteWeight { op, kernel, array, index } => write!(
                f,
                "op {op} ({kernel}): non-finite value in {array} at \
                 index {index}"
            ),
            E::IllegalKernel { op, kernel, scheme } => write!(
                f,
                "op {op}: kernel {kernel} is not producible by \
                 scheme {}",
                scheme.label()
            ),
        }
    }
}

impl std::error::Error for VerifyError {}

/// Stable label for a compiled kernel, used in error messages and the
/// `verify` CLI report.
pub fn kernel_label(kernel: &CompiledKernel) -> &'static str {
    use CompiledKernel as K;
    match kernel {
        K::ConvNaive { .. } => "conv-naive",
        K::ConvIm2col { .. } => "conv-im2col",
        K::ConvIm2colPacked { .. } => "conv-im2col-packed",
        K::ConvWinograd { .. } => "conv-winograd",
        K::ConvCsr { .. } => "conv-csr",
        K::ConvPattern { .. } => "conv-pattern",
        K::ConvPatternGemm { .. } => "conv-pattern-gemm",
        K::ConvQuantDense { .. } => "conv-quant-dense",
        K::ConvQuantPattern { .. } => "conv-quant-pattern",
        K::ConvQuantPatternGemm { .. } => "conv-quant-pattern-gemm",
        K::Depthwise { .. } => "depthwise",
        K::MaxPool2 => "maxpool2",
        K::GlobalAvgPool => "gap",
        K::Fc { .. } => "fc",
        K::Add { .. } => "add",
        K::SeqMatMul { .. } => "seq-matmul",
        K::SeqNorm { .. } => "seq-norm",
        K::SeqAttn { .. } => "seq-attn",
        K::SeqPool => "seq-pool",
    }
}

/// Statically verify a compiled pipeline against `scheme`.
///
/// Checks run in severity order per op — slot ranges, dataflow,
/// kernel metadata/bounds, scheme legality — then the whole-pipeline
/// arena liveness proof. The first violation is returned.
pub fn verify_pipeline(p: &CompiledPipeline, scheme: Scheme)
                       -> Result<(), VerifyError> {
    let n_slots = p.mem.slot_elems.len();
    for (i, op) in p.ops.iter().enumerate() {
        check_slots(i, op, n_slots)?;
        check_dataflow(i, op, p)?;
        let cx = Ctx {
            op: i,
            kernel: kernel_label(&op.kernel),
        };
        check_kernel(cx, op)?;
        check_legality(i, op, scheme)?;
    }
    check_arena(p)
}

/// Error-construction context: which op a helper is checking.
#[derive(Clone, Copy)]
struct Ctx {
    op: usize,
    kernel: &'static str,
}

impl Ctx {
    fn extent(self, invariant: &'static str, expected: usize,
              got: usize) -> VerifyError {
        VerifyError::ExtentMismatch {
            op: self.op,
            kernel: self.kernel,
            invariant,
            expected,
            got,
        }
    }

    fn shape(self, invariant: &'static str, expected: Chw, got: Chw)
             -> VerifyError {
        VerifyError::ShapeMismatch {
            op: self.op,
            kernel: self.kernel,
            invariant,
            expected,
            got,
        }
    }

    fn family(self, invariant: &'static str) -> VerifyError {
        VerifyError::FamilyMismatch {
            op: self.op,
            kernel: self.kernel,
            invariant,
        }
    }
}

fn check_slots(i: usize, op: &CompiledOp, n_slots: usize)
               -> Result<(), VerifyError> {
    let mut refs = vec![op.dst];
    if let BufId::Slot(s) = op.src {
        refs.push(s);
    }
    if let Some(BufId::Slot(s)) = op.src2 {
        refs.push(s);
    }
    for slot in refs {
        if slot >= n_slots {
            return Err(VerifyError::SlotOutOfRange {
                op: i,
                slot,
                slots: n_slots,
            });
        }
    }
    Ok(())
}

fn check_dataflow(i: usize, op: &CompiledOp, p: &CompiledPipeline)
                  -> Result<(), VerifyError> {
    let kernel = kernel_label(&op.kernel);
    let cx = Ctx { op: i, kernel };
    let (expected_src, want_in) = if i == 0 {
        (BufId::Input, p.input)
    } else {
        let prev = &p.ops[i - 1];
        (BufId::Slot(prev.dst), prev.out_shape)
    };
    if op.src != expected_src {
        return Err(VerifyError::BrokenChain {
            op: i,
            kernel,
            got: op.src,
            expected: expected_src,
        });
    }
    if op.in_shape != want_in {
        return Err(cx.shape("in_shape vs producer out_shape",
                            want_in, op.in_shape));
    }
    if op.in_shape.family() != want_in.family() {
        return Err(cx.family("in_shape family vs producer family"));
    }
    match (&op.kernel, op.src2) {
        (CompiledKernel::Add { .. }, Some(BufId::Slot(s))) => {
            // The skip operand reads its slot's *current* tenant: the
            // most recent writer before this op.
            let Some(j) = (0..i).rev().find(|&j| p.ops[j].dst == s)
            else {
                return Err(VerifyError::ReadBeforeWrite {
                    op: i,
                    slot: s,
                });
            };
            if p.ops[j].out_shape != op.in_shape {
                return Err(cx.shape("skip operand shape",
                                    op.in_shape,
                                    p.ops[j].out_shape));
            }
            Ok(())
        }
        (CompiledKernel::Add { .. }, _) => {
            Err(VerifyError::MissingSkipOperand { op: i })
        }
        (_, None) => Ok(()),
        (_, Some(_)) => Err(VerifyError::UnexpectedSkipOperand {
            op: i,
            kernel,
        }),
    }
}

/// SAME-padding conv output geometry — the exact arithmetic every
/// conv engine uses (`exec::tensor::same_pad`).
fn conv_out(i: Chw, cout: usize, kh: usize, kw: usize,
            stride: usize) -> Chw {
    let (h, _) = same_pad(i.h, kh, stride);
    let (w, _) = same_pad(i.w, kw, stride);
    Chw::new(cout, h, w)
}

fn check_conv_geom(cx: Ctx, i: Chw, o: Chw,
                   (cout, cin, kh, kw): (usize, usize, usize, usize),
                   stride: usize) -> Result<(), VerifyError> {
    if i.family() != Family::Spatial
        || o.family() != Family::Spatial
    {
        return Err(cx.family(
            "conv kernels require spatial activations",
        ));
    }
    if stride == 0 {
        return Err(cx.extent("conv stride must be nonzero", 1, 0));
    }
    if i.c != cin {
        return Err(cx.extent("input channels vs cin", cin, i.c));
    }
    let want = conv_out(i, cout, kh, kw, stride);
    if o != want {
        return Err(cx.shape("conv output geometry", want, o));
    }
    Ok(())
}

fn check_finite(cx: Ctx, array: &'static str, data: &[f32])
                -> Result<(), VerifyError> {
    match data.iter().position(|v| !v.is_finite()) {
        Some(index) => Err(VerifyError::NonFiniteWeight {
            op: cx.op,
            kernel: cx.kernel,
            array,
            index,
        }),
        None => Ok(()),
    }
}

fn check_bias(cx: Ctx, bias: &[f32], cout: usize)
              -> Result<(), VerifyError> {
    if bias.len() != cout {
        return Err(cx.extent("bias length vs cout", cout,
                             bias.len()));
    }
    check_finite(cx, "bias", bias)
}

fn check_dense_conv(cx: Ctx, w: &DenseLayer, i: Chw, o: Chw,
                    stride: usize) -> Result<(), VerifyError> {
    check_conv_geom(cx, i, o, (w.cout, w.cin, w.kh, w.kw), stride)?;
    let want = w.cout * w.cin * w.kh * w.kw;
    if w.weights.len() != want {
        return Err(cx.extent("dense weight count", want,
                             w.weights.len()));
    }
    check_finite(cx, "weights", &w.weights)?;
    check_bias(cx, &w.bias, w.cout)
}

/// The `gemm_packed` seam: a compile-time `PackedA` panel must match
/// the layer it will multiply — M = cout rows, K = cin*kh*kw depth,
/// and a buffer of exactly `ceil(M/MR)*MR*K` zero-padded elements.
/// This is the release-mode promotion of the `debug_assert!` at the
/// `exec::im2col::conv2d_packed_into` seam.
fn check_packed_panel(cx: Ctx, w: &DenseLayer, pack: &micro::PackedA)
                      -> Result<(), VerifyError> {
    let mismatch = |invariant, expected, got| {
        VerifyError::PackedPanelMismatch {
            op: cx.op,
            invariant,
            expected,
            got,
        }
    };
    let kdim = w.cin * w.kh * w.kw;
    if pack.m != w.cout {
        return Err(mismatch("panel rows (m) vs cout", w.cout,
                            pack.m));
    }
    if pack.k != kdim {
        return Err(mismatch("panel depth (k) vs cin*kh*kw", kdim,
                            pack.k));
    }
    let want = pack.m.div_ceil(micro::MR) * micro::MR * pack.k;
    if pack.buf().len() != want {
        return Err(mismatch("panel buffer length", want,
                            pack.buf().len()));
    }
    check_finite(cx, "packed panel", pack.buf())
}

fn check_csr(cx: Ctx, c: &CsrLayer) -> Result<(), VerifyError> {
    let corrupt = |detail| VerifyError::CsrStructureCorrupt {
        op: cx.op,
        detail,
    };
    let nnz = c.col_idx.len();
    if c.row_ptr.len() != c.cout + 1 {
        return Err(corrupt("row_ptr length != cout + 1"));
    }
    if c.row_ptr.first() != Some(&0) {
        return Err(corrupt("row_ptr does not start at 0"));
    }
    if c.row_ptr.windows(2).any(|w| w[0] > w[1]) {
        return Err(corrupt("row_ptr not monotone"));
    }
    if c.row_ptr.last().copied() != Some(nnz as u32) {
        return Err(corrupt("row_ptr end != nnz"));
    }
    if c.values.len() != nnz {
        return Err(corrupt("values/col_idx length mismatch"));
    }
    let extent = c.cin * c.kh * c.kw;
    for (row, w) in c.row_ptr.windows(2).enumerate() {
        for entry in w[0] as usize..w[1] as usize {
            let col = c.col_idx[entry];
            if col as usize >= extent {
                return Err(VerifyError::CsrColOutOfBounds {
                    op: cx.op,
                    row,
                    entry,
                    col,
                    extent,
                });
            }
        }
    }
    check_finite(cx, "values", &c.values)?;
    check_bias(cx, &c.bias, c.cout)
}

/// The structural fields shared by `FkwLayer` and `QuantFkw`.
struct FkwParts<'a> {
    cout: usize,
    cin: usize,
    filter_order: &'a [u32],
    offsets: &'a [u32],
    kernels: &'a [FkwKernel],
    weights_len: usize,
}

fn check_fkw_structure(cx: Ctx, p: &FkwParts<'_>)
                       -> Result<(), VerifyError> {
    let bad = |invariant, index| {
        VerifyError::PatternStructureCorrupt {
            op: cx.op,
            invariant,
            index,
        }
    };
    if p.filter_order.len() != p.cout {
        return Err(bad("filter_order length != cout",
                       p.filter_order.len()));
    }
    let mut seen = vec![false; p.cout];
    for (i, &fo) in p.filter_order.iter().enumerate() {
        let fo = fo as usize;
        if fo >= p.cout || seen[fo] {
            return Err(bad("filter_order is not a permutation", i));
        }
        seen[fo] = true;
    }
    if p.offsets.len() != p.cout + 1 {
        return Err(bad("offsets length != cout + 1",
                       p.offsets.len()));
    }
    if p.offsets.first() != Some(&0) {
        return Err(bad("offsets do not start at 0", 0));
    }
    if let Some(i) = p.offsets.windows(2).position(|w| w[0] > w[1]) {
        return Err(bad("offsets not monotone", i));
    }
    if p.offsets.last().copied() != Some(p.kernels.len() as u32) {
        return Err(bad("offsets end != kernel count", p.cout));
    }
    for (e, k) in p.kernels.iter().enumerate() {
        if (k.ci as usize) >= p.cin {
            return Err(bad("kernel input channel out of range", e));
        }
        if (k.pattern as usize) >= PATTERN_SET_4.len() {
            return Err(bad("pattern id out of range", e));
        }
    }
    if p.weights_len != 4 * p.kernels.len() {
        return Err(bad("weights != 4 per surviving kernel",
                       p.weights_len));
    }
    Ok(())
}

fn check_fkw(cx: Ctx, w: &FkwLayer, i: Chw, o: Chw, stride: usize)
             -> Result<(), VerifyError> {
    check_conv_geom(cx, i, o, (w.cout, w.cin, 3, 3), stride)?;
    check_fkw_structure(cx, &FkwParts {
        cout: w.cout,
        cin: w.cin,
        filter_order: &w.filter_order,
        offsets: &w.offsets,
        kernels: &w.kernels,
        weights_len: w.weights.len(),
    })?;
    check_finite(cx, "weights", &w.weights)?;
    check_bias(cx, &w.bias, w.cout)
}

fn check_scales(cx: Ctx, scales: &[f32]) -> Result<(), VerifyError> {
    for (channel, &value) in scales.iter().enumerate() {
        if !value.is_finite() || value == 0.0 {
            return Err(VerifyError::QuantScaleInvalid {
                op: cx.op,
                channel,
                value,
            });
        }
    }
    Ok(())
}

fn check_quant_fkw(cx: Ctx, w: &QuantFkw, i: Chw, o: Chw,
                   stride: usize) -> Result<(), VerifyError> {
    check_conv_geom(cx, i, o, (w.cout, w.cin, 3, 3), stride)?;
    check_fkw_structure(cx, &FkwParts {
        cout: w.cout,
        cin: w.cin,
        filter_order: &w.filter_order,
        offsets: &w.offsets,
        kernels: &w.kernels,
        weights_len: w.weights_q.len(),
    })?;
    if w.scales.len() != w.cout {
        return Err(VerifyError::QuantGroupMismatch {
            op: cx.op,
            invariant: "scale count vs out channels",
            expected: w.cout,
            got: w.scales.len(),
        });
    }
    check_scales(cx, &w.scales)?;
    check_bias(cx, &w.bias, w.cout)
}

fn check_quant_dense(cx: Ctx, q: &QuantDense)
                     -> Result<(), VerifyError> {
    let group = q.cin * q.kh * q.kw;
    if q.weights.len() != q.cout * group {
        return Err(VerifyError::QuantGroupMismatch {
            op: cx.op,
            invariant: "int8 weights vs cout * group size",
            expected: q.cout * group,
            got: q.weights.len(),
        });
    }
    if q.scales.len() != q.cout {
        return Err(VerifyError::QuantGroupMismatch {
            op: cx.op,
            invariant: "scale count vs out channels",
            expected: q.cout,
            got: q.scales.len(),
        });
    }
    check_scales(cx, &q.scales)?;
    check_bias(cx, &q.bias, q.cout)
}

/// Every tap of every surviving kernel must map to a live row of the
/// packed U panel — the bound `build_u_matrix`/`filter_gemm` index
/// with. Requires `check_fkw_structure` to have validated `ci` and
/// pattern ids first.
fn check_row_map(cx: Ctx, gp: &PatternGemmPlan, cin: usize,
                 kernels: &[FkwKernel]) -> Result<(), VerifyError> {
    let map = gp.row_map();
    if map.len() != cin * 9 {
        return Err(VerifyError::PatternStructureCorrupt {
            op: cx.op,
            invariant: "row map length != cin * 9",
            index: map.len(),
        });
    }
    let n_rows = gp.n_rows();
    for (entry, k) in kernels.iter().enumerate() {
        let taps = &PATTERN_SET_4[k.pattern as usize];
        for (tap, &(dy, dx)) in taps.iter().enumerate() {
            let row = map[k.ci as usize * 9 + dy * 3 + dx];
            if row == u32::MAX || row as usize >= n_rows {
                return Err(VerifyError::PatternRowMapOutOfBounds {
                    op: cx.op,
                    entry,
                    tap,
                    row,
                    n_rows,
                });
            }
        }
    }
    Ok(())
}

/// Validate one sequence projection store against its input width;
/// returns the store's output width.
fn check_proj(cx: Ctx, store: &ProjStore, d_in: usize)
              -> Result<usize, VerifyError> {
    match store {
        ProjStore::Dense(w) => {
            let d_out = w.bias.len();
            if w.weights.len() != d_in * d_out {
                return Err(cx.extent(
                    "projection weights vs d_in * d_out",
                    d_in * d_out,
                    w.weights.len(),
                ));
            }
            check_finite(cx, "weights", &w.weights)?;
            check_finite(cx, "bias", &w.bias)?;
            Ok(d_out)
        }
        ProjStore::Csr(c) => {
            if c.kh * c.kw != 1 {
                return Err(cx.extent("projection CSR kernel extent",
                                     1, c.kh * c.kw));
            }
            if c.cin != d_in {
                return Err(cx.extent("projection CSR cin vs d_in",
                                     d_in, c.cin));
            }
            check_csr(cx, c)?;
            Ok(c.cout)
        }
        ProjStore::Int8(q) => {
            if q.kh * q.kw != 1 {
                return Err(cx.extent(
                    "projection int8 kernel extent",
                    1,
                    q.kh * q.kw,
                ));
            }
            if q.cin != d_in {
                return Err(cx.extent("projection int8 cin vs d_in",
                                     d_in, q.cin));
            }
            check_quant_dense(cx, q)?;
            Ok(q.cout)
        }
    }
}

fn check_seq_families(cx: Ctx, i: Chw, o: Chw)
                      -> Result<(), VerifyError> {
    if i.family() != Family::Sequence
        || o.family() != Family::Sequence
    {
        return Err(cx.family(
            "sequence kernels require [T, D] activations",
        ));
    }
    Ok(())
}

fn check_kernel(cx: Ctx, op: &CompiledOp) -> Result<(), VerifyError> {
    use CompiledKernel as K;
    let (i, o) = (op.in_shape, op.out_shape);
    match &op.kernel {
        K::ConvNaive { w, stride, .. }
        | K::ConvIm2col { w, stride, .. } => {
            check_dense_conv(cx, w, i, o, *stride)
        }
        K::ConvIm2colPacked { w, pack, stride, .. } => {
            check_dense_conv(cx, w, i, o, *stride)?;
            check_packed_panel(cx, w, pack)
        }
        K::ConvWinograd { w, .. } => {
            // Winograd F(2,3) is 3x3 stride-1 only; the transform
            // bakes the stride in.
            check_conv_geom(cx, i, o, (w.cout, w.cin, 3, 3), 1)?;
            check_winograd(cx, w)
        }
        K::ConvCsr { w, stride, .. } => {
            check_conv_geom(cx, i, o, (w.cout, w.cin, w.kh, w.kw),
                            *stride)?;
            check_csr(cx, w)
        }
        K::ConvPattern { w, stride, .. } => {
            check_fkw(cx, w, i, o, *stride)
        }
        K::ConvPatternGemm { w, stride, gp, .. } => {
            check_fkw(cx, w, i, o, *stride)?;
            check_row_map(cx, gp, w.cin, &w.kernels)
        }
        K::ConvQuantDense { w, stride, .. } => {
            check_conv_geom(cx, i, o, (w.cout, w.cin, w.kh, w.kw),
                            *stride)?;
            check_quant_dense(cx, w)
        }
        K::ConvQuantPattern { w, stride, .. } => {
            check_quant_fkw(cx, w, i, o, *stride)
        }
        K::ConvQuantPatternGemm { w, stride, gp, .. } => {
            check_quant_fkw(cx, w, i, o, *stride)?;
            check_row_map(cx, gp, w.cin, &w.kernels)
        }
        K::Depthwise { w, stride, .. } => {
            check_conv_geom(cx, i, o, (i.c, i.c, 3, 3), *stride)?;
            if w.weights.len() != 9 * i.c {
                return Err(cx.extent(
                    "depthwise weights vs 9 * channels",
                    9 * i.c,
                    w.weights.len(),
                ));
            }
            check_finite(cx, "weights", &w.weights)?;
            check_bias(cx, &w.bias, i.c)
        }
        K::MaxPool2 => {
            let want =
                Chw::new(i.c, i.h.div_ceil(2), i.w.div_ceil(2));
            if i.family() != Family::Spatial {
                return Err(cx.family(
                    "maxpool requires spatial activations",
                ));
            }
            if o != want {
                return Err(cx.shape("maxpool output geometry",
                                    want, o));
            }
            Ok(())
        }
        K::GlobalAvgPool => {
            if i.family() != Family::Spatial {
                return Err(cx.family(
                    "gap requires spatial activations",
                ));
            }
            let want = Chw::new(i.c, 1, 1);
            if o != want {
                return Err(cx.shape("gap output geometry", want, o));
            }
            Ok(())
        }
        K::Fc { w, .. } => {
            let cout = w.bias.len();
            let want = i.elements() * cout;
            if w.weights.len() != want {
                return Err(cx.extent("fc weights vs in_elems * cout",
                                     want, w.weights.len()));
            }
            let want_o = Chw::new(cout, 1, 1);
            if o != want_o {
                return Err(cx.shape("fc output geometry", want_o, o));
            }
            check_finite(cx, "weights", &w.weights)?;
            check_finite(cx, "bias", &w.bias)
        }
        K::Add { .. } => {
            if o != i {
                return Err(cx.shape("add preserves shape", i, o));
            }
            Ok(())
        }
        K::SeqMatMul { w, .. } => {
            check_seq_families(cx, i, o)?;
            let d_out = check_proj(cx, w, i.d())?;
            if o.t() != i.t() {
                return Err(cx.extent("token count preserved", i.t(),
                                     o.t()));
            }
            if o.d() != d_out {
                return Err(cx.extent(
                    "projection width vs store d_out",
                    d_out,
                    o.d(),
                ));
            }
            Ok(())
        }
        K::SeqNorm { w } => {
            check_seq_families(cx, i, o)?;
            if o != i {
                return Err(cx.shape("layernorm preserves shape", i,
                                    o));
            }
            if w.weights.len() != i.d() {
                return Err(cx.extent("gamma length vs width", i.d(),
                                     w.weights.len()));
            }
            if w.bias.len() != i.d() {
                return Err(cx.extent("beta length vs width", i.d(),
                                     w.bias.len()));
            }
            check_finite(cx, "gamma", &w.weights)?;
            check_finite(cx, "beta", &w.bias)
        }
        K::SeqAttn { w, heads } => {
            check_seq_families(cx, i, o)?;
            if o != i {
                return Err(cx.shape("attention preserves shape", i,
                                    o));
            }
            if *heads == 0 {
                return Err(cx.extent("attention heads nonzero", 1,
                                     0));
            }
            if i.d() % heads != 0 {
                return Err(cx.extent("width divisible by heads", 0,
                                     i.d() % heads));
            }
            for store in w.stores() {
                let d_out = check_proj(cx, store, i.d())?;
                if d_out != i.d() {
                    return Err(cx.extent(
                        "attention projection is square",
                        i.d(),
                        d_out,
                    ));
                }
            }
            Ok(())
        }
        K::SeqPool => {
            if i.family() != Family::Sequence
                || o.family() != Family::Spatial
            {
                return Err(cx.family(
                    "seqpool bridges sequence to spatial",
                ));
            }
            let want = Chw::new(i.d(), 1, 1);
            if o != want {
                return Err(cx.shape("seqpool output geometry", want,
                                    o));
            }
            Ok(())
        }
    }
}

fn check_winograd(cx: Ctx, w: &WinogradWeights)
                  -> Result<(), VerifyError> {
    let want = 16 * w.cout * w.cin;
    if w.v.len() != want {
        return Err(cx.extent("winograd V vs 16 * cout * cin", want,
                             w.v.len()));
    }
    check_finite(cx, "winograd V", &w.v)?;
    check_bias(cx, &w.bias, w.cout)
}

/// The scheme×kernel legality matrix implied by `build_plan` and the
/// `CocoAuto` engine sweep (`autotune_engines`). `ConvIm2col` is the
/// universal dense fallback (non-3x3 layers keep it under every
/// scheme); quant kernels exist only under `CocoGenQuant` or a
/// `CocoAuto` sweep that measured them faster; `Fc`/`SeqNorm` are
/// structurally f32 under every scheme (quant never touches the FC
/// head — there is no quant variant to produce).
fn scheme_allows(scheme: Scheme, kernel: &CompiledKernel) -> bool {
    use CompiledKernel as K;
    use Scheme as S;
    match kernel {
        K::ConvNaive { .. } => {
            matches!(scheme, S::DenseNaive | S::CocoAuto)
        }
        K::ConvIm2col { .. } => true,
        K::ConvIm2colPacked { .. } => matches!(scheme, S::CocoAuto),
        K::ConvWinograd { .. } => {
            matches!(scheme, S::DenseWinograd | S::CocoAuto)
        }
        K::ConvCsr { .. } => matches!(scheme, S::SparseCsr),
        K::ConvPattern { .. } | K::ConvPatternGemm { .. } => {
            matches!(scheme, S::CocoGen | S::CocoAuto)
        }
        K::ConvQuantDense { .. }
        | K::ConvQuantPattern { .. }
        | K::ConvQuantPatternGemm { .. } => {
            matches!(scheme, S::CocoGenQuant | S::CocoAuto)
        }
        K::Depthwise { .. }
        | K::MaxPool2
        | K::GlobalAvgPool
        | K::Fc { .. }
        | K::Add { .. }
        | K::SeqNorm { .. }
        | K::SeqPool => true,
        K::SeqMatMul { w, .. } => proj_allowed(scheme, w, false),
        K::SeqAttn { w, .. } => w
            .stores()
            .iter()
            .all(|s| proj_allowed(scheme, s, true)),
    }
}

/// Projection-store legality. Attention stores are scheme-chosen (no
/// per-projection sweep), so `CocoAuto` attention never carries dense
/// or int8 stores; standalone projections get the engine sweep and
/// may carry any store under `CocoAuto`.
fn proj_allowed(scheme: Scheme, store: &ProjStore,
                attn: bool) -> bool {
    use Scheme as S;
    match (store, attn) {
        (ProjStore::Dense(_), false) => matches!(
            scheme,
            S::DenseNaive
                | S::DenseIm2col
                | S::DenseWinograd
                | S::CocoAuto
        ),
        (ProjStore::Dense(_), true) => matches!(
            scheme,
            S::DenseNaive | S::DenseIm2col | S::DenseWinograd
        ),
        (ProjStore::Csr(_), _) => matches!(
            scheme,
            S::SparseCsr | S::CocoGen | S::CocoAuto
        ),
        (ProjStore::Int8(_), false) => {
            matches!(scheme, S::CocoGenQuant | S::CocoAuto)
        }
        (ProjStore::Int8(_), true) => {
            matches!(scheme, S::CocoGenQuant)
        }
    }
}

fn check_legality(i: usize, op: &CompiledOp, scheme: Scheme)
                  -> Result<(), VerifyError> {
    if scheme_allows(scheme, &op.kernel) {
        Ok(())
    } else {
        Err(VerifyError::IllegalKernel {
            op: i,
            kernel: kernel_label(&op.kernel),
            scheme,
        })
    }
}

fn reads_slot(op: &CompiledOp, s: usize) -> bool {
    op.src == BufId::Slot(s) || op.src2 == Some(BufId::Slot(s))
}

/// Re-derive liveness from the ops alone (never trusting
/// `mem.slot_of`) and prove the arena plan sound: no aliasing of
/// live values, every write out-of-place, capacities sufficient, and
/// the reported `peak_activation_bytes()` equal to the verified
/// footprint.
fn check_arena(p: &CompiledPipeline) -> Result<(), VerifyError> {
    let n = p.ops.len();
    let n_slots = p.mem.slot_elems.len();
    // Live range of each op's value: the last op reading its slot
    // before the slot is overwritten (`n` for the model output,
    // which the caller copies out after the walk).
    let mut live_until = vec![0usize; n];
    for (t, op) in p.ops.iter().enumerate() {
        let s = op.dst;
        let mut until = t;
        for (j, later) in p.ops.iter().enumerate().skip(t + 1) {
            if reads_slot(later, s) {
                until = j;
            }
            if later.dst == s {
                break;
            }
        }
        if t == n - 1 {
            until = n;
        }
        live_until[t] = until;
    }
    let mut writer: Vec<Option<usize>> = vec![None; n_slots];
    let mut need = vec![0usize; n_slots];
    for (i, op) in p.ops.iter().enumerate() {
        for src in [Some(op.src), op.src2].into_iter().flatten() {
            if let BufId::Slot(s) = src {
                if writer[s].is_none() {
                    return Err(VerifyError::ReadBeforeWrite {
                        op: i,
                        slot: s,
                    });
                }
            }
        }
        // A tenant read *by* this op has live_until >= i, so this
        // single check also proves every op is out-of-place — the
        // invariant `CompiledPipeline::execute` relies on when it
        // `mem::take`s the destination buffer.
        if let Some(t) = writer[op.dst] {
            if live_until[t] >= i {
                return Err(VerifyError::SlotAliasesLiveValue {
                    op: i,
                    slot: op.dst,
                    producer: t,
                    live_until: live_until[t],
                });
            }
        }
        writer[op.dst] = Some(i);
        let elems = op.out_shape.elements() * p.mem.batch;
        need[op.dst] = need[op.dst].max(elems);
    }
    for (slot, (&have, &want)) in
        p.mem.slot_elems.iter().zip(&need).enumerate()
    {
        if have < want {
            return Err(VerifyError::SlotTooSmall {
                slot,
                need_elems: want,
                have_elems: have,
            });
        }
    }
    // Sequence scratch: [heads, T, T] scores + Q/K/V/context rows,
    // shared (not batch-scaled — the batched kernel loops per image).
    let scratch = p
        .ops
        .iter()
        .map(|op| match op.kernel {
            CompiledKernel::SeqAttn { heads, .. } => {
                let (t, d) = (op.in_shape.t(), op.in_shape.d());
                4 * t * d + heads * t * t
            }
            _ => 0,
        })
        .max()
        .unwrap_or(0);
    if p.mem.scratch_elems < scratch {
        return Err(VerifyError::ScratchTooSmall {
            need_elems: scratch,
            have_elems: p.mem.scratch_elems,
        });
    }
    let verified = (need.iter().sum::<usize>() + scratch) * 4;
    let reported = p.peak_activation_bytes();
    if verified != reported {
        return Err(VerifyError::ArenaSizeMismatch {
            verified_bytes: verified,
            reported_bytes: reported,
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{build_plan, lower, lower_batched,
                         PruneConfig};
    use crate::ir::{Chw, IrBuilder, ModelIR, Shape};

    fn conv_ir() -> ModelIR {
        let mut b = IrBuilder::new("vres", Chw::new(3, 12, 12));
        b.conv("c1", 3, 8, 1, true);
        let skip = b.last();
        b.conv("c2", 3, 8, 1, false)
            .add("a", skip, true)
            .conv("p1", 1, 12, 1, true)
            .maxpool("mp")
            .gap("g")
            .dense("fc", 5, false);
        b.build().unwrap()
    }

    fn seq_ir() -> ModelIR {
        let mut b = IrBuilder::new("vseq", Shape::seq(8, 16));
        b.matmul("embed", 16, false);
        let skip = b.last();
        b.attention("attn", 2)
            .add("res", skip, false)
            .layernorm("ln")
            .seqpool("pool")
            .dense("cls", 4, false);
        b.build().unwrap()
    }

    #[test]
    fn accepts_every_scheme_on_both_families() {
        for ir in [conv_ir(), seq_ir()] {
            for scheme in Scheme::ALL {
                let plan = build_plan(&ir, scheme,
                                      PruneConfig::default(), 3);
                let single = lower(&plan);
                verify_pipeline(&single, scheme).unwrap_or_else(|e| {
                    panic!("{} / {}: {e}", ir.name, scheme.label())
                });
                let batched = lower_batched(&plan, 4);
                verify_pipeline(&batched, scheme).unwrap_or_else(
                    |e| {
                        panic!("{} / {} (batched): {e}", ir.name,
                               scheme.label())
                    },
                );
            }
        }
    }

    #[test]
    fn empty_pipeline_verifies() {
        let ir = ModelIR {
            name: "empty".into(),
            input: Chw::new(1, 1, 1),
            layers: Vec::new(),
        };
        let plan =
            build_plan(&ir, Scheme::DenseIm2col,
                       PruneConfig::default(), 1);
        verify_pipeline(&lower(&plan), Scheme::DenseIm2col).unwrap();
    }

    #[test]
    fn every_kernel_gets_a_label() {
        let plan = build_plan(&conv_ir(), Scheme::CocoGenQuant,
                              PruneConfig::default(), 3);
        for op in lower(&plan).ops {
            assert!(!kernel_label(&op.kernel).is_empty());
        }
    }

    #[test]
    fn errors_render_op_slot_and_invariant() {
        let e = VerifyError::SlotAliasesLiveValue {
            op: 3,
            slot: 1,
            producer: 0,
            live_until: 4,
        };
        let s = e.to_string();
        assert!(s.contains("op 3") && s.contains("slot 1"),
                "unhelpful message: {s}");
        let e = VerifyError::CsrColOutOfBounds {
            op: 2,
            row: 7,
            entry: 41,
            col: 99,
            extent: 72,
        };
        assert!(e.to_string().contains("72"));
    }

    #[test]
    fn quant_kernels_are_illegal_under_dense_schemes() {
        let plan = build_plan(&conv_ir(), Scheme::CocoGenQuant,
                              PruneConfig::default(), 3);
        let p = lower(&plan);
        let err =
            verify_pipeline(&p, Scheme::DenseIm2col).unwrap_err();
        assert!(matches!(err,
                         VerifyError::IllegalKernel { .. }),
                "{err}");
    }
}
