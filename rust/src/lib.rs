//! CoCoPIE reproduction: compression-compilation co-design for real-time
//! DNN inference, on a three-layer Rust + JAX + Pallas stack.
//!
//! See DESIGN.md for the paper -> module mapping and README.md for usage.

pub mod codegen;
pub mod coordinator;
pub mod compress;
pub mod exec;
pub mod hwsim;
pub mod ir;
pub mod patterns;
pub mod quant;
pub mod runtime;
pub mod util;

/// Library version.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

pub mod cocotune;
pub mod data;

/// One-import surface for the serving pipeline: build a model IR, turn
/// it into named [`prelude::Deployment`]s (scheme → prune/quant →
/// autotune → compiled backends), register them on a
/// [`prelude::Coordinator`], and submit typed
/// [`prelude::InferRequest`]s.
///
/// ```
/// use cocopie::ir::{Chw, IrBuilder};
/// use cocopie::prelude::*;
///
/// let mut b = IrBuilder::new("p", Chw::new(3, 8, 8));
/// b.conv("c1", 3, 4, 1, true).gap("g").dense("fc", 2, false);
/// let ir = b.build().unwrap();
/// let coord = Coordinator::builder()
///     .register(Deployment::builder("dense", &ir)
///         .scheme(Scheme::DenseIm2col)
///         .build()
///         .unwrap())
///     .register(Deployment::builder("cocogen", &ir)
///         .scheme(Scheme::CocoGen)
///         .build()
///         .unwrap())
///     .start()
///     .unwrap();
/// let rx = coord
///     .infer(InferRequest {
///         image: vec![0.1; 8 * 8 * 3],
///         sla: Sla::Realtime,
///         deployment: None,
///     })
///     .unwrap();
/// let pred = rx.recv().unwrap().unwrap();
/// assert!(coord.deployments().iter().any(|d| *d == pred.deployment));
/// coord.shutdown();
/// ```
pub mod prelude {
    pub use crate::codegen::{autotune_plan, autotune_plan_batched,
                             build_plan, ExecPlan, PruneConfig, Scheme};
    pub use crate::coordinator::{BatchPolicy, CanaryConfig,
                                 CanaryOutcome, Client, Coordinator,
                                 CoordinatorBuilder, Deployment,
                                 DeploymentBuilder, DeploymentId,
                                 InferRequest, Lifecycle,
                                 NativeBackend, NativeBatchMode,
                                 Prediction, PredictionResult,
                                 RetuneOutcome, Retuner, RetunerConfig,
                                 RouterPolicy, ServeConfig, ServeError,
                                 ServeReport, Sla, SlaPolicy,
                                 SlotState, Summary};
    pub use crate::exec::{ExecutorPool, ModelExecutor};
}
