//! CoCoPIE reproduction: compression-compilation co-design for real-time
//! DNN inference, on a three-layer Rust + JAX + Pallas stack.
//!
//! See DESIGN.md for the paper -> module mapping and README.md for usage.

pub mod codegen;
pub mod coordinator;
pub mod compress;
pub mod exec;
pub mod hwsim;
pub mod ir;
pub mod patterns;
pub mod quant;
pub mod runtime;
pub mod util;

/// Library version.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

pub mod cocotune;
pub mod data;
