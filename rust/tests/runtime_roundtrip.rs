//! Integration: AOT HLO-text artifacts load, compile and execute through
//! PJRT with correct numerics. This is the L1/L2 -> L3 seam test.
//!
//! Offline (vendored xla stub, or no artifacts/ dir) every test here
//! skips: the `runtime()` helper reports why and returns None.

use cocopie::runtime::{HostTensor, Runtime};

fn runtime() -> Option<Runtime> {
    match Runtime::new(&Runtime::default_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!(
                "skipping PJRT roundtrip test: {e:#} \
                 (generate artifacts with python/compile/aot.py and use \
                 the real xla bindings)"
            );
            None
        }
    }
}

#[test]
fn gemm_micro_artifact_matches_host_matmul() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_micro("gemm").unwrap();
    let n = 128;
    let mut x = vec![0f32; n * n];
    let mut w = vec![0f32; n * n];
    for i in 0..n * n {
        x[i] = ((i % 13) as f32) * 0.25 - 1.0;
        w[i] = ((i % 7) as f32) * 0.5 - 1.5;
    }
    let out = exe
        .run(&[HostTensor::f32(&[n, n], x.clone()),
               HostTensor::f32(&[n, n], w.clone())])
        .unwrap();
    let got = out[0].as_f32().unwrap();
    // host reference
    for (r, c) in [(0usize, 0usize), (5, 9), (127, 127), (64, 3)] {
        let mut acc = 0f32;
        for k in 0..n {
            acc += x[r * n + k] * w[k * n + c];
        }
        let g = got[r * n + c];
        assert!(
            (acc - g).abs() <= 1e-2 + 1e-4 * acc.abs().max(g.abs()),
            "({r},{c}): host {acc} vs pjrt {g}"
        );
    }
}

#[test]
fn pattern_conv_micro_artifact_shape_and_sparsity() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_micro("pattern_conv").unwrap();
    let (n, h, w, cin, cout, k) = (1, 16, 16, 16, 32, 4);
    let x = HostTensor::ones(&[n, h, w, cin]);
    let wc = HostTensor::ones(&[k, cin, cout]);
    let b = HostTensor::zeros(&[cout]);
    let out = exe.run(&[x, wc, b]).unwrap();
    assert_eq!(out[0].shape(), &[n, h, w, cout]);
    let vals = out[0].as_f32().unwrap();
    // interior pixels see all 4 taps x cin ones = 64; borders see fewer.
    let interior = vals[(8 * w + 8) * cout];
    assert_eq!(interior, (k * cin) as f32);
    assert!(vals.iter().all(|v| *v <= (k * cin) as f32 + 1e-4));
}

#[test]
fn infer_artifact_runs_and_is_finite() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_model_artifact("resnet_mini", "infer_b1").unwrap();
    let spec = rt.manifest.model("resnet_mini").unwrap().clone();
    let mut inputs = Vec::new();
    // params: small deterministic values; masks: ones; x: ramp.
    for p in &spec.params {
        let data: Vec<f32> = (0..p.elements())
            .map(|i| ((i % 101) as f32 - 50.0) * 2e-3)
            .collect();
        inputs.push(HostTensor::f32(&p.shape, data));
    }
    for m in &spec.masks {
        inputs.push(HostTensor::ones(&m.shape));
    }
    inputs.push(HostTensor::f32(
        &[1, 16, 16, 3],
        (0..16 * 16 * 3).map(|i| (i as f32) / 768.0).collect(),
    ));
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out[0].shape(), &[1, spec.classes]);
    assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn pallas_infer_matches_lax_infer() {
    // The Pallas-kernel-composed graph and the lax graph must agree:
    // proves the L1 kernels lower into L2 and execute under PJRT.
    let Some(rt) = runtime() else { return };
    let lax = rt.load_model_artifact("resnet_mini", "infer_b1").unwrap();
    let pal = rt
        .load_model_artifact("resnet_mini", "infer_pallas_b1")
        .unwrap();
    let spec = rt.manifest.model("resnet_mini").unwrap().clone();
    let mut inputs = Vec::new();
    for p in &spec.params {
        let data: Vec<f32> = (0..p.elements())
            .map(|i| (((i * 37) % 211) as f32 - 105.0) * 1e-3)
            .collect();
        inputs.push(HostTensor::f32(&p.shape, data));
    }
    for m in &spec.masks {
        inputs.push(HostTensor::ones(&m.shape));
    }
    inputs.push(HostTensor::f32(
        &[1, 16, 16, 3],
        (0..768).map(|i| ((i % 97) as f32) / 97.0).collect(),
    ));
    let a = lax.run(&inputs).unwrap();
    let b = pal.run(&inputs).unwrap();
    let av = a[0].as_f32().unwrap();
    let bv = b[0].as_f32().unwrap();
    for (x, y) in av.iter().zip(bv.iter()) {
        assert!(
            (x - y).abs() <= 1e-3 + 1e-3 * y.abs(),
            "pallas {y} vs lax {x}"
        );
    }
}

#[test]
fn signature_validation_rejects_bad_feeds() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_micro("gemm").unwrap();
    // wrong arity
    assert!(exe.run(&[HostTensor::ones(&[128, 128])]).is_err());
    // wrong shape
    assert!(exe
        .run(&[HostTensor::ones(&[64, 128]), HostTensor::ones(&[128, 128])])
        .is_err());
}

#[test]
fn executable_cache_dedupes() {
    let Some(rt) = runtime() else { return };
    let a = rt.load_micro("gemm").unwrap();
    let b = rt.load_micro("gemm").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    assert_eq!(rt.cached_count(), 1);
}
